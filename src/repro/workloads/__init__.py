"""Workload scenario lab (the evaluation side of the reproduction).

Everything the scheduler is *driven with* lives here, behind one schema:

* :mod:`repro.workloads.schema` — the canonical :class:`JobTrace` record
  (arrival, gang size, duration/iteration profile, model tag, priority
  class) with JSON round-tripping and materialisation into simulator
  :class:`~repro.core.jobs.JobSpec` lists;
* :mod:`repro.workloads.generators` — seeded, composable synthetic
  generators (Poisson / diurnal / bursty arrivals, lognormal / Pareto
  heavy-tail durations, gang-size skew, priority mixes);
* :mod:`repro.workloads.failures` — seeded, composable failure-event
  generators (node outages, GPU degradations, per-job software failures)
  behind a :class:`FailureRecipe`, feeding the simulator's
  fault-injection layer;
* :mod:`repro.workloads.loaders` — Philly-style CSV loader (+ committed
  sample) and loaders for the in-repo fixture generators;
* :mod:`repro.workloads.scenarios` — the named-scenario registry:
  ``workloads.scenario("philly-like-burst")`` returns a trace factory and
  a (possibly heterogeneous / racked) cluster factory the evaluation
  harness (``benchmarks/evaluate.py``) sweeps.

Determinism contract: every scenario trace is a pure function of
``(scenario, seed, num_jobs)`` — CI gates on it.
"""

from repro.workloads.failures import (
    FailureRecipe,
    GpuDegradations,
    JobFailures,
    NodeOutages,
    generate_failures,
)
from repro.workloads.generators import (
    Arrivals,
    Durations,
    GangSizes,
    TraceRecipe,
    generate_trace,
)
from repro.workloads.loaders import (
    gavel_fixture,
    load_philly_csv,
    philly_sample,
    save_philly_csv,
    shockwave_fixture,
)
from repro.workloads.scenarios import (
    Scenario,
    homogeneous_cluster,
    list_scenarios,
    mixed_a100_v100_cluster,
    register_scenario,
    scenario,
)
from repro.workloads.schema import (
    PRIORITY_CLASSES,
    SCHEMA_VERSION,
    JobTrace,
    from_jobspecs,
    load_json,
    load_json_with_failures,
    save_json,
    to_jobspecs,
)

__all__ = [
    "Arrivals",
    "Durations",
    "FailureRecipe",
    "GangSizes",
    "GpuDegradations",
    "JobFailures",
    "JobTrace",
    "NodeOutages",
    "PRIORITY_CLASSES",
    "SCHEMA_VERSION",
    "Scenario",
    "TraceRecipe",
    "from_jobspecs",
    "gavel_fixture",
    "generate_failures",
    "generate_trace",
    "homogeneous_cluster",
    "list_scenarios",
    "load_json",
    "load_json_with_failures",
    "load_philly_csv",
    "mixed_a100_v100_cluster",
    "philly_sample",
    "register_scenario",
    "save_json",
    "save_philly_csv",
    "scenario",
    "shockwave_fixture",
    "to_jobspecs",
]
