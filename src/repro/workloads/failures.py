"""Seeded, composable failure-event generators (the fault model).

Mirrors the structure of :mod:`repro.workloads.generators`: each failure
axis is a small frozen spec with a ``sample`` method, a
:class:`FailureRecipe` composes one of each, and
:func:`generate_failures` materialises a deterministic, time-sorted
:class:`~repro.core.faults.FailureEvent` stream for a given cluster and
horizon.  The same ``(recipe, cluster, horizon, seed)`` always yields the
identical stream — the chaos differential suite and the CI chaos-smoke
lane gate on that determinism.

Default shapes follow the Helios characterisation (PAPERS.md,
arxiv 2109.01313): node outages are a per-node Poisson process with
lognormal repair times (most repairs are a reboot, a heavy tail is a
hardware swap); a minority of jobs fail at least once and failed jobs
retry a small number of times; slowdowns (thermal / ECC pressure) are
rarer than crashes but last longer.  The absolute rates default far above
production (hours, not weeks, between faults) so short simulations
actually exercise the machinery; scenarios scale them as needed.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.cluster import ClusterSpec
from repro.core.faults import (
    GPU_DEGRADE,
    JOB_FAIL,
    NODE_DOWN,
    NODE_UP,
    FailureEvent,
)
from repro.workloads.schema import JobTrace

_H = 3600.0


@dataclasses.dataclass(frozen=True)
class NodeOutages:
    """Per-node crash/recover process: exponential time-between-crashes
    (``mtbf_h`` hours), lognormal repair durations (median
    ``repair_median_s``, shape ``repair_sigma``), at most
    ``max_per_node`` outages per node per trace."""

    mtbf_h: float = 6.0
    repair_median_s: float = 1800.0
    repair_sigma: float = 0.8
    min_repair_s: float = 120.0
    max_per_node: int = 8

    def sample(
        self, rng: np.random.Generator, num_nodes: int, horizon_s: float
    ) -> List[FailureEvent]:
        out: List[FailureEvent] = []
        for node in range(num_nodes):
            t = 0.0
            for _ in range(self.max_per_node):
                t += float(rng.exponential(self.mtbf_h * _H))
                if t >= horizon_s:
                    break
                repair = self.repair_median_s * float(
                    np.exp(self.repair_sigma * rng.standard_normal())
                )
                repair = max(repair, self.min_repair_s)
                out.append(FailureEvent(t, NODE_DOWN, node=node))
                t += repair
                if t < horizon_s:
                    out.append(FailureEvent(t, NODE_UP, node=node))
        return out


@dataclasses.dataclass(frozen=True)
class GpuDegradations:
    """Per-node slowdown process (stragglers): Poisson onsets at
    ``rate_per_node_per_day``, uniform severity in ``factor_range``
    (fraction of nominal speed), lognormal episode length; every episode
    is closed with a ``factor=1.0`` restore event."""

    rate_per_node_per_day: float = 1.0
    factor_range: tuple = (0.3, 0.9)
    duration_median_s: float = 3600.0
    duration_sigma: float = 0.6
    max_per_node: int = 8

    def sample(
        self, rng: np.random.Generator, num_nodes: int, horizon_s: float
    ) -> List[FailureEvent]:
        out: List[FailureEvent] = []
        lo, hi = self.factor_range
        for node in range(num_nodes):
            t = 0.0
            for _ in range(self.max_per_node):
                t += float(rng.exponential(24.0 * _H / self.rate_per_node_per_day))
                if t >= horizon_s:
                    break
                factor = float(rng.uniform(lo, hi))
                dur = self.duration_median_s * float(
                    np.exp(self.duration_sigma * rng.standard_normal())
                )
                out.append(FailureEvent(t, GPU_DEGRADE, node=node, factor=factor))
                t += max(dur, 60.0)
                if t < horizon_s:
                    out.append(FailureEvent(t, GPU_DEGRADE, node=node, factor=1.0))
        return out


@dataclasses.dataclass(frozen=True)
class JobFailures:
    """Per-job software-failure hazard: each job independently fails with
    probability ``fail_prob``; a failing job draws 1..``max_failures``
    failure instants spread over a window proportional to its (estimated)
    runtime.  Events that fire while the job is queued or already done are
    dropped by the simulator — the hazard missed — so the realised failure
    rate is a lower bound on ``fail_prob`` under contention."""

    fail_prob: float = 0.15
    max_failures: int = 2
    #: runtime estimate for iteration-profiled rows (no ``duration_s``).
    default_runtime_s: float = 3600.0
    #: failures land in ``[0, window_stretch * runtime]`` after arrival —
    #: stretched past 1.0 because queueing delays execution.
    window_stretch: float = 2.0

    def sample(
        self, rng: np.random.Generator, trace: Sequence[JobTrace]
    ) -> List[FailureEvent]:
        out: List[FailureEvent] = []
        for t in trace:
            if float(rng.random()) >= self.fail_prob:
                continue
            k = 1 + int(rng.integers(0, self.max_failures))
            runtime = (
                t.duration_s if t.duration_s is not None else self.default_runtime_s
            )
            window = max(self.window_stretch * runtime, 600.0)
            times = np.sort(rng.uniform(0.0, window, size=k))
            for dt in times:
                out.append(
                    FailureEvent(t.arrival_s + float(dt), JOB_FAIL, job_id=t.job_id)
                )
        return out


@dataclasses.dataclass(frozen=True)
class FailureRecipe:
    """One fault model = node outages x GPU degradations x job failures.
    Any axis may be ``None`` (disabled); the all-``None`` recipe generates
    the empty stream — bit-identical to the failure-free seed path."""

    nodes: Optional[NodeOutages] = None
    gpus: Optional[GpuDegradations] = None
    jobs: Optional[JobFailures] = None

    @classmethod
    def helios_like(cls) -> "FailureRecipe":
        """All three axes at the Helios-shaped defaults."""
        return cls(nodes=NodeOutages(), gpus=GpuDegradations(), jobs=JobFailures())


def generate_failures(
    recipe: FailureRecipe,
    cluster: ClusterSpec,
    horizon_s: float,
    seed: int,
    trace: Optional[Sequence[JobTrace]] = None,
) -> List[FailureEvent]:
    """Materialise the recipe's event stream, deterministically in ``seed``.

    Each axis draws from its own child RNG stream (``spawn_key``-style
    offsets of the seed), so enabling one axis never perturbs another's
    draws — recipes compose without cross-talk.  The merged stream is
    sorted by :meth:`FailureEvent.sort_key` (time, then kind, then
    target), a total order, so the output is unique regardless of
    generation order.
    """
    events: List[FailureEvent] = []
    if recipe.nodes is not None:
        rng = np.random.default_rng([seed, 0xFA01])
        events.extend(recipe.nodes.sample(rng, cluster.num_nodes, horizon_s))
    if recipe.gpus is not None:
        rng = np.random.default_rng([seed, 0xFA02])
        events.extend(recipe.gpus.sample(rng, cluster.num_nodes, horizon_s))
    if recipe.jobs is not None and trace:
        rng = np.random.default_rng([seed, 0xFA03])
        events.extend(recipe.jobs.sample(rng, trace))
        events = [e for e in events if e.time_s < horizon_s]
    return sorted(events, key=FailureEvent.sort_key)
