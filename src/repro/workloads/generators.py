"""Seeded, composable synthetic workload generators.

The datacenter characterisations behind the paper's evaluation (Philly,
Helios, PAI — "Deep Learning Workload Scheduling in GPU Datacenters" and
"Characterization and Prediction of Deep Learning Workloads") agree on
three properties the hand-rolled fixtures in :mod:`repro.core.traces`
under-represent:

* **arrival processes** are not stationary Poisson: submission rates swing
  diurnally (3-5x peak/trough) and burst (gang submissions, sweep scripts,
  retry storms);
* **durations** are heavy-tailed: most jobs run minutes, a Pareto tail
  runs days and dominates GPU-time;
* **gang sizes** are skewed: single-GPU jobs dominate counts, 8+-GPU gangs
  dominate occupancy.

Each axis is a small frozen spec with a ``sample`` method; a
:class:`TraceRecipe` composes one of each into a full generator, and
:func:`generate_trace` materialises it deterministically from a seed.  The
same ``(recipe, num_jobs, seed)`` always yields the identical trace —
that is what makes scenario sweeps reproducible and lets CI gate on
determinism.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

import numpy as np

from repro.core.profiler import ThroughputProfile
from repro.core.traces import TABLE1_MODELS
from repro.workloads.schema import JobTrace

_H = 3600.0


# --------------------------------------------------------------------------- #
# Arrival processes
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class Arrivals:
    """Arrival-time generator.

    ``kind``:

    * ``"poisson"`` — homogeneous Poisson at ``rate_per_hour``;
    * ``"diurnal"`` — inhomogeneous Poisson (thinning) with sinusoidal
      rate, ``peak_ratio`` = peak/trough, period ``period_h`` hours, the
      trough at t=0 (clusters fill over the working day);
    * ``"bursty"`` — background Poisson carrying half the mean rate, plus
      a clustered burst every ``burst_every_h`` hours spread over
      ``burst_spread_s`` (sweep scripts / gang retries).  ``burst_size``
      0 (default) sizes bursts to carry the other half of the rate
      budget, so the realised mean rate matches ``rate_per_hour``.
    """

    kind: str = "poisson"
    rate_per_hour: float = 80.0
    peak_ratio: float = 4.0
    period_h: float = 24.0
    burst_every_h: float = 3.0
    burst_size: int = 0
    burst_spread_s: float = 300.0

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        if self.kind == "poisson":
            gaps = rng.exponential(_H / self.rate_per_hour, size=n)
            return np.cumsum(gaps)
        if self.kind == "diurnal":
            return self._diurnal(rng, n)
        if self.kind == "bursty":
            return self._bursty(rng, n)
        raise ValueError(f"unknown arrival kind {self.kind!r}")

    def _rate_at(self, t_s: np.ndarray) -> np.ndarray:
        """Diurnal rate (jobs/hour) at time t: mean ``rate_per_hour``,
        peak/trough ratio ``peak_ratio``."""
        a = (self.peak_ratio - 1.0) / (self.peak_ratio + 1.0)
        phase = 2.0 * math.pi * t_s / (self.period_h * _H)
        return self.rate_per_hour * (1.0 - a * np.cos(phase))

    def _diurnal(self, rng: np.random.Generator, n: int) -> np.ndarray:
        peak = self.rate_per_hour * 2.0 * self.peak_ratio / (self.peak_ratio + 1.0)
        out = np.empty(n)
        t, got = 0.0, 0
        while got < n:
            t += float(rng.exponential(_H / peak))
            if rng.random() * peak <= float(self._rate_at(np.array(t))):
                out[got] = t
                got += 1
        return out

    def _bursty(self, rng: np.random.Generator, n: int) -> np.ndarray:
        # background pays for half the mean rate, bursts for the other half
        bg_rate = self.rate_per_hour / 2.0
        mean_burst = self.burst_size or max(
            1, round(bg_rate * self.burst_every_h)
        )
        times: List[float] = []
        t_bg = 0.0
        horizon = n * _H / self.rate_per_hour * 4.0 + _H
        while t_bg < horizon:
            t_bg += float(rng.exponential(_H / bg_rate))
            times.append(t_bg)
        t_burst = float(rng.uniform(0.0, self.burst_every_h * _H))
        while t_burst < horizon:
            k = max(1, int(rng.poisson(mean_burst)))
            times.extend(
                (t_burst + rng.uniform(0.0, self.burst_spread_s, size=k)).tolist()
            )
            t_burst += self.burst_every_h * _H
        times.sort()
        return np.asarray(times[:n])


# --------------------------------------------------------------------------- #
# Duration distributions
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class Durations:
    """Isolated-runtime generator (seconds, at the job's own gang size).

    ``kind``: ``"lognormal"`` (median ``median_s``, shape ``sigma``),
    ``"pareto"`` (scale ``min_s``, tail index ``alpha`` — the heavy tail
    of the Philly/Helios characterisations), or ``"loguniform"``
    (``10^U[log10 lo, log10 hi]`` minutes — the Gavel generator's shape).
    All kinds clip into ``[min_s, cap_s]``.
    """

    kind: str = "lognormal"
    median_s: float = 1800.0
    sigma: float = 1.6
    alpha: float = 1.2
    min_s: float = 120.0
    cap_s: float = 4.0 * 24.0 * _H

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        if self.kind == "lognormal":
            d = self.median_s * np.exp(self.sigma * rng.standard_normal(n))
        elif self.kind == "pareto":
            d = self.median_s * (1.0 + rng.pareto(self.alpha, size=n))
        elif self.kind == "loguniform":
            lo, hi = np.log10(self.min_s), np.log10(self.cap_s)
            d = 10.0 ** rng.uniform(lo, hi, size=n)
        else:
            raise ValueError(f"unknown duration kind {self.kind!r}")
        return np.clip(d, self.min_s, self.cap_s)


# --------------------------------------------------------------------------- #
# Gang sizes, models, priority mix
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class GangSizes:
    """Gang-size (GPU count) distribution; defaults to the Philly-style
    skew where single-GPU jobs dominate counts."""

    sizes: Tuple[int, ...] = (1, 2, 4, 8)
    probs: Tuple[float, ...] = (0.60, 0.25, 0.10, 0.05)

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        p = np.asarray(self.probs, dtype=np.float64)
        return np.asarray(self.sizes)[rng.choice(len(self.sizes), size=n, p=p / p.sum())]


@dataclasses.dataclass(frozen=True)
class TraceRecipe:
    """One synthetic workload = arrivals x durations x gangs x models
    (+ a production-priority fraction whose jobs bypass packing)."""

    arrivals: Arrivals = Arrivals()
    durations: Durations = Durations()
    gangs: GangSizes = GangSizes()
    models: Tuple[str, ...] = tuple(TABLE1_MODELS)
    production_fraction: float = 0.0


def generate_trace(
    recipe: TraceRecipe,
    num_jobs: int,
    seed: int,
    profile: Optional[ThroughputProfile] = None,
) -> List[JobTrace]:
    """Materialise ``num_jobs`` trace rows, deterministically in ``seed``.

    Durations are kept as durations (the schema converts through the
    profile at :meth:`JobTrace.to_jobspec` time), so the same recipe can
    be re-profiled on different hardware without regenerating.  The
    ``profile`` argument exists only for signature compatibility with the
    fixture loaders — generation itself never consults it.
    """
    del profile  # duration-profiled rows; materialisation converts later
    rng = np.random.default_rng(seed)
    arrivals = recipe.arrivals.sample(rng, num_jobs)
    durations = recipe.durations.sample(rng, num_jobs)
    gangs = recipe.gangs.sample(rng, num_jobs)
    models = [
        recipe.models[int(k)]
        for k in rng.integers(0, len(recipe.models), size=num_jobs)
    ]
    batch = 16 * (2 ** rng.integers(0, 4, size=num_jobs))
    prod = rng.random(num_jobs) < recipe.production_fraction
    return [
        JobTrace(
            job_id=j,
            model=models[j],
            num_gpus=int(gangs[j]),
            arrival_s=float(arrivals[j]),
            duration_s=float(durations[j]),
            priority="production" if prod[j] else "best-effort",
            batch_size=int(batch[j]),
        )
        for j in range(num_jobs)
    ]
