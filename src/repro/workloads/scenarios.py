"""Named workload scenarios: ``workloads.scenario("philly-like-burst")``.

A :class:`Scenario` bundles everything one evaluation arm needs — a trace
source (synthetic recipe, CSV loader or fixture generator) plus a cluster
shape (optionally heterogeneous / racked) — behind a name, so the
evaluation harness, tests and CI all sweep the same registry instead of
re-hand-rolling workloads.  Every scenario is **seeded-deterministic**:
``make_trace(seed)`` is a pure function of its arguments.

Registry (see README for the full table):

====================  =======================================================
``poisson-steady``    stationary Poisson arrivals, Shockwave-class durations
``diurnal-lognorm``   diurnal arrivals (4x peak/trough), lognormal durations
``philly-like-burst`` bursty arrivals, Pareto heavy-tail durations, gang
                      skew, 10% production (non-packable) jobs
``tiresias-churn``    oversubscribed arrivals + bimodal durations — drives
                      Tiresias demotion/resume churn, the warm-start
                      stress regime
``philly-sample``     loader-backed: the committed Philly-style CSV
``shockwave-fixture`` the paper's Shockwave-like fixture generator
``gavel-fixture``     the paper's Gavel-like fixture generator
``hetero-mixed``      philly-like workload on a half-A100 / half-V100
                      two-rack cluster (type- and topology-aware paths on)
``node-flaky``        poisson-steady workload + aggressive node
                      crash/recover churn (1h MTBF) — fault-tolerance
                      stress regime
``philly-failures``   philly-like burst under the full Helios-shaped
                      failure mix (outages + degradations + job failures)
====================  =======================================================

Custom scenarios register with :func:`register_scenario`.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.cluster import ClusterSpec
from repro.core.faults import FailureEvent
from repro.core.profiler import ThroughputProfile
from repro.workloads import loaders
from repro.workloads.failures import (
    FailureRecipe,
    GpuDegradations,
    JobFailures,
    NodeOutages,
    generate_failures,
)
from repro.workloads.generators import (
    Arrivals,
    Durations,
    GangSizes,
    TraceRecipe,
    generate_trace,
)
from repro.workloads.schema import JobTrace


def homogeneous_cluster(num_gpus: int, gpus_per_node: int = 4) -> ClusterSpec:
    if num_gpus % gpus_per_node:
        raise ValueError(f"{num_gpus} GPUs not a multiple of node size {gpus_per_node}")
    return ClusterSpec(num_gpus // gpus_per_node, gpus_per_node)


def racked_cluster(
    num_gpus: int, gpus_per_node: int = 4, nodes_per_rack: int = 2
) -> ClusterSpec:
    """Homogeneous cluster with a rack topology (rack = failure domain):
    the shape the failure scenarios run on, so domain-spread placement
    and rack-aware relabelling have real domains to work with."""
    base = homogeneous_cluster(num_gpus, gpus_per_node)
    return dataclasses.replace(base, nodes_per_rack=nodes_per_rack)


def mixed_a100_v100_cluster(num_gpus: int, gpus_per_node: int = 4) -> ClusterSpec:
    """Half A100 / half V100 nodes, one rack per type — the Gavel-style
    heterogeneity regime where packing feasibility (16 vs 40 GB HBM) and
    per-type speed flip policy rankings."""
    base = homogeneous_cluster(num_gpus, gpus_per_node)
    kc = base.num_nodes
    half = max(1, kc // 2)
    types = ("a100",) * half + ("v100",) * (kc - half)
    return ClusterSpec(
        kc,
        gpus_per_node,
        node_gpu_types=types,
        nodes_per_rack=half,
    )


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    description: str
    kind: str  # "synthetic" | "loader" | "fixture"
    #: trace factory: (seed, num_jobs, profile) -> List[JobTrace]
    trace_fn: Callable[[int, int, Optional[ThroughputProfile]], List[JobTrace]]
    #: cluster factory: (num_gpus) -> ClusterSpec
    cluster_fn: Callable[[int], ClusterSpec] = homogeneous_cluster
    default_num_jobs: int = 120
    heterogeneous: bool = False
    #: optional failure model: the :class:`repro.workloads.failures.FailureRecipe`
    #: this scenario injects (None = fault-free — the seed behaviour).
    failure_recipe: Optional[FailureRecipe] = None

    def make_trace(
        self,
        seed: int,
        num_jobs: Optional[int] = None,
        profile: Optional[ThroughputProfile] = None,
    ) -> List[JobTrace]:
        return self.trace_fn(seed, num_jobs or self.default_num_jobs, profile)

    def make_cluster(self, num_gpus: int) -> ClusterSpec:
        return self.cluster_fn(num_gpus)

    def make_failures(
        self,
        seed: int,
        cluster: ClusterSpec,
        horizon_s: float,
        trace: Optional[List[JobTrace]] = None,
    ) -> List[FailureEvent]:
        """Seeded failure-event stream for one arm (empty for fault-free
        scenarios).  Deterministic in ``(scenario, seed, cluster shape)``
        — the same contract as :meth:`make_trace`."""
        if self.failure_recipe is None:
            return []
        return generate_failures(
            self.failure_recipe, cluster, horizon_s, seed, trace=trace
        )


_REGISTRY: Dict[str, Scenario] = {}


def register_scenario(s: Scenario) -> Scenario:
    if s.name in _REGISTRY:
        raise ValueError(f"scenario {s.name!r} already registered")
    _REGISTRY[s.name] = s
    return s


def scenario(name: str) -> Scenario:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; registered: {list_scenarios()}"
        ) from None


def list_scenarios() -> List[str]:
    return sorted(_REGISTRY)


def _synthetic(recipe: TraceRecipe):
    def fn(seed: int, num_jobs: int, profile=None) -> List[JobTrace]:
        return generate_trace(recipe, num_jobs, seed, profile)

    return fn


# --------------------------------------------------------------------------- #
# Built-in registry
# --------------------------------------------------------------------------- #
register_scenario(
    Scenario(
        name="poisson-steady",
        description="stationary Poisson arrivals, Shockwave-class durations",
        kind="synthetic",
        trace_fn=_synthetic(
            TraceRecipe(
                arrivals=Arrivals(kind="poisson", rate_per_hour=60.0),
                durations=Durations(kind="lognormal", median_s=2400.0, sigma=1.1),
                gangs=GangSizes(probs=(0.60, 0.30, 0.09, 0.01)),
            )
        ),
    )
)

register_scenario(
    Scenario(
        name="diurnal-lognorm",
        description="diurnal arrivals (4x peak/trough), lognormal durations",
        kind="synthetic",
        trace_fn=_synthetic(
            TraceRecipe(
                arrivals=Arrivals(kind="diurnal", rate_per_hour=60.0, peak_ratio=4.0),
                durations=Durations(kind="lognormal", median_s=1800.0, sigma=1.6),
            )
        ),
    )
)

register_scenario(
    Scenario(
        name="philly-like-burst",
        description=(
            "bursty arrivals, Pareto heavy-tail durations, gang skew, "
            "10% production jobs"
        ),
        kind="synthetic",
        trace_fn=_synthetic(
            TraceRecipe(
                arrivals=Arrivals(kind="bursty", rate_per_hour=70.0),
                durations=Durations(kind="pareto", median_s=900.0, alpha=1.1),
                gangs=GangSizes(probs=(0.55, 0.25, 0.12, 0.08)),
                production_fraction=0.10,
            )
        ),
    )
)

register_scenario(
    Scenario(
        name="tiresias-churn",
        description=(
            "oversubscribed arrivals + bimodal durations: sustained "
            "Tiresias demotion/resume churn (warm-start stress regime)"
        ),
        kind="synthetic",
        trace_fn=_synthetic(
            TraceRecipe(
                arrivals=Arrivals(kind="poisson", rate_per_hour=200.0),
                durations=Durations(kind="lognormal", median_s=3600.0, sigma=0.9),
                gangs=GangSizes(probs=(0.70, 0.20, 0.08, 0.02)),
            )
        ),
    )
)

register_scenario(
    Scenario(
        name="philly-sample",
        description="loader-backed: committed Philly-style CSV sample",
        kind="loader",
        # the file IS the workload: seed and num_jobs only subsample
        trace_fn=lambda seed, num_jobs, profile=None: loaders.philly_sample()[
            :num_jobs
        ],
        default_num_jobs=10**9,  # whole file
    )
)

register_scenario(
    Scenario(
        name="shockwave-fixture",
        description="the paper's Shockwave-like fixture generator (§6.1)",
        kind="fixture",
        trace_fn=lambda seed, num_jobs, profile=None: loaders.shockwave_fixture(
            num_jobs, seed, profile
        ),
    )
)

register_scenario(
    Scenario(
        name="gavel-fixture",
        description="the paper's Gavel-like fixture generator (Fig. 17)",
        kind="fixture",
        trace_fn=lambda seed, num_jobs, profile=None: loaders.gavel_fixture(
            num_jobs, seed, profile
        ),
    )
)

register_scenario(
    Scenario(
        name="node-flaky",
        description=(
            "steady Poisson workload on a cluster with aggressively flaky "
            "nodes (1h MTBF, ~15 min repairs) — the node-crash/recover "
            "stress regime for eviction, retry/backoff and targeted "
            "cache-invalidation paths"
        ),
        kind="synthetic",
        cluster_fn=racked_cluster,
        failure_recipe=FailureRecipe(
            nodes=NodeOutages(
                mtbf_h=1.0, repair_median_s=900.0, repair_sigma=0.6
            )
        ),
        trace_fn=_synthetic(
            TraceRecipe(
                arrivals=Arrivals(kind="poisson", rate_per_hour=60.0),
                durations=Durations(kind="lognormal", median_s=2400.0, sigma=1.1),
                gangs=GangSizes(probs=(0.60, 0.30, 0.09, 0.01)),
            )
        ),
    )
)

register_scenario(
    Scenario(
        name="philly-failures",
        description=(
            "philly-like bursty workload under the Helios-shaped failure "
            "mix (node outages + GPU degradations + per-job software "
            "failures) — the end-to-end graceful-degradation regime"
        ),
        kind="synthetic",
        cluster_fn=racked_cluster,
        failure_recipe=FailureRecipe.helios_like(),
        trace_fn=_synthetic(
            TraceRecipe(
                arrivals=Arrivals(kind="bursty", rate_per_hour=70.0),
                durations=Durations(kind="pareto", median_s=900.0, alpha=1.1),
                gangs=GangSizes(probs=(0.55, 0.25, 0.12, 0.08)),
                production_fraction=0.10,
            )
        ),
    )
)

register_scenario(
    Scenario(
        name="hetero-mixed",
        description=(
            "philly-like workload on a half-A100/half-V100 two-rack "
            "cluster (type- & topology-aware migration and packing)"
        ),
        kind="synthetic",
        heterogeneous=True,
        cluster_fn=mixed_a100_v100_cluster,
        trace_fn=_synthetic(
            TraceRecipe(
                arrivals=Arrivals(kind="poisson", rate_per_hour=60.0),
                durations=Durations(kind="lognormal", median_s=2400.0, sigma=1.2),
                gangs=GangSizes(probs=(0.55, 0.30, 0.10, 0.05)),
            )
        ),
    )
)
