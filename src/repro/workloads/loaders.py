"""Trace loaders: Philly-style CSV and the in-repo fixture generators.

**Philly-style CSV** — the column set the Microsoft Philly trace release
(and most cluster dumps derived from it) boils down to:

    job_id,vc,submitted_s,num_gpus,duration_s,model,status

``vc`` is the virtual cluster (production VCs map to the ``production``
priority class — their jobs bypass packing), ``submitted_s`` is seconds
since the trace epoch, ``duration_s`` the observed runtime at the job's
gang size, ``status`` one of Pass/Killed/Failed.  Failed jobs are dropped
(they never represent useful demand); Pass and Killed both count — a
killed job still occupied its gang.  Unknown model tags map
deterministically onto the Table-1 catalog so any Philly-shaped file
loads without a custom catalog (the mapping is a stable hash, not an
RNG).  A small committed sample lives next to this module
(``data/philly_sample.csv``) and backs the ``philly-sample`` scenario.

**Fixture loaders** — :func:`shockwave_fixture` / :func:`gavel_fixture`
wrap the seeded generators of :mod:`repro.core.traces` into the canonical
schema, so the paper's original fixture workloads are first-class
scenarios too.
"""

from __future__ import annotations

import csv
import hashlib
import os
from typing import List, Optional, Sequence

from repro.core.profiler import MODEL_CATALOG, ThroughputProfile
from repro.core.traces import gavel_trace, shockwave_trace
from repro.workloads.schema import JobTrace, from_jobspecs

PHILLY_COLUMNS = (
    "job_id",
    "vc",
    "submitted_s",
    "num_gpus",
    "duration_s",
    "model",
    "status",
)

#: VC names treated as production (strict-priority, non-packable) demand.
PRODUCTION_VCS = frozenset({"prod", "production", "vc-prod"})

DATA_DIR = os.path.join(os.path.dirname(__file__), "data")
PHILLY_SAMPLE = os.path.join(DATA_DIR, "philly_sample.csv")


def _canonical_model(tag: str) -> str:
    """Map an arbitrary trace model tag into the profiled catalog.

    Known tags pass through; unknown ones pick a Table-1 model by stable
    hash, so the same file always loads the same workload."""
    if tag in MODEL_CATALOG:
        return tag
    names = sorted(MODEL_CATALOG)
    h = int.from_bytes(hashlib.sha256(tag.encode()).digest()[:8], "little")
    return names[h % len(names)]


def load_philly_csv(path: str) -> List[JobTrace]:
    """Parse a Philly-style CSV into the canonical schema.

    Arrivals are re-based to the earliest surviving submission; rows are
    renumbered in (arrival, file order) so job ids are dense and unique
    regardless of the file's own id column gaps."""
    rows = []
    with open(path, newline="") as f:
        reader = csv.DictReader(f)
        missing = set(PHILLY_COLUMNS) - set(reader.fieldnames or ())
        if missing:
            raise ValueError(f"{path}: missing Philly columns {sorted(missing)}")
        for i, rec in enumerate(reader):
            status = rec["status"].strip().lower()
            if status == "failed":
                continue
            duration = float(rec["duration_s"])
            gpus = int(rec["num_gpus"])
            if duration <= 0 or gpus <= 0:
                continue
            rows.append(
                (
                    float(rec["submitted_s"]),
                    i,
                    _canonical_model(rec["model"].strip()),
                    gpus,
                    duration,
                    rec["vc"].strip().lower(),
                )
            )
    if not rows:
        raise ValueError(f"{path}: no usable rows")
    rows.sort(key=lambda r: (r[0], r[1]))
    t0 = rows[0][0]
    return [
        JobTrace(
            job_id=j,
            model=model,
            num_gpus=gpus,
            arrival_s=submitted - t0,
            duration_s=duration,
            priority="production" if vc in PRODUCTION_VCS else "best-effort",
        )
        for j, (submitted, _, model, gpus, duration, vc) in enumerate(rows)
    ]


def save_philly_csv(path: str, trace: Sequence[JobTrace]) -> None:
    """Write a trace back out in the Philly-style column set (duration-
    profiled rows only — iteration-profiled rows have no runtime column)."""
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(PHILLY_COLUMNS)
        for t in trace:
            if t.duration_s is None:
                raise ValueError(f"job {t.job_id} is iteration-profiled")
            w.writerow(
                [
                    t.job_id,
                    "prod" if t.priority == "production" else "research",
                    f"{t.arrival_s:.1f}",
                    t.num_gpus,
                    f"{t.duration_s:.1f}",
                    t.model,
                    "Pass",
                ]
            )


def philly_sample(path: Optional[str] = None) -> List[JobTrace]:
    """The committed sample file backing the ``philly-sample`` scenario."""
    return load_philly_csv(path or PHILLY_SAMPLE)


# --------------------------------------------------------------------------- #
# Fixture-backed loaders
# --------------------------------------------------------------------------- #
def shockwave_fixture(
    num_jobs: int, seed: int, profile: Optional[ThroughputProfile] = None
) -> List[JobTrace]:
    profile = profile or ThroughputProfile()
    return from_jobspecs(shockwave_trace(num_jobs=num_jobs, seed=seed, profile=profile))


def gavel_fixture(
    num_jobs: int, seed: int, profile: Optional[ThroughputProfile] = None
) -> List[JobTrace]:
    profile = profile or ThroughputProfile()
    return from_jobspecs(gavel_trace(num_jobs=num_jobs, seed=seed, profile=profile))
