"""Canonical workload-trace schema (the scenario lab's interchange format).

A :class:`JobTrace` is ONE submitted job as a cluster trace records it:
arrival, gang size, a duration/iteration profile, a model tag and a
priority class.  It deliberately carries *either* a wall-clock duration
(what real traces like Philly publish — runtime at the job's own gang
size) *or* an explicit iteration count (what the simulator ultimately
consumes); :meth:`JobTrace.to_jobspec` materialises the former through a
:class:`~repro.core.profiler.ThroughputProfile` using the exact conversion
rule of the fixture generators (:func:`repro.core.traces.iters_for_duration`),
so loader-backed and synthetic scenarios drive the scheduler identically.

Every trace round-trips through JSON (:func:`save_json` / :func:`load_json`)
with a versioned envelope, which is how sweeps archive the exact workload
they measured.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.faults import FailureEvent
from repro.core.jobs import JobSpec
from repro.core.profiler import MODEL_CATALOG, ThroughputProfile
from repro.core.traces import iters_for_duration

#: v2 adds an optional top-level ``failures`` list (fault-model events,
#: :class:`~repro.core.faults.FailureEvent` rows) to the envelope.  The
#: job-row schema is unchanged, so v1 documents load as-is.
SCHEMA_VERSION = "tesserae-trace-v2"
_COMPAT_VERSIONS = ("tesserae-trace-v1", SCHEMA_VERSION)

#: priority classes: "production" jobs carry strict SLOs and bypass packing
#: (§4.3 "Fairness" — no Algorithm-4 edges), "best-effort" jobs pack freely.
PRIORITY_CLASSES = ("best-effort", "production")


@dataclasses.dataclass(frozen=True)
class JobTrace:
    """One trace row.  Exactly one of ``duration_s`` / ``total_iters`` is
    set; ``duration_s`` is the isolated runtime at the job's own gang size."""

    job_id: int
    model: str
    num_gpus: int
    arrival_s: float
    duration_s: Optional[float] = None
    total_iters: Optional[float] = None
    priority: str = "best-effort"
    batch_size: int = 32

    def __post_init__(self):
        if (self.duration_s is None) == (self.total_iters is None):
            raise ValueError(
                f"job {self.job_id}: exactly one of duration_s/total_iters "
                f"must be set (got duration_s={self.duration_s}, "
                f"total_iters={self.total_iters})"
            )
        if self.priority not in PRIORITY_CLASSES:
            raise ValueError(
                f"job {self.job_id}: unknown priority {self.priority!r}; "
                f"expected one of {PRIORITY_CLASSES}"
            )
        if self.num_gpus <= 0:
            raise ValueError(f"job {self.job_id}: num_gpus must be positive")
        if self.arrival_s < 0:
            raise ValueError(f"job {self.job_id}: negative arrival")

    # -- materialisation -------------------------------------------------- #
    def to_jobspec(self, profile: Optional[ThroughputProfile] = None) -> JobSpec:
        profile = profile or ThroughputProfile()
        iters = (
            self.total_iters
            if self.total_iters is not None
            else iters_for_duration(self.model, self.num_gpus, self.duration_s, profile)
        )
        return JobSpec(
            job_id=self.job_id,
            model=self.model,
            num_gpus=self.num_gpus,
            total_iters=float(iters),
            arrival_time=float(self.arrival_s),
            batch_size=self.batch_size,
            packable=self.priority != "production",
            is_llm=MODEL_CATALOG[self.model].is_llm,
        )

    # -- (de)serialisation ------------------------------------------------ #
    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        return {k: v for k, v in d.items() if v is not None}

    @classmethod
    def from_dict(cls, d: Dict) -> "JobTrace":
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - fields
        if unknown:
            raise ValueError(f"unknown JobTrace fields: {sorted(unknown)}")
        return cls(**d)


def to_jobspecs(
    trace: Sequence[JobTrace], profile: Optional[ThroughputProfile] = None
) -> List[JobSpec]:
    """Materialise a whole trace, sorted the way the simulator consumes it."""
    profile = profile or ThroughputProfile()
    specs = [t.to_jobspec(profile) for t in trace]
    return sorted(specs, key=lambda s: (s.arrival_time, s.job_id))


def from_jobspecs(specs: Sequence[JobSpec]) -> List[JobTrace]:
    """Loader for the existing fixture generators
    (:func:`repro.core.traces.shockwave_trace` & friends): re-expresses
    their :class:`JobSpec` lists in the canonical schema (iteration-
    profiled, so no profile round-trip error is introduced)."""
    return [
        JobTrace(
            job_id=s.job_id,
            model=s.model,
            num_gpus=s.num_gpus,
            arrival_s=s.arrival_time,
            total_iters=s.total_iters,
            priority="best-effort" if s.packable else "production",
            batch_size=s.batch_size,
        )
        for s in specs
    ]


def save_json(
    path: str,
    trace: Sequence[JobTrace],
    meta: Optional[Dict] = None,
    failures: Optional[Sequence[FailureEvent]] = None,
) -> None:
    doc = {
        "schema": SCHEMA_VERSION,
        "meta": dict(meta or {}),
        "jobs": [t.to_dict() for t in trace],
    }
    if failures is not None:
        # canonical order (FailureEvent.sort_key is a total order), so the
        # archived document is unique regardless of generation order
        doc["failures"] = [
            e.to_dict() for e in sorted(failures, key=FailureEvent.sort_key)
        ]
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)


def load_json(path: str) -> List[JobTrace]:
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") not in _COMPAT_VERSIONS:
        raise ValueError(
            f"{path}: schema {doc.get('schema')!r} not in {_COMPAT_VERSIONS!r}"
        )
    return [JobTrace.from_dict(d) for d in doc["jobs"]]


def load_json_with_failures(
    path: str,
) -> Tuple[List[JobTrace], List[FailureEvent]]:
    """Like :func:`load_json` but also returns the archived fault-model
    events (empty for v1 documents, which predate the field)."""
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") not in _COMPAT_VERSIONS:
        raise ValueError(
            f"{path}: schema {doc.get('schema')!r} not in {_COMPAT_VERSIONS!r}"
        )
    jobs = [JobTrace.from_dict(d) for d in doc["jobs"]]
    failures = [FailureEvent.from_dict(d) for d in doc.get("failures", [])]
    return jobs, failures
