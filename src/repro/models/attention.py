"""Attention variants: GQA (llama/qwen/dbrx/nemotron), QK-norm (qwen3),
M-RoPE (qwen2-vl), MLA (deepseek-v2), sliding-window decode, KV caches.

All functions are pure; caches are explicit pytrees.  The scaled-dot-
product core dispatches to the Pallas flash kernel when
``REPRO_USE_FLASH=1`` (interpret off-TPU) and otherwise uses a fused-einsum
reference path — both numerically validated against each other in tests.
"""

from __future__ import annotations

import os
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch.pspec import constrain
from repro.models.layers import apply_mrope, apply_rope, dense_init, rms_norm

NEG_INF = -1e30


def use_flash() -> bool:
    return os.environ.get("REPRO_USE_FLASH", "0") == "1"


# --------------------------------------------------------------------------- #
# Parameter init
# --------------------------------------------------------------------------- #
def init_gqa(rng, cfg: ModelConfig, dtype) -> Dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(rng, 6)
    p = {
        "wq": dense_init(ks[0], d, (h, hd), dtype),
        "wk": dense_init(ks[1], d, (kv, hd), dtype),
        "wv": dense_init(ks[2], d, (kv, hd), dtype),
        "wo": dense_init(ks[3], h * hd, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def init_mla(rng, cfg: ModelConfig, dtype) -> Dict:
    """DeepSeek-V2 multi-head latent attention parameters."""
    d = cfg.d_model
    h = cfg.num_heads
    r = cfg.kv_lora_rank
    qn, qr, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(rng, 5)
    return {
        # queries (undecomposed; deepseek also low-ranks Q but cache-wise
        # only the KV path matters)
        "wq": dense_init(ks[0], d, (h, qn + qr), dtype),
        # compressed KV latent + decoupled rope key
        "wkv_a": dense_init(ks[1], d, (r + qr,), dtype),
        "kv_norm": jnp.zeros((r,), dtype),
        # up-projection from latent to per-head K_nope and V
        "wkv_b": dense_init(ks[2], r, (h, qn + vd), dtype),
        "wo": dense_init(ks[3], h * vd, d, dtype),
    }


# --------------------------------------------------------------------------- #
# SDPA core (GQA-aware)
# --------------------------------------------------------------------------- #
def sdpa(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, T, KV, D)
    v: jax.Array,  # (B, T, KV, D)
    causal: bool,
    q_offset: Optional[jax.Array] = None,  # scalar: absolute pos of q[0]
    kv_valid_len: Optional[jax.Array] = None,  # scalar: #valid cache slots
) -> jax.Array:
    b, s, h, d = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh

    if use_flash() and causal and s == t and q_offset is None and kv_valid_len is None:
        from repro.kernels.ops import flash_attention

        kr = jnp.repeat(k, g, axis=2)
        vr = jnp.repeat(v, g, axis=2)
        qt = q.transpose(0, 2, 1, 3)
        out = flash_attention(qt, kr.transpose(0, 2, 1, 3), vr.transpose(0, 2, 1, 3))
        return out.transpose(0, 2, 1, 3)

    if os.environ.get("REPRO_ABLATE_ATTN") == "1":
        # profiling bisection knob: shape-preserving stand-in for SDPA
        return jnp.repeat(v.mean(axis=1, keepdims=True), g, axis=2).astype(
            q.dtype
        ) + 0 * q

    qg = q.reshape(b, s, kvh, g, d)
    scale = 1.0 / (d**0.5)
    logits = jnp.einsum(
        "bskgd,btkd->bkgst",
        qg.astype(jnp.float32),
        k.astype(jnp.float32),
    ) * scale  # (B, KV, G, S, T)

    if causal or kv_valid_len is not None:
        rows = jnp.arange(s)[:, None]
        if q_offset is not None:
            rows = rows + q_offset
        cols = jnp.arange(t)[None, :]
        ok = jnp.ones((s, t), bool) if not causal else rows >= cols
        if kv_valid_len is not None:
            ok &= cols < kv_valid_len
        logits = jnp.where(ok[None, None, None], logits, NEG_INF)

    probs = jax.nn.softmax(logits, axis=-1)
    # Perf knob (EXPERIMENTS.md §Perf H3): the (B,KV,G,S,T) probs tensor is
    # the largest HBM buffer in the unfused path; bf16 halves its traffic
    # (row stats stay f32 inside softmax).  On real TPU the Pallas flash
    # kernel replaces this path entirely.
    if os.environ.get("REPRO_ATTN_DTYPE", "f32") == "bf16":
        probs = probs.astype(jnp.bfloat16)
        out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.bfloat16))
    else:
        out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    # v's head dim may differ from q/k's (MLA: qk 192, v 128)
    return out.reshape(b, s, h, v.shape[-1]).astype(q.dtype)


# --------------------------------------------------------------------------- #
# GQA attention: train forward + decode step
# --------------------------------------------------------------------------- #
def _project_qkv(p, cfg: ModelConfig, x, positions, mrope_positions):
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k = jnp.einsum("bsd,dke->bske", x, p["wk"])
    v = jnp.einsum("bsd,dke->bske", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.mrope and mrope_positions is not None:
        q = apply_mrope(q, mrope_positions, cfg.rope_theta)
        k = apply_mrope(k, mrope_positions, cfg.rope_theta)
    elif cfg.num_heads > 0 and cfg.rope_theta > 0:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_forward(
    p: Dict,
    cfg: ModelConfig,
    x: jax.Array,  # (B, S, D)
    positions: jax.Array,  # (B, S)
    mrope_positions: Optional[jax.Array] = None,  # (3, B, S)
    causal: bool = True,
) -> jax.Array:
    q, k, v = _project_qkv(p, cfg, x, positions, mrope_positions)
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    k = constrain(k, "batch", "seq", "kv_heads", "head_dim")
    out = sdpa(q, k, v, causal=causal)
    out = out.reshape(*x.shape[:2], -1)
    return jnp.einsum("bsf,fd->bsd", out, p["wo"])


def init_gqa_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype) -> Dict:
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, cache_len, kv, hd), dtype),
        "v": jnp.zeros((batch, cache_len, kv, hd), dtype),
    }


def gqa_decode_step(
    p: Dict,
    cfg: ModelConfig,
    x: jax.Array,        # (B, 1, D) new-token hidden
    cache: Dict,
    pos: jax.Array,      # scalar int: absolute position of the new token
) -> Tuple[jax.Array, Dict]:
    """One decode step.  With ``cfg.attention_window`` the cache is a ring
    buffer of window length (sub-quadratic long-context decode); otherwise
    the cache covers the full context."""
    b = x.shape[0]
    positions = jnp.broadcast_to(pos, (b, 1))
    mpos = jnp.broadcast_to(pos, (3, b, 1)) if cfg.mrope else None
    q, k_new, v_new = _project_qkv(p, cfg, x, positions, mpos)

    cache_len = cache["k"].shape[1]
    slot = pos % cache_len  # ring-buffer slot (== pos when cache covers ctx)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))
    k = constrain(k, "batch", "cache_seq", "kv_heads", "head_dim")
    v = constrain(v, "batch", "cache_seq", "kv_heads", "head_dim")
    valid = jnp.minimum(pos + 1, cache_len)
    out = sdpa(q, k, v, causal=False, kv_valid_len=valid)
    out = out.reshape(b, 1, -1)
    return jnp.einsum("bsf,fd->bsd", out, p["wo"]), {"k": k, "v": v}


# --------------------------------------------------------------------------- #
# MLA (deepseek-v2)
# --------------------------------------------------------------------------- #
def mla_forward(
    p: Dict,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    mrope_positions=None,
    causal: bool = True,
) -> jax.Array:
    b, s, d = x.shape
    h = cfg.num_heads
    qn, qr, vd, r = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank

    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])  # (B,S,H,qn+qr)
    q_nope, q_rope = q[..., :qn], q[..., qn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = jnp.einsum("bsd,de->bse", x, p["wkv_a"])  # (B,S,r+qr)
    ckv = rms_norm(kv_a[..., :r], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(kv_a[..., None, r:], positions, cfg.rope_theta)  # (B,S,1,qr)

    kv_up = jnp.einsum("bsr,rhe->bshe", ckv, p["wkv_b"])  # (B,S,H,qn+vd)
    k_nope, v = kv_up[..., :qn], kv_up[..., qn:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, h, qr))], axis=-1
    )
    qq = jnp.concatenate([q_nope, q_rope], axis=-1)
    qq = constrain(qq, "batch", "seq", "heads", "head_dim")
    out = sdpa(qq, k, v, causal=causal)
    return jnp.einsum("bsf,fd->bsd", out.reshape(b, s, h * vd), p["wo"])


def init_mla_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype) -> Dict:
    """MLA's memory win: the cache holds the r-dim latent + rope key, NOT
    per-head K/V — (r + qr) vs 2*H*hd floats per token (9x smaller here)."""
    return {
        "ckv": jnp.zeros((batch, cache_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, cache_len, cfg.qk_rope_dim), dtype),
    }


def mla_decode_step(
    p: Dict,
    cfg: ModelConfig,
    x: jax.Array,   # (B, 1, D)
    cache: Dict,
    pos: jax.Array,
) -> Tuple[jax.Array, Dict]:
    b = x.shape[0]
    h = cfg.num_heads
    qn, qr, vd, r = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    positions = jnp.broadcast_to(pos, (b, 1))

    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    q_nope, q_rope = q[..., :qn], q[..., qn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = jnp.einsum("bsd,de->bse", x, p["wkv_a"])
    ckv_new = rms_norm(kv_a[..., :r], p["kv_norm"], cfg.norm_eps)
    k_rope_new = apply_rope(kv_a[..., None, r:], positions, cfg.rope_theta)[:, :, 0]

    cache_len = cache["ckv"].shape[1]
    slot = pos % cache_len
    ckv = jax.lax.dynamic_update_slice(cache["ckv"], ckv_new, (0, slot, 0))
    k_rope = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope_new, (0, slot, 0))
    ckv = constrain(ckv, "batch", "cache_seq", None)
    valid = jnp.minimum(pos + 1, cache_len)

    # Absorbed attention: score = q_nope^T (W_b^K ckv_t) + q_rope^T k_rope_t.
    # The whole score path runs in f32: the forward pass casts q/k to f32
    # before its logits einsum (see sdpa), and letting the absorbed
    # intermediates round to bf16 loses prefill parity (~1% of logits move
    # past rtol=0.05 through the softmax).
    wkb_k = p["wkv_b"][..., :qn].astype(jnp.float32)  # (r, H, qn)
    q_latent = jnp.einsum(
        "bshe,rhe->bshr", q_nope.astype(jnp.float32), wkb_k
    )  # (B,1,H,r)
    logits = jnp.einsum("bshr,btr->bhst", q_latent, ckv.astype(jnp.float32))
    logits = logits + jnp.einsum(
        "bshe,bte->bhst", q_rope.astype(jnp.float32), k_rope.astype(jnp.float32)
    )
    scale = 1.0 / ((qn + qr) ** 0.5)
    logits = logits * scale
    mask = jnp.arange(cache_len)[None, None, None, :] < valid
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    # out = probs @ V where V = W_b^V ckv  (absorbed: latent first)
    lat = jnp.einsum("bhst,btr->bshr", probs, ckv.astype(jnp.float32))
    wkb_v = p["wkv_b"][..., qn:]  # (r, H, vd)
    out = jnp.einsum("bshr,rhe->bshe", lat, wkb_v.astype(jnp.float32))
    out = out.astype(x.dtype).reshape(b, 1, h * vd)
    return (
        jnp.einsum("bsf,fd->bsd", out, p["wo"]),
        {"ckv": ckv, "k_rope": k_rope},
    )
