"""Generic decoder-only model covering dense / MoE / VLM / SSM / hybrid.

One parameterised implementation: per-layer params are stacked on a
leading L axis and the layer body is ``lax.scan``-ed with ``jax.checkpoint``
(remat) so deep models (96L nemotron) lower as a single layer program.

Batch dict keys:
  tokens            (B, S) int32            — always
  image_embeds      (B, P, D)               — vlm frontend stub (prepended)
  mrope_positions   (3, B, S_total) int32   — optional (vlm)

Decode caches: ``{"layers": stacked-per-layer cache, "shared": ...}``; the
cache length is the serving context (ring-buffer for sliding-window archs).
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch.pspec import constrain
from repro.models import attention as attn_lib
from repro.models import mlp as mlp_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import dtype_of, embed_init, dense_init, rms_norm
from repro.models.scan_util import remat_policy, scan_layers


# --------------------------------------------------------------------------- #
# Init
# --------------------------------------------------------------------------- #
def _layer_init(rng, cfg: ModelConfig, dtype) -> Dict:
    ks = jax.random.split(rng, 4)
    if cfg.arch_type in ("ssm", "hybrid"):
        return {
            "norm1": jnp.zeros((cfg.d_model,), dtype),
            "mamba": ssm_lib.init_mamba2(ks[0], cfg, dtype),
        }
    p = {
        "norm1": jnp.zeros((cfg.d_model,), dtype),
        "norm2": jnp.zeros((cfg.d_model,), dtype),
        "attn": (
            attn_lib.init_mla(ks[0], cfg, dtype)
            if cfg.use_mla
            else attn_lib.init_gqa(ks[0], cfg, dtype)
        ),
    }
    if cfg.num_experts:
        p["moe"] = mlp_lib.init_moe(ks[1], cfg, dtype)
    else:
        p["ffn"] = mlp_lib.init_ffn(ks[1], cfg, cfg.d_ff, dtype)
    return p


def _shared_block_init(rng, cfg: ModelConfig, dtype) -> Dict:
    """Zamba2's weight-shared attention+MLP block (consumes concat(x, x0))."""
    ks = jax.random.split(rng, 4)
    return {
        "in_proj": dense_init(ks[0], 2 * cfg.d_model, cfg.d_model, dtype),
        "norm1": jnp.zeros((2 * cfg.d_model,), dtype),
        "attn": attn_lib.init_gqa(ks[1], cfg, dtype),
        "norm2": jnp.zeros((cfg.d_model,), dtype),
        "ffn": mlp_lib.init_ffn(ks[2], cfg, cfg.d_ff, dtype),
    }


def init(rng, cfg: ModelConfig) -> Dict:
    dtype = dtype_of(cfg.dtype)
    ks = jax.random.split(rng, 4)
    layer_keys = jax.random.split(ks[0], cfg.num_layers)
    layers = jax.vmap(lambda k: _layer_init(k, cfg, dtype))(layer_keys)
    params = {
        "embed": embed_init(ks[1], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
        "layers": layers,
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[2], cfg.d_model, cfg.vocab_size, dtype)
    if cfg.arch_type == "hybrid" and cfg.hybrid_attn_every:
        params["shared_attn"] = _shared_block_init(ks[3], cfg, dtype)
    return params


# --------------------------------------------------------------------------- #
# Layer bodies
# --------------------------------------------------------------------------- #
def _attn_layer(p, cfg: ModelConfig, x, positions, mrope_positions):
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if cfg.use_mla:
        a = attn_lib.mla_forward(p["attn"], cfg, h, positions, mrope_positions)
    else:
        a = attn_lib.gqa_forward(p["attn"], cfg, h, positions, mrope_positions)
    x = x + a
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.num_experts:
        import os

        from repro.launch.pspec import current_rules

        if os.environ.get("REPRO_MOE_SHARDMAP") == "1" and current_rules() is not None:
            f, aux = mlp_lib.moe_ffn_sharded(p["moe"], cfg, h)
        else:
            f, aux = mlp_lib.moe_ffn(p["moe"], cfg, h)
    else:
        f = mlp_lib.ffn(p["ffn"], cfg, h)
    return x + f, aux


def _ssm_layer(p, cfg: ModelConfig, x):
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    return x + ssm_lib.mamba2_forward(p["mamba"], cfg, h)


def _shared_block(p, cfg: ModelConfig, x, x0, positions):
    h = rms_norm(jnp.concatenate([x, x0], axis=-1), p["norm1"], cfg.norm_eps)
    h = jnp.einsum("bsd,de->bse", h, p["in_proj"])
    a = attn_lib.gqa_forward(p["attn"], cfg, h, positions)
    x = x + a
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    return x + mlp_lib.ffn(p["ffn"], cfg, h)


# --------------------------------------------------------------------------- #
# Forward (train / full-sequence)
# --------------------------------------------------------------------------- #
def embed_inputs(params, cfg: ModelConfig, batch) -> Tuple[jax.Array, jax.Array, Optional[jax.Array]]:
    tokens = batch["tokens"]
    x = params["embed"][tokens]
    if cfg.frontend == "vision" and "image_embeds" in batch:
        x = jnp.concatenate([batch["image_embeds"].astype(x.dtype), x], axis=1)
    b, s = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    mrope_positions = batch.get("mrope_positions")
    if cfg.mrope and mrope_positions is None:
        mrope_positions = jnp.broadcast_to(positions[None], (3, b, s))
    return x, positions, mrope_positions


def forward(params, cfg: ModelConfig, batch) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits (B, S_total, V), aux_loss scalar)."""
    x, positions, mrope_positions = embed_inputs(params, cfg, batch)
    x = constrain(x, "batch", "seq", "embed")

    if cfg.arch_type in ("ssm", "hybrid"):
        x = _forward_ssm_stack(params, cfg, x, positions)
        aux = jnp.zeros((), jnp.float32)
    else:
        def body2(carry, layer_p):
            y, aux_l = _attn_layer(layer_p, cfg, carry, positions, mrope_positions)
            return y, aux_l

        x, auxes = scan_layers(
            jax.checkpoint(body2, policy=remat_policy()),
            x,
            params["layers"],
        )
        aux = auxes.sum()

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    logits = constrain(logits, "batch", "seq", "vocab")
    return logits, aux


def _forward_ssm_stack(params, cfg: ModelConfig, x, positions):
    body = jax.checkpoint(
        lambda carry, layer_p: (_ssm_layer(layer_p, cfg, carry), None),
        policy=remat_policy(),
    )
    if cfg.arch_type == "ssm" or not cfg.hybrid_attn_every:
        x, _ = scan_layers(body, x, params["layers"])
        return x
    # hybrid: groups of `hybrid_attn_every` ssm layers + one SHARED block
    x0 = x
    per = cfg.hybrid_attn_every
    groups = cfg.num_layers // per
    layers = params["layers"]
    for g in range(groups):
        group_p = jax.tree.map(lambda a: a[g * per : (g + 1) * per], layers)
        x, _ = scan_layers(body, x, group_p)
        x = _shared_block(params["shared_attn"], cfg, x, x0, positions)
    rem = cfg.num_layers - groups * per
    if rem:
        tail_p = jax.tree.map(lambda a: a[groups * per :], layers)
        x, _ = scan_layers(body, x, tail_p)
    return x


# --------------------------------------------------------------------------- #
# Decode
# --------------------------------------------------------------------------- #
def init_cache(cfg: ModelConfig, batch_size: int, cache_len: int) -> Dict:
    """cache_len: serving context (for sliding-window archs pass the window)."""
    dtype = dtype_of(cfg.dtype)
    l = cfg.num_layers

    def stack(tree):
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (l, *a.shape)), tree)

    if cfg.arch_type in ("ssm", "hybrid"):
        layer_cache = stack(ssm_lib.init_mamba2_cache(cfg, batch_size, dtype))
        cache = {"layers": layer_cache}
        if cfg.arch_type == "hybrid" and cfg.hybrid_attn_every:
            groups = cfg.num_layers // cfg.hybrid_attn_every
            shared = attn_lib.init_gqa_cache(cfg, batch_size, cache_len, dtype)
            cache["shared"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (groups, *a.shape)), shared
            )
        return cache
    if cfg.use_mla:
        base = attn_lib.init_mla_cache(cfg, batch_size, cache_len, dtype)
    else:
        base = attn_lib.init_gqa_cache(cfg, batch_size, cache_len, dtype)
    return {"layers": stack(base)}


def decode_step(
    params, cfg: ModelConfig, batch, cache: Dict, pos: jax.Array
) -> Tuple[jax.Array, Dict]:
    """One new token for every sequence.  batch: {"tokens": (B, 1)}.

    ``pos`` is the absolute position (cache slot = pos % cache_len for
    sliding-window ring buffers)."""
    tokens = batch["tokens"]
    x = params["embed"][tokens]  # (B, 1, D)
    x = constrain(x, "batch", None, "embed")

    if cfg.arch_type in ("ssm", "hybrid"):
        x, new_cache = _decode_ssm_stack(params, cfg, x, cache, pos)
    else:
        def body(carry, xs):
            layer_p, layer_c = xs
            h = rms_norm(carry, layer_p["norm1"], cfg.norm_eps)
            if cfg.use_mla:
                a, new_c = attn_lib.mla_decode_step(layer_p["attn"], cfg, h, layer_c, pos)
            else:
                a, new_c = attn_lib.gqa_decode_step(layer_p["attn"], cfg, h, layer_c, pos)
            y = carry + a
            h = rms_norm(y, layer_p["norm2"], cfg.norm_eps)
            if cfg.num_experts:
                f, _ = mlp_lib.moe_ffn(layer_p["moe"], cfg, h)
            else:
                f = mlp_lib.ffn(layer_p["ffn"], cfg, h)
            return y + f, new_c

        x, new_layers = scan_layers(body, x, (params["layers"], cache["layers"]))
        new_cache = {"layers": new_layers}

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return logits, new_cache


def _decode_ssm_stack(params, cfg: ModelConfig, x, cache, pos):
    def body(carry, xs):
        layer_p, layer_c = xs
        h = rms_norm(carry, layer_p["norm1"], cfg.norm_eps)
        out, new_c = ssm_lib.mamba2_decode_step(layer_p["mamba"], cfg, h, layer_c, pos)
        return carry + out, new_c

    if cfg.arch_type == "ssm" or not cfg.hybrid_attn_every:
        x, new_layers = scan_layers(body, x, (params["layers"], cache["layers"]))
        return x, {"layers": new_layers}

    x0 = x
    per = cfg.hybrid_attn_every
    groups = cfg.num_layers // per
    layers, layer_caches = params["layers"], cache["layers"]
    new_layer_caches = []
    new_shared = []
    b = x.shape[0]
    positions = None
    for g in range(groups):
        gp = jax.tree.map(lambda a: a[g * per : (g + 1) * per], layers)
        gc = jax.tree.map(lambda a: a[g * per : (g + 1) * per], layer_caches)
        x, nc = scan_layers(body, x, (gp, gc))
        new_layer_caches.append(nc)
        # shared attention block with its g-th cache
        sp = params["shared_attn"]
        sc = jax.tree.map(lambda a: a[g], cache["shared"])
        h = rms_norm(jnp.concatenate([x, x0], axis=-1), sp["norm1"], cfg.norm_eps)
        h = jnp.einsum("bsd,de->bse", h, sp["in_proj"])
        a_out, nsc = attn_lib.gqa_decode_step(sp["attn"], cfg, h, sc, pos)
        x = x + a_out
        h = rms_norm(x, sp["norm2"], cfg.norm_eps)
        x = x + mlp_lib.ffn(sp["ffn"], cfg, h)
        new_shared.append(nsc)
    new_cache = {
        "layers": jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *new_layer_caches),
        "shared": jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *new_shared),
    }
    return x, new_cache
