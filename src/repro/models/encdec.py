"""Encoder-decoder transformer (SeamlessM4T backbone).

Encoder: bidirectional self-attention over (stubbed) audio-frame
embeddings.  Decoder: causal self-attention + cross-attention to the
encoder output, standard teacher-forced training.

Batch dict:
  audio_frames (B, F, D)   — frontend stub output (encoder input)
  tokens       (B, S) int  — decoder input (targets shifted by caller)

Decode cache: per-decoder-layer self-attn KV ring + precomputed
cross-attention K/V over the encoder output (computed once at prefill; the
dry-run treats it as part of the cache input).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch.pspec import constrain
from repro.models import attention as attn_lib
from repro.models import mlp as mlp_lib
from repro.models.layers import dtype_of, embed_init, dense_init, rms_norm
from repro.models.scan_util import remat_policy, scan_layers


def _enc_layer_init(rng, cfg, dtype):
    ks = jax.random.split(rng, 2)
    return {
        "norm1": jnp.zeros((cfg.d_model,), dtype),
        "attn": attn_lib.init_gqa(ks[0], cfg, dtype),
        "norm2": jnp.zeros((cfg.d_model,), dtype),
        "ffn": mlp_lib.init_ffn(ks[1], cfg, cfg.d_ff, dtype),
    }


def _dec_layer_init(rng, cfg, dtype):
    ks = jax.random.split(rng, 3)
    return {
        "norm1": jnp.zeros((cfg.d_model,), dtype),
        "self_attn": attn_lib.init_gqa(ks[0], cfg, dtype),
        "norm_x": jnp.zeros((cfg.d_model,), dtype),
        "cross_attn": attn_lib.init_gqa(ks[1], cfg, dtype),
        "norm2": jnp.zeros((cfg.d_model,), dtype),
        "ffn": mlp_lib.init_ffn(ks[2], cfg, cfg.d_ff, dtype),
    }


def init(rng, cfg: ModelConfig) -> Dict:
    dtype = dtype_of(cfg.dtype)
    ks = jax.random.split(rng, 5)
    enc_keys = jax.random.split(ks[0], cfg.encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.num_layers)
    return {
        "embed": embed_init(ks[2], cfg.vocab_size, cfg.d_model, dtype),
        "enc_layers": jax.vmap(lambda k: _enc_layer_init(k, cfg, dtype))(enc_keys),
        "dec_layers": jax.vmap(lambda k: _dec_layer_init(k, cfg, dtype))(dec_keys),
        "enc_norm": jnp.zeros((cfg.d_model,), dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
        "lm_head": dense_init(ks[3], cfg.d_model, cfg.vocab_size, dtype),
    }


# --------------------------------------------------------------------------- #
def encode(params, cfg: ModelConfig, audio_frames: jax.Array) -> jax.Array:
    x = audio_frames.astype(dtype_of(cfg.dtype))
    x = constrain(x, "batch", "seq", "embed")
    b, f = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(f)[None], (b, f))

    def body(carry, layer_p):
        h = rms_norm(carry, layer_p["norm1"], cfg.norm_eps)
        a = attn_lib.gqa_forward(layer_p["attn"], cfg, h, positions, causal=False)
        y = carry + a
        h = rms_norm(y, layer_p["norm2"], cfg.norm_eps)
        return y + mlp_lib.ffn(layer_p["ffn"], cfg, h), None

    x, _ = scan_layers(
        jax.checkpoint(body, policy=remat_policy()),
        x,
        params["enc_layers"],
    )
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_attention(p, cfg, h, enc_out):
    """Cross-attention: queries from decoder, K/V from encoder output."""
    q = jnp.einsum("bsd,dhe->bshe", h, p["wq"])
    k = jnp.einsum("bfd,dke->bfke", enc_out, p["wk"])
    v = jnp.einsum("bfd,dke->bfke", enc_out, p["wv"])
    out = attn_lib.sdpa(q, k, v, causal=False)
    out = out.reshape(*h.shape[:2], -1)
    return jnp.einsum("bsf,fd->bsd", out, p["wo"])


def forward(params, cfg: ModelConfig, batch) -> Tuple[jax.Array, jax.Array]:
    enc_out = encode(params, cfg, batch["audio_frames"])
    tokens = batch["tokens"]
    x = params["embed"][tokens]
    x = constrain(x, "batch", "seq", "embed")
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(carry, layer_p):
        h = rms_norm(carry, layer_p["norm1"], cfg.norm_eps)
        a = attn_lib.gqa_forward(layer_p["self_attn"], cfg, h, positions)
        y = carry + a
        h = rms_norm(y, layer_p["norm_x"], cfg.norm_eps)
        y = y + _cross_attention(layer_p["cross_attn"], cfg, h, enc_out)
        h = rms_norm(y, layer_p["norm2"], cfg.norm_eps)
        return y + mlp_lib.ffn(layer_p["ffn"], cfg, h), None

    x, _ = scan_layers(
        jax.checkpoint(body, policy=remat_policy()),
        x,
        params["dec_layers"],
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    logits = constrain(logits, "batch", "seq", "vocab")
    return logits, jnp.zeros((), jnp.float32)


# --------------------------------------------------------------------------- #
# Decode
# --------------------------------------------------------------------------- #
def init_cache(cfg: ModelConfig, batch_size: int, cache_len: int) -> Dict:
    dtype = dtype_of(cfg.dtype)
    l = cfg.num_layers
    kv, hd, f = cfg.num_kv_heads, cfg.head_dim, cfg.frontend_len

    def stack(a):
        return jnp.broadcast_to(a, (l, *a.shape))

    self_c = attn_lib.init_gqa_cache(cfg, batch_size, cache_len, dtype)
    return {
        "layers": jax.tree.map(stack, self_c),
        # precomputed cross K/V over the encoder output (prefill artifact)
        "cross_k": jnp.zeros((l, batch_size, f, kv, hd), dtype),
        "cross_v": jnp.zeros((l, batch_size, f, kv, hd), dtype),
    }


def prefill_cross(params, cfg: ModelConfig, enc_out: jax.Array):
    """Compute per-layer cross-attention K/V once from the encoder output."""
    def per_layer(layer_p):
        k = jnp.einsum("bfd,dke->bfke", enc_out, layer_p["cross_attn"]["wk"])
        v = jnp.einsum("bfd,dke->bfke", enc_out, layer_p["cross_attn"]["wv"])
        return k, v

    ks, vs = jax.vmap(per_layer)(params["dec_layers"])
    return ks, vs


def decode_step(
    params, cfg: ModelConfig, batch, cache: Dict, pos: jax.Array
) -> Tuple[jax.Array, Dict]:
    tokens = batch["tokens"]  # (B, 1)
    x = params["embed"][tokens]
    b = x.shape[0]

    def body(carry, xs):
        layer_p, layer_c, ck, cv = xs
        h = rms_norm(carry, layer_p["norm1"], cfg.norm_eps)
        a, new_c = attn_lib.gqa_decode_step(layer_p["self_attn"], cfg, h, layer_c, pos)
        y = carry + a
        h = rms_norm(y, layer_p["norm_x"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhe->bshe", h, layer_p["cross_attn"]["wq"])
        co = attn_lib.sdpa(q, ck, cv, causal=False)
        co = co.reshape(b, 1, -1)
        y = y + jnp.einsum("bsf,fd->bsd", co, layer_p["cross_attn"]["wo"])
        h = rms_norm(y, layer_p["norm2"], cfg.norm_eps)
        return y + mlp_lib.ffn(layer_p["ffn"], cfg, h), new_c

    x, new_layers = scan_layers(
        body,
        x,
        (params["dec_layers"], cache["layers"], cache["cross_k"], cache["cross_v"]),
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    new_cache = dict(cache)
    new_cache["layers"] = new_layers
    return logits, new_cache
