"""Scan helpers with env-controlled unroll (dry-run cost accounting).

XLA's ``cost_analysis`` counts a while-loop body ONCE, not trip-count
times (verified empirically — see EXPERIMENTS.md §Roofline "methodology").
The dry-run therefore compiles each program twice: once normally and once
with the layer/microbatch scans partially unrolled via these knobs; the
difference isolates the per-body cost, which is then multiplied by the
known static trip counts.  Env knobs (read at TRACE time):

    REPRO_UNROLL_LAYERS=<u>   unroll factor for scan-over-layers
    REPRO_UNROLL_MB=<u>       unroll factor for the microbatch grad-accum scan
"""

from __future__ import annotations

import os

import jax


def _env_unroll(name: str) -> int:
    return max(1, int(os.environ.get(name, "1")))


def remat_policy():
    """Remat policy knob (perf iteration H3, EXPERIMENTS.md §Perf).

    REPRO_REMAT_POLICY = "nothing" (baseline: recompute everything) |
    "dots" (save dot/matmul outputs — cheaper backward at higher live
    memory).
    """
    name = os.environ.get("REPRO_REMAT_POLICY", "nothing")
    if name == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable


def scan_layers(body, init, xs):
    return jax.lax.scan(body, init, xs, unroll=_env_unroll("REPRO_UNROLL_LAYERS"))


def scan_microbatches(body, init, xs):
    return jax.lax.scan(body, init, xs, unroll=_env_unroll("REPRO_UNROLL_MB"))
