"""Workload substrate: the 10 assigned architectures in pure JAX.

``get_model(cfg)`` returns a functional model namespace with

* ``init(rng, cfg)``                       -> params pytree
* ``forward(params, cfg, batch)``          -> logits (training forward)
* ``init_cache(cfg, batch, cache_len)``    -> decode cache pytree
* ``decode_step(params, cfg, batch, cache, pos)`` -> (logits, new cache)

Params are plain nested dicts of jnp arrays (no framework dependency);
layers are stacked on a leading L axis and scanned with ``jax.lax.scan``
(+remat) so a 96-layer model lowers as one layer body.
"""

from repro.configs.base import ModelConfig
from repro.models import encdec, transformer


def get_model(cfg: ModelConfig):
    if cfg.is_encoder_decoder:
        return encdec
    return transformer
