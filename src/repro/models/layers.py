"""Shared primitive layers: norms, init helpers, rotary embeddings (+M-RoPE)."""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}[
        name
    ]


# --------------------------------------------------------------------------- #
# Init helpers
# --------------------------------------------------------------------------- #
def dense_init(rng, in_dim: int, out_shape, dtype) -> jax.Array:
    """Truncated-normal fan-in init, shape (in_dim, *out_shape)."""
    if isinstance(out_shape, int):
        out_shape = (out_shape,)
    scale = 1.0 / np.sqrt(in_dim)
    return (
        jax.random.truncated_normal(rng, -2.0, 2.0, (in_dim, *out_shape)) * scale
    ).astype(dtype)


def embed_init(rng, vocab: int, dim: int, dtype) -> jax.Array:
    return (jax.random.normal(rng, (vocab, dim)) * 0.02).astype(dtype)


# --------------------------------------------------------------------------- #
# Norms
# --------------------------------------------------------------------------- #
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale) + bias).astype(x.dtype)


# --------------------------------------------------------------------------- #
# Rotary embeddings
# --------------------------------------------------------------------------- #
def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))


def apply_rope(
    x: jax.Array,  # (B, S, H, D)
    positions: jax.Array,  # (B, S) int
    theta: float,
) -> jax.Array:
    d = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(d, theta))  # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, d/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def mrope_sections(head_dim: int) -> Tuple[int, int, int]:
    """Split of the half-dim rotary channels across (t, h, w) position
    streams; Qwen2-VL uses (16, 24, 24) for head_dim=128."""
    half = head_dim // 2
    a = half // 3
    return (half - 2 * a, a, a)


def apply_mrope(
    x: jax.Array,          # (B, S, H, D)
    positions: jax.Array,  # (3, B, S) int — temporal / height / width
    theta: float,
) -> jax.Array:
    """Qwen2-VL multimodal rotary: rotary channel groups are driven by
    different position streams (text tokens use identical streams)."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(d, theta))  # (d/2,)
    secs = mrope_sections(d)
    # pick the position stream per rotary channel
    stream_of = np.concatenate(
        [np.full(s, i, dtype=np.int32) for i, s in enumerate(secs)]
    )  # (d/2,)
    pos = positions.astype(jnp.float32)  # (3, B, S)
    pos_per_chan = pos[stream_of]  # (d/2, B, S)
    angles = jnp.moveaxis(pos_per_chan, 0, -1) * freqs  # (B, S, d/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
