"""Mamba-2 SSD (state-space duality) block [arXiv:2405.21060].

Training/prefill uses the chunked SSD algorithm: within a chunk of Q
tokens the recurrence is materialised as a masked (Q x Q) matmul (the
"attention-like" dual form, MXU-friendly); across chunks the (H, P, N)
states follow a linear recurrence evaluated with ``lax.scan``.  Decode is
the pure recurrence: O(1) state update per token — this is why the SSM and
hybrid architectures run the ``long_500k`` shape natively.

Shapes: d_inner = expand*d_model, H = d_inner/head_dim heads, state N,
single B/C group (G=1).  A short depthwise conv (width 4) precedes the SSM
on the x/B/C channels, as in the reference implementation.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch.pspec import constrain
from repro.models.layers import dense_init, rms_norm


def init_mamba2(rng, cfg: ModelConfig, dtype) -> Dict:
    d = cfg.d_model
    di = cfg.ssm_d_inner
    n = cfg.ssm_state
    h = cfg.ssm_heads
    conv_ch = di + 2 * n
    ks = jax.random.split(rng, 6)
    return {
        # fused input projection -> [z (di), x (di), B (n), C (n), dt (h)]
        "in_proj": dense_init(ks[0], d, 2 * di + 2 * n + h, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv_width, conv_ch)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.log(
            jnp.linspace(1.0, 16.0, h)
        ).astype(jnp.float32),  # A = -exp(a_log)
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((h,), 0.01))).astype(jnp.float32),
        "norm": jnp.zeros((di,), dtype),
        "out_proj": dense_init(ks[2], di, d, dtype),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    di, n, h = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    x = zxbcdt[..., di : 2 * di]
    b = zxbcdt[..., 2 * di : 2 * di + n]
    c = zxbcdt[..., 2 * di + n : 2 * di + 2 * n]
    dt = zxbcdt[..., 2 * di + 2 * n :]
    return z, x, b, c, dt


def _conv(p: Dict, xbc: jax.Array) -> jax.Array:
    """Causal depthwise conv over seq: xbc (B, S, CH)."""
    w = p["conv_w"]  # (W, CH)
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * w[i][None, None, :] for i in range(width)
    )
    return jax.nn.silu((out + p["conv_b"]).astype(jnp.float32)).astype(xbc.dtype)


def mamba2_forward(p: Dict, cfg: ModelConfig, u: jax.Array) -> jax.Array:
    """u: (B, S, D) -> (B, S, D).  S must be a multiple of ssm_chunk."""
    bsz, s, _ = u.shape
    di, n, h, pd = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    q = cfg.ssm_chunk
    assert s % q == 0, f"seq {s} not a multiple of ssm_chunk {q}"
    nc = s // q

    zxbcdt = jnp.einsum("bsd,de->bse", u, p["in_proj"])
    z, x, b, c, dt = _split_proj(cfg, zxbcdt)
    xbc = _conv(p, jnp.concatenate([x, b, c], axis=-1))
    x, b, c = xbc[..., :di], xbc[..., di : di + n], xbc[..., di + n :]

    x = x.reshape(bsz, nc, q, h, pd)
    x = constrain(x, "batch", None, None, "heads", None)
    b = b.reshape(bsz, nc, q, n).astype(jnp.float32)
    c = c.reshape(bsz, nc, q, n).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"]).reshape(bsz, nc, q, h)
    a = -jnp.exp(p["a_log"])  # (H,)

    da = dt * a  # (B, NC, Q, H), negative
    cum = jnp.cumsum(da, axis=2)  # inclusive cumsum over chunk positions

    xf = x.astype(jnp.float32)
    # ---- intra-chunk (dual / attention-like form) ----------------------- #
    scores = jnp.einsum("bcin,bcjn->bcij", c, b)  # (B,NC,Q,Q)
    decay = jnp.exp(
        cum[:, :, :, None, :] - cum[:, :, None, :, :]
    )  # (B,NC,Q,Q,H): exp(cum_i - cum_j)
    tri = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(tri[None, None, :, :, None], decay, 0.0)
    y_intra = jnp.einsum(
        "bcij,bcijh,bcjh,bcjhp->bcihp", scores, decay, dt, xf
    )

    # ---- chunk states and inter-chunk recurrence ------------------------- #
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,NC,Q,H)
    chunk_state = jnp.einsum(
        "bcjh,bcjn,bcjhp->bchpn", decay_to_end * dt, b, xf
    )  # (B,NC,H,P,N)
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B,NC,H)

    def scan_fn(carry, inp):
        state_c, decay_c = inp  # (B,H,P,N), (B,H)
        out = carry  # state entering this chunk
        new = carry * decay_c[:, :, None, None] + state_c
        return new, out

    init = jnp.zeros((bsz, h, pd, n), jnp.float32)
    _, states_in = jax.lax.scan(
        scan_fn,
        init,
        (chunk_state.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)),
    )
    states_in = states_in.swapaxes(0, 1)  # (B,NC,H,P,N): state BEFORE chunk

    y_inter = jnp.einsum(
        "bcin,bcih,bchpn->bcihp", c, jnp.exp(cum), states_in
    )

    y = y_intra + y_inter + p["d_skip"][None, None, None, :, None] * xf
    y = y.reshape(bsz, s, di).astype(u.dtype)

    # gated RMSNorm then output projection (mamba2 ordering)
    zf = z.reshape(bsz, s, di)
    y = y * jax.nn.silu(zf.astype(jnp.float32)).astype(u.dtype)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    return jnp.einsum("bsd,de->bse", y, p["out_proj"])


# --------------------------------------------------------------------------- #
# Decode (recurrent form)
# --------------------------------------------------------------------------- #
def init_mamba2_cache(cfg: ModelConfig, batch: int, dtype) -> Dict:
    di, n = cfg.ssm_d_inner, cfg.ssm_state
    return {
        "state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, di + 2 * n), dtype),
    }


def mamba2_decode_step(
    p: Dict, cfg: ModelConfig, u: jax.Array, cache: Dict, pos: jax.Array
) -> Tuple[jax.Array, Dict]:
    """u: (B, 1, D); O(1) per-token state update."""
    bsz = u.shape[0]
    di, n, h, pd = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim

    zxbcdt = jnp.einsum("bsd,de->bse", u, p["in_proj"])[:, 0]
    z, x, b, c, dt = _split_proj(cfg, zxbcdt)
    xbc_new = jnp.concatenate([x, b, c], axis=-1)  # (B, CH)

    # conv ring: window = [conv_cache, new]
    window = jnp.concatenate([cache["conv"], xbc_new[:, None, :]], axis=1)
    w = p["conv_w"]
    conv_out = jnp.einsum("bwc,wc->bc", window, w) + p["conv_b"]
    xbc = jax.nn.silu(conv_out.astype(jnp.float32))
    x = xbc[:, :di].reshape(bsz, h, pd)
    b = xbc[:, di : di + n]
    c = xbc[:, di + n :]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, H)
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dt * a)  # (B, H)

    state = cache["state"] * da[:, :, None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dt, b, x
    )
    y = jnp.einsum("bn,bhpn->bhp", c, state) + p["d_skip"][None, :, None] * x
    y = y.reshape(bsz, 1, di).astype(u.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(u.dtype)[:, None, :]
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"])
    new_cache = {"state": state, "conv": window[:, 1:, :]}
    return out, new_cache
