"""Feed-forward variants (SwiGLU / squared-ReLU / GeLU) and MoE.

The MoE layer implements capacity-based token dispatch: tokens pick top-k
experts; positions within each expert's buffer come from a one-hot cumsum;
overflow beyond ``capacity = ceil(T*k/E * cf)`` is dropped (standard
Switch/GShard semantics).  Expert compute is a single batched einsum over
the (E, C, D) buffer so the expert axis shards cleanly over the mesh
"model" axis (expert parallelism) — the dispatch scatter/gather become
all-to-alls under pjit.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch.pspec import constrain
from repro.models.layers import dense_init


# --------------------------------------------------------------------------- #
# Dense FFN
# --------------------------------------------------------------------------- #
def init_ffn(rng, cfg: ModelConfig, d_ff: int, dtype) -> Dict:
    d = cfg.d_model
    ks = jax.random.split(rng, 3)
    if cfg.mlp_type == "swiglu":
        return {
            "w_gate": dense_init(ks[0], d, d_ff, dtype),
            "w_up": dense_init(ks[1], d, d_ff, dtype),
            "w_down": dense_init(ks[2], d_ff, d, dtype),
        }
    return {
        "w_up": dense_init(ks[0], d, d_ff, dtype),
        "w_down": dense_init(ks[1], d_ff, d, dtype),
    }


def ffn(p: Dict, cfg: ModelConfig, x: jax.Array, constrained: bool = True) -> jax.Array:
    if cfg.mlp_type == "swiglu":
        g = jnp.einsum("...d,df->...f", x, p["w_gate"])
        u = jnp.einsum("...d,df->...f", x, p["w_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        u = jnp.einsum("...d,df->...f", x, p["w_up"])
        if cfg.mlp_type == "squared_relu":  # nemotron-4
            r = jax.nn.relu(u.astype(jnp.float32))
            h = (r * r).astype(x.dtype)
        else:  # gelu
            h = jax.nn.gelu(u.astype(jnp.float32)).astype(x.dtype)
    if constrained:  # skipped inside shard_map (axes are manual there)
        h = constrain(h, *([None] * (h.ndim - 1)), "ff")
    return jnp.einsum("...f,fd->...d", h, p["w_down"])


# --------------------------------------------------------------------------- #
# MoE
# --------------------------------------------------------------------------- #
def init_moe(rng, cfg: ModelConfig, dtype) -> Dict:
    d, e, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(rng, 6)
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "w_gate": (jax.random.truncated_normal(ks[1], -2, 2, (e, d, ff)) / math.sqrt(d)).astype(dtype),
        "w_up": (jax.random.truncated_normal(ks[2], -2, 2, (e, d, ff)) / math.sqrt(d)).astype(dtype),
        "w_down": (jax.random.truncated_normal(ks[3], -2, 2, (e, ff, d)) / math.sqrt(ff)).astype(dtype),
    }
    if cfg.num_shared_experts:
        sh_ff = cfg.moe_d_ff * cfg.num_shared_experts
        p["shared"] = init_ffn(ks[4], cfg, sh_ff, dtype)
    return p


def moe_ffn_sharded(p: Dict, cfg: ModelConfig, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """shard_map expert-parallel MoE (EXPERIMENTS.md §Perf H1, iteration 3).

    pjit's SPMD partitioner lowers the dispatch scatter by replicating the
    full (T*k, D) token tensor over the model axis (observed: 6.4 GB f32
    all-gathers per layer).  Here the parallelism is explicit instead:

      * tokens stay data-sharded and (within a data shard) replicated over
        the model axis — so dispatch (router, top-k, prefix-sum, scatter)
        is 100% local;
      * each model shard slices ITS experts' buffer rows, all-gathers the
        fsdp-sharded expert weights (standard ZeRO-3), runs the expert
        einsum, and combines gated outputs for its experts only;
      * one bf16 psum over the model axis sums the partial combines —
        (T_local, D) bytes instead of gathering (T*k, D) in f32.

    Semantics match :func:`moe_ffn` up to capacity granularity (capacity is
    enforced per data shard here; tests pin exact equality on a 1-device
    mesh).
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    from repro.launch.pspec import current_rules

    rules = current_rules()
    mesh = rules.mesh
    dp = rules.dp_axes if len(rules.dp_axes) > 1 else rules.dp_axes[0]
    m_size = mesh.shape["model"]
    e = cfg.num_experts
    if e % m_size != 0:
        return moe_ffn(p, cfg, x)  # cannot slice experts evenly
    e_loc = e // m_size

    def local(x_loc, router, wg, wu, wd, shared):
        b_loc, s, d = x_loc.shape
        tl = b_loc * s
        k = cfg.num_experts_per_token
        xt = x_loc.reshape(tl, d)
        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

        token_frac = (
            jnp.zeros((e,), jnp.float32).at[expert_idx.reshape(-1)].add(1.0)
            / (tl * k)
        )
        aux_local = cfg.router_aux_coef * e * jnp.sum(token_frac * probs.mean(0))
        aux = jax.lax.pmean(aux_local, rules.dp_axes if isinstance(dp, tuple) else dp)

        capg = capacity_of(cfg, tl)
        flat_e = expert_idx.reshape(-1)  # (Tl*k,)
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
        pos = jnp.take_along_axis(
            jnp.cumsum(onehot, axis=0) - 1, flat_e[:, None], axis=1
        )[:, 0]
        keep = pos < capg

        # keep only choices routed to THIS model shard's experts
        m_idx = jax.lax.axis_index("model")
        mine = (flat_e >= m_idx * e_loc) & (flat_e < (m_idx + 1) * e_loc) & keep
        local_e = jnp.where(mine, flat_e - m_idx * e_loc, 0)
        safe_pos = jnp.where(mine, pos, capg - 1)
        src = jnp.repeat(xt, k, axis=0)
        buf = jnp.zeros((e_loc, capg, d), x_loc.dtype)
        buf = buf.at[local_e, safe_pos].add(
            jnp.where(mine[:, None], src, 0), mode="drop"
        )

        # ZeRO-3: gather the fsdp-sharded expert weights for this layer
        def gather_fsdp(w):
            for ax in (rules.dp_axes if isinstance(dp, tuple) else (dp,)):
                w = jax.lax.all_gather(w, ax, axis=1, tiled=True)
            return w

        wg_f, wu_f, wd_f = gather_fsdp(wg), gather_fsdp(wu), wd
        for ax in (rules.dp_axes if isinstance(dp, tuple) else (dp,)):
            wd_f = jax.lax.all_gather(wd_f, ax, axis=2, tiled=True)

        if cfg.mlp_type == "swiglu":
            g = jnp.einsum("ecd,edf->ecf", buf, wg_f)
            u = jnp.einsum("ecd,edf->ecf", buf, wu_f)
            h = jax.nn.silu(g.astype(jnp.float32)).astype(x_loc.dtype) * u
        else:
            u = jnp.einsum("ecd,edf->ecf", buf, wu_f)
            h = jax.nn.gelu(u.astype(jnp.float32)).astype(x_loc.dtype)
        out_buf = jnp.einsum("ecf,efd->ecd", h, wd_f)

        gathered = out_buf[local_e, safe_pos]
        gathered = jnp.where(mine[:, None], gathered, 0)
        partial = (
            (gathered * gate_vals.reshape(-1)[:, None].astype(x_loc.dtype))
            .reshape(tl, k, d)
            .sum(axis=1)
        )
        out = jax.lax.psum(partial, "model")  # combine across expert shards
        if cfg.num_shared_experts:
            out = out + ffn(shared, cfg, xt, constrained=False)
        return out.reshape(b_loc, s, d), aux

    b, s, d = x.shape
    # match the actual (expert->model, fsdp->data) weight shardings
    in_specs = (
        P(dp, None, None),
        P(None, None),
        P("model", dp, None),
        P("model", dp, None),
        P("model", None, dp),
        P(),
    )
    shared = p.get("shared", {"w_up": jnp.zeros((0,)), "w_down": jnp.zeros((0,))})
    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(P(dp, None, None), P()),
        check_rep=False,
    )
    return fn(
        x,
        p["router"],
        p["w_gate"],
        p["w_up"],
        p["w_down"],
        shared,
    )


def capacity_of(cfg: ModelConfig, num_tokens: int) -> int:
    cap = int(
        math.ceil(num_tokens * cfg.num_experts_per_token / cfg.num_experts * cfg.capacity_factor)
    )
    return max(8, (cap + 7) // 8 * 8)


def moe_ffn(p: Dict, cfg: ModelConfig, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss)."""
    import os

    b, s, d = x.shape
    t = b * s
    e, k = cfg.num_experts, cfg.num_experts_per_token
    xt = x.reshape(t, d)

    if os.environ.get("REPRO_ABLATE_MOE") == "1":
        # profiling bisection knob: router only, zero expert compute
        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
        return jnp.zeros_like(x), 1e-9 * logits.sum()

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch): E * sum_e f_e * P_e
    token_frac = jnp.zeros((e,), jnp.float32).at[expert_idx.reshape(-1)].add(1.0) / (t * k)
    prob_frac = probs.mean(axis=0)
    aux = cfg.router_aux_coef * e * jnp.sum(token_frac * prob_frac)

    # ---- group-local dispatch (EXPERIMENTS.md §Perf H1) ----------------- #
    # Tokens are split into G groups aligned with the data shards; each
    # group computes buffer positions with a LOCAL prefix sum and scatters
    # into its own slice of the (E, G, C_g, D) buffers, so dispatch needs
    # no cross-device position exchange and the expert routing lowers to an
    # all-to-all.  G is installed by the launcher (REPRO_MOE_GROUPS = dp
    # size when the token count divides it; 1 on single-device runs).
    groups = int(os.environ.get("REPRO_MOE_GROUPS", "1"))
    if t % groups != 0:
        groups = 1
    tg = t // groups
    capg = capacity_of(cfg, tg)

    flat_e = expert_idx.reshape(groups, tg * k)  # group-major token order
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (G, Tg*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=1) - 1  # group-LOCAL prefix sum
    pos = jnp.take_along_axis(pos_in_e, flat_e[..., None], axis=2)[..., 0]
    keep = pos < capg
    safe_pos = jnp.where(keep, pos, capg - 1)

    # scatter tokens into (E, G*Cg, D) buffers at group-local slots
    src = jnp.repeat(xt.reshape(groups, tg, d), k, axis=1)  # (G, Tg*k, D)
    gates_flat = gate_vals.reshape(-1)
    gidx = jnp.arange(groups, dtype=jnp.int32)[:, None]
    slot = gidx * capg + safe_pos  # (G, Tg*k)
    buf = jnp.zeros((e, groups * capg, d), x.dtype)
    buf = buf.at[flat_e.reshape(-1), slot.reshape(-1)].add(
        jnp.where(keep.reshape(-1)[:, None], src.reshape(-1, d), 0),
        mode="drop",
    )
    buf = buf.reshape(e, groups, capg, d)
    buf = constrain(buf, "expert", "batch", None, "embed")

    # expert computation (batched over E; shards over the model axis)
    if cfg.mlp_type == "swiglu":
        g = jnp.einsum("egcd,edf->egcf", buf, p["w_gate"])
        u = jnp.einsum("egcd,edf->egcf", buf, p["w_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        u = jnp.einsum("egcd,edf->egcf", buf, p["w_up"])
        h = jax.nn.gelu(u.astype(jnp.float32)).astype(x.dtype)
    out_buf = jnp.einsum("egcf,efd->egcd", h, p["w_down"])
    out_buf = constrain(out_buf, "expert", "batch", None, "embed")

    # gather back with gates
    out_flat = out_buf.reshape(e, groups * capg, d)
    gathered = out_flat[flat_e.reshape(-1), slot.reshape(-1)]  # (T*k, D)
    gathered = jnp.where(keep.reshape(-1)[:, None], gathered, 0)
    out = (
        (gathered.astype(jnp.float32) * gates_flat[:, None])
        .reshape(t, k, d)
        .sum(axis=1)
        .astype(x.dtype)
    )

    if cfg.num_shared_experts:
        out = out + ffn(p["shared"], cfg, xt)
    return out.reshape(b, s, d), aux
