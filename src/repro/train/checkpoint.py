"""Checkpointing: flat-npz save/restore for params + optimizer state.

The migration overheads Tesserae minimises (Fig. 3) are exactly
checkpoint-save + checkpoint-load + warmup; this module is the substrate's
real implementation of that path (used by launch/train.py and the
examples).  Format: one ``.npz`` with dotted-path keys plus a tiny JSON
sidecar for step/metadata — dependency-free and portable.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p))
            for p in path
        )
        arr = np.asarray(leaf)
        if arr.dtype == np.dtype("bfloat16"):
            out[key + "::bf16"] = arr.astype(np.float32)
        else:
            out[key] = arr
    return out


def save_checkpoint(path: str, state: Any, step: int, metadata: Dict | None = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **_flatten(state))
    with open(path + ".meta.json", "w") as f:
        json.dump({"step": step, **(metadata or {})}, f)


def restore_checkpoint(path: str, state_template: Any) -> Tuple[Any, int]:
    """Restore into the structure of ``state_template`` (same treedef)."""
    import jax.numpy as jnp

    data = np.load(path if path.endswith(".npz") else path + ".npz")
    flat, treedef = jax.tree_util.tree_flatten_with_path(state_template)
    leaves = []
    for p, leaf in flat:
        key = "/".join(
            str(x.key) if hasattr(x, "key") else str(getattr(x, "idx", x)) for x in p
        )
        if key + "::bf16" in data:
            arr = jnp.asarray(data[key + "::bf16"], jnp.bfloat16)
        else:
            arr = jnp.asarray(data[key], leaf.dtype)
        if arr.shape != leaf.shape:
            raise ValueError(f"checkpoint leaf {key}: {arr.shape} != {leaf.shape}")
        leaves.append(arr)
    meta_path = path + ".meta.json"  # same rule as save_checkpoint
    step = 0
    if os.path.exists(meta_path):
        with open(meta_path) as f:
            step = json.load(f).get("step", 0)
    return jax.tree_util.tree_unflatten(treedef, [l for l in leaves]), step
