"""Training substrate: synthetic data pipeline, AdamW, train step, checkpointing."""

from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.train.step import TrainConfig, loss_fn, make_train_step, train_state_init

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "TrainConfig",
    "loss_fn",
    "make_train_step",
    "train_state_init",
]
