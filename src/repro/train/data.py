"""Deterministic synthetic data pipeline.

Token streams come from a seeded counter-based generator (threefry via
jax.random on host, or numpy for the pure-python iterator) so runs are
reproducible, shardable (each data shard derives its slice from the global
batch index), and free of filesystem dependencies.  A light Markov-ish
structure (token t+1 correlates with token t) makes the LM loss actually
decrease during the examples' training runs instead of plateauing at
log(V) immediately.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    batch_size: int
    seq_len: int
    seed: int = 0
    #: mixing weight of the structured (learnable) component
    structure: float = 0.75


class SyntheticTokens:
    """Iterator of {"tokens", "targets"} numpy batches."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # fixed random bigram table: next-token distribution per token (top-8)
        self._succ = rng.integers(
            0, cfg.vocab_size, size=(cfg.vocab_size, 8), dtype=np.int32
        )
        self._step = 0

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, self._step))
        self._step += 1
        b, s = cfg.batch_size, cfg.seq_len
        toks = np.empty((b, s + 1), dtype=np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab_size, size=b)
        structured = rng.random((b, s)) < cfg.structure
        picks = rng.integers(0, 8, size=(b, s))
        randoms = rng.integers(0, cfg.vocab_size, size=(b, s))
        for t in range(s):
            nxt = self._succ[toks[:, t], picks[:, t]]
            toks[:, t + 1] = np.where(structured[:, t], nxt, randoms[:, t])
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


def batch_for(
    cfg_vocab: int,
    batch_size: int,
    seq_len: int,
    seed: int = 0,
    step: int = 0,
    frontend: Optional[str] = None,
    frontend_len: int = 0,
    d_model: int = 0,
) -> Dict[str, np.ndarray]:
    """One batch including frontend stubs (vision patches / audio frames)."""
    it = SyntheticTokens(DataConfig(cfg_vocab, batch_size, seq_len, seed))
    it._step = step
    batch = dict(next(it))
    rng = np.random.default_rng((seed, step, 7))
    if frontend == "vision":
        batch["image_embeds"] = rng.normal(
            size=(batch_size, frontend_len, d_model)
        ).astype(np.float32) * 0.02
    elif frontend == "audio":
        batch["audio_frames"] = rng.normal(
            size=(batch_size, frontend_len, d_model)
        ).astype(np.float32) * 0.02
    return batch
