"""AdamW with a configurable moment-dtype policy (no external deps).

For the frontier-size dry-run configs the optimizer dtype policy is the
difference between fitting and OOM: bf16 moments cost 4 bytes/param of
state vs 8 for f32 (DESIGN.md §3; EXPERIMENTS.md records per-config
choices).  Stochastic-rounding caveats are out of scope for the dry-run —
we keep f32 as the default for real (reduced-size) training runs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    #: dtype for the m/v moments: "float32" | "bfloat16"
    moment_dtype: str = "float32"
    #: linear warmup steps then constant (cosine optional via schedule_fn)
    warmup_steps: int = 100


def _mdtype(cfg: AdamWConfig):
    return jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32


def adamw_init(cfg: AdamWConfig, params) -> Dict[str, Any]:
    md = _mdtype(cfg)
    zeros = lambda p: jnp.zeros_like(p, dtype=md)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.learning_rate * warm


def adamw_update(
    cfg: AdamWConfig, grads, params, opt_state
) -> Tuple[Any, Dict[str, Any]]:
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    md = _mdtype(cfg)

    def upd(g, p, m, v):
        gf = g.astype(jnp.float32)
        mf = m.astype(jnp.float32) * b1 + gf * (1 - b1)
        vf = v.astype(jnp.float32) * b2 + gf * gf * (1 - b2)
        mhat = mf / bc1
        vhat = vf / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, mf.astype(md), vf.astype(md)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(g, p, m, v) for g, p, m, v in zip(flat_g, flat_p, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "step": step}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves)
    )


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda l: (l.astype(jnp.float32) * scale).astype(l.dtype), tree), norm
