"""Train step: loss, grad accumulation (microbatching), remat, AdamW apply.

``make_train_step`` builds the jit-able function the launcher lowers for
the dry-run and the examples execute for real (reduced) training.  With
``microbatches > 1`` the global batch is split on the batch axis and
gradients accumulate through ``lax.scan`` — the standard activation-memory
lever for the frontier-size configs (see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.scan_util import scan_microbatches
from repro.models import get_model
from repro.train.optimizer import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    microbatches: int = 1
    grad_clip: float = 1.0
    #: cross-entropy z-loss coefficient (stabilises large-vocab logits)
    z_loss: float = 1e-4


def loss_fn(
    params, cfg: ModelConfig, batch: Dict[str, jax.Array], train_cfg: TrainConfig
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    model = get_model(cfg)
    logits, aux = model.forward(params, cfg, batch)
    targets = batch["targets"]
    # frontend positions (vision patches) carry no LM loss: logits for the
    # prepended P embeddings are sliced off.
    if logits.shape[1] != targets.shape[1]:
        logits = logits[:, logits.shape[1] - targets.shape[1] :]
    logits_f = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits_f, axis=-1)
    tgt_logit = jnp.take_along_axis(logits_f, targets[..., None], axis=-1)[..., 0]
    nll = (logz - tgt_logit).mean()
    zl = train_cfg.z_loss * (logz**2).mean()
    loss = nll + aux + zl
    return loss, {"nll": nll, "aux": aux, "z_loss": zl}


def train_state_init(rng, cfg: ModelConfig, train_cfg: TrainConfig):
    model = get_model(cfg)
    params = model.init(rng, cfg)
    opt = adamw_init(train_cfg.optimizer, params)
    return {"params": params, "opt": opt}


def make_train_step(
    cfg: ModelConfig, train_cfg: TrainConfig
) -> Callable[[Dict, Dict[str, jax.Array]], Tuple[Dict, Dict[str, jax.Array]]]:
    """Returns train_step(state, batch) -> (state, metrics)."""

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, cfg, batch, train_cfg
        )
        return loss, metrics, grads

    def train_step(state, batch):
        params, opt = state["params"], state["opt"]
        mb = train_cfg.microbatches
        if mb == 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            bsz = batch["tokens"].shape[0]

            def split(k, v):
                if k == "mrope_positions":  # (3, B, S): batch on axis 1
                    r = v.reshape(v.shape[0], mb, bsz // mb, *v.shape[2:])
                    return jnp.moveaxis(r, 1, 0)
                return v.reshape(mb, bsz // mb, *v.shape[1:])

            split_keys = [
                k
                for k, v in batch.items()
                if (v.shape[0] == bsz or k == "mrope_positions")
            ]
            static = {k: v for k, v in batch.items() if k not in split_keys}
            stacked = {k: split(k, batch[k]) for k in split_keys}

            def acc_fn(carry, mb_batch):
                full = dict(static)
                full.update(mb_batch)
                loss, metrics, grads = grads_of(params, full)
                acc_g, acc_l = carry
                acc_g = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), acc_g, grads
                )
                return (acc_g, acc_l + loss), metrics

            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (acc_g, acc_l), metrics_all = scan_microbatches(
                acc_fn, (zero_g, jnp.zeros((), jnp.float32)), stacked
            )
            grads = jax.tree.map(lambda g: g / mb, acc_g)
            loss = acc_l / mb
            metrics = jax.tree.map(lambda m: m[-1], metrics_all)

        grads, gnorm = clip_by_global_norm(grads, train_cfg.grad_clip)
        new_params, new_opt = adamw_update(train_cfg.optimizer, grads, params, opt)
        metrics = dict(metrics)
        metrics["loss"] = loss
        metrics["grad_norm"] = gnorm
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step
