"""Batched decode serving.

``make_serve_step`` builds the jit-able step the dry-run lowers for the
``decode_32k`` / ``long_500k`` shapes: ONE new token per sequence against a
cache of ``cache_len`` positions.  For sliding-window archs the cache is a
ring buffer of the window length; SSM archs carry O(1) recurrent state.

``greedy_generate`` (used by the serving example) loops decode steps with
greedy sampling on the host.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import get_model


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch_size: int
    #: logical context length the service promises
    context_len: int

    def cache_len(self, cfg: ModelConfig) -> int:
        """Physical cache length: full context, or the attention window for
        sliding-window archs (the sub-quadratic long_500k path)."""
        if cfg.arch_type in ("ssm",):
            return 1  # recurrent state only; no positional cache
        if cfg.attention_window and cfg.attention_window < self.context_len:
            return cfg.attention_window
        return self.context_len


def init_serving_cache(cfg: ModelConfig, serve_cfg: ServeConfig):
    model = get_model(cfg)
    return model.init_cache(cfg, serve_cfg.batch_size, serve_cfg.cache_len(cfg))


def make_serve_step(cfg: ModelConfig) -> Callable:
    """serve_step(params, tokens (B,1), cache, pos) -> (logits, new_cache)."""
    model = get_model(cfg)

    def serve_step(params, tokens, cache, pos):
        logits, new_cache = model.decode_step(
            params, cfg, {"tokens": tokens}, cache, pos
        )
        return logits, new_cache

    return serve_step


def greedy_generate(
    params,
    cfg: ModelConfig,
    prompt: jax.Array,  # (B, P) int32
    num_tokens: int,
    serve_cfg: ServeConfig,
) -> jax.Array:
    """Prefill by stepping the prompt, then greedy-decode num_tokens."""
    step = jax.jit(make_serve_step(cfg))
    cache = init_serving_cache(cfg, serve_cfg)
    b, p = prompt.shape
    tok = prompt[:, :1]
    out = [prompt]
    logits = None
    for i in range(p + num_tokens - 1):
        if i < p:
            tok = prompt[:, i : i + 1]
        logits, cache = step(params, tok, cache, jnp.asarray(i))
        if i >= p - 1:
            tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
            out.append(tok)
    return jnp.concatenate(out, axis=1)
