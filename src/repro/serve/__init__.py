"""Serving substrate: KV-cache decode steps and batched request serving."""

from repro.serve.engine import ServeConfig, make_serve_step, init_serving_cache

__all__ = ["ServeConfig", "make_serve_step", "init_serving_cache"]
