"""Test-support utilities shipped with the package.

Currently: :mod:`repro.testing.hypothesis_fallback`, a minimal
hypothesis-compatible property-testing shim used when the real
``hypothesis`` package is unavailable (hermetic containers).
"""
