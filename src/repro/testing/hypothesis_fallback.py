"""Minimal hypothesis-compatible fallback for hermetic environments.

The tier-1 suite property-tests the matching engine, migration planner and
simulator with `hypothesis <https://hypothesis.readthedocs.io>`_.  Some
build containers cannot install packages, which previously left 4 test
modules failing at *collection*.  This module implements just enough of
the hypothesis API surface used by this repo — ``given`` / ``settings`` /
``assume`` and the ``integers`` / ``floats`` / ``booleans`` /
``sampled_from`` / ``lists`` / ``tuples`` strategies — to run the same
tests as seeded random property checks.

It is installed by ``tests/conftest.py`` ONLY when the real package is
missing (``requirements.txt`` declares hypothesis, so CI always gets the
real engine with shrinking and database-backed edge-case search).  Draws
are deterministic (fixed per-test seed) and boundary values are
over-weighted, but there is no shrinking: a falsifying example is reported
as-is.
"""

from __future__ import annotations

import random
import sys
import types
from functools import wraps
from typing import Any, Callable, List

DEFAULT_MAX_EXAMPLES = 50

#: Probability that a bounded strategy draws one of its boundary values
#: instead of a uniform sample (cheap stand-in for hypothesis' bias
#: toward edge cases).
BOUNDARY_P = 0.2


class _AssumeFailed(Exception):
    """Raised by :func:`assume`; the wrapper discards the example."""


def assume(condition: Any) -> bool:
    if not condition:
        raise _AssumeFailed()
    return True


class SearchStrategy:
    def __init__(self, draw: Callable[[random.Random], Any]):
        self._draw = draw

    def example_for(self, rng: random.Random) -> Any:
        return self._draw(rng)

    def map(self, fn: Callable) -> "SearchStrategy":
        return SearchStrategy(lambda rng: fn(self._draw(rng)))

    def filter(self, pred: Callable) -> "SearchStrategy":
        def draw(rng):
            for _ in range(1000):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate rejected 1000 consecutive draws")

        return SearchStrategy(draw)


def _bounded(draw_uniform: Callable, boundaries: List[Any]) -> SearchStrategy:
    def draw(rng):
        if boundaries and rng.random() < BOUNDARY_P:
            return rng.choice(boundaries)
        return draw_uniform(rng)

    return SearchStrategy(draw)


def integers(min_value: int, max_value: int) -> SearchStrategy:
    bounds = sorted({min_value, max_value, min(min_value + 1, max_value)})
    return _bounded(lambda rng: rng.randint(min_value, max_value), bounds)


def floats(min_value: float, max_value: float, **_: Any) -> SearchStrategy:
    bounds = [float(min_value), float(max_value)]
    return _bounded(lambda rng: rng.uniform(min_value, max_value), bounds)


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.random() < 0.5)


def sampled_from(elements) -> SearchStrategy:
    elements = list(elements)
    return SearchStrategy(lambda rng: rng.choice(elements))


def lists(elements: SearchStrategy, min_size: int = 0, max_size: int = 10) -> SearchStrategy:
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [elements.example_for(rng) for _ in range(n)]

    return SearchStrategy(draw)


def tuples(*strats: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(lambda rng: tuple(s.example_for(rng) for s in strats))


def just(value: Any) -> SearchStrategy:
    return SearchStrategy(lambda rng: value)


def settings(max_examples: int | None = None, deadline: Any = None, **_: Any):
    """Decorator recording run options; only ``max_examples`` is honoured."""

    def deco(fn):
        fn._fallback_settings = {"max_examples": max_examples}
        return fn

    return deco


def given(*strats: SearchStrategy, **kw_strats: SearchStrategy):
    """Seeded-random stand-in for ``hypothesis.given``.

    Works with ``@settings`` applied either above or below it.  Each test
    gets a deterministic seed derived from its name, so failures reproduce
    run-to-run; the falsifying example is embedded in the raised error.
    """

    def deco(fn):
        import inspect

        inner_settings = getattr(fn, "_fallback_settings", None)
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        # Positional strategies fill the RIGHTMOST params (hypothesis'
        # contract) — bind them BY NAME so pytest-supplied kwargs
        # (fixtures, parametrize values) never collide positionally.
        n_pos = len(strats)
        target_names = [p.name for p in params[len(params) - n_pos :]] if n_pos else []

        @wraps(fn)
        def wrapper(*args, **kwargs):
            opts = (
                getattr(wrapper, "_fallback_settings", None)
                or inner_settings
                or {}
            )
            n = opts.get("max_examples") or DEFAULT_MAX_EXAMPLES
            rng = random.Random(fn.__qualname__)
            for _ in range(n):
                kvals = dict(zip(target_names, (s.example_for(rng) for s in strats)))
                kvals.update((k, s.example_for(rng)) for k, s in kw_strats.items())
                try:
                    fn(*args, **kvals, **kwargs)
                except _AssumeFailed:
                    continue
                except Exception as e:
                    raise AssertionError(
                        f"Falsifying example (hypothesis_fallback, no shrinking): "
                        f"{fn.__name__}(**{kvals!r})"
                    ) from e

        # Strategy-supplied parameters must vanish from the visible
        # signature, or pytest would treat them as fixtures.
        keep = [
            p
            for p in (params[: len(params) - n_pos] if n_pos else params)
            if p.name not in kw_strats
        ]
        wrapper.__signature__ = sig.replace(parameters=keep)
        del wrapper.__wrapped__  # keep inspect from recovering fn's signature
        return wrapper

    return deco


def install() -> None:
    """Register this module as ``hypothesis`` (+ ``hypothesis.strategies``).

    No-op if the real hypothesis is already importable/imported.
    """
    if "hypothesis" in sys.modules:
        return
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None)
    hyp.__is_repro_fallback__ = True

    strat_mod = types.ModuleType("hypothesis.strategies")
    for name in (
        "SearchStrategy",
        "integers",
        "floats",
        "booleans",
        "sampled_from",
        "lists",
        "tuples",
        "just",
    ):
        setattr(strat_mod, name, globals()[name])

    hyp.strategies = strat_mod
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strat_mod
