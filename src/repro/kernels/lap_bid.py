"""Pallas kernel: auction bid step — masked row-wise top-2 reduction.

Given the benefit matrix ``a`` (n, m) and prices ``p`` (m,), each
*unassigned person* (row) needs, per auction round:

    vals[i, j] = a[i, j] - p[j]
    best_v[i]  = max_j vals[i, j]
    best_j[i]  = argmax_j vals[i, j]
    second[i]  = max_{j != best_j} vals[i, j]

TPU mapping: the matrix streams HBM->VMEM in (BLOCK_ROWS x BLOCK_COLS)
tiles; the grid is (rows/BLOCK_ROWS, cols/BLOCK_COLS) with the column axis
minor (sequential on TPU), so each row-block keeps a running (top-1, arg,
top-2) carry in VMEM scratch across column tiles.  Blocks are 128-aligned
for the VPU lanes; a (128, 512) f32 tile is 256 KiB — far under the ~16 MiB
v5e VMEM budget even with double buffering.

Padding-free bids: the grid covers only the *real* columns (rounded up to
one tile); the ragged tile edge is masked **in-kernel** against global
column ids via :mod:`repro.kernels.tile_mask` (shared with
``flash_decode``), so the host-side padding is plain ``jnp.pad`` zeros —
no NEG_INF-filled copy of the benefit matrix is ever materialised, and a
rectangular (n, m) instance costs O(n * m) bid work, never O(max(n, m)^2).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tile_mask import mask_ragged_cols, tile_col_ids

NEG_INF = -1e30

BLOCK_ROWS = 128
BLOCK_COLS = 512

# f32 min tile is (8, 128) (sublane x lane); small instances — the k_l x k_l
# node-pair LAPs are 4x4-8x8 — shrink to one min tile instead of padding to
# the full (128, 512) block (a 4096x compute blowup per instance).
MIN_BLOCK_ROWS = 8
MIN_BLOCK_COLS = 128


def _resolve_interpret(interpret: bool | None) -> bool:
    """None = auto: compiled on TPU, interpret mode on CPU/GPU hosts."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def _block_dims(n: int, m: int) -> tuple[int, int]:
    """Largest-useful (block_rows, block_cols) for an (n, m) instance:
    tile-aligned, never larger than the default blocks, never smaller than
    the f32 min tile."""
    br = min(BLOCK_ROWS, max(MIN_BLOCK_ROWS, -(-n // MIN_BLOCK_ROWS) * MIN_BLOCK_ROWS))
    bc = min(BLOCK_COLS, max(MIN_BLOCK_COLS, -(-m // MIN_BLOCK_COLS) * MIN_BLOCK_COLS))
    return br, bc


def _tile_top2(vals, col_offset):
    """(best, arg, second) of one (BR, BC) tile, args in global columns."""
    col_ids = tile_col_ids(vals.shape, col_offset)
    tile_best = jnp.max(vals, axis=1, keepdims=True)  # (BR, 1)
    tile_arg = (jnp.argmax(vals, axis=1) + col_offset).astype(jnp.int32)[:, None]
    masked = jnp.where(col_ids == tile_arg, NEG_INF, vals)
    tile_second = jnp.max(masked, axis=1, keepdims=True)
    return tile_best, tile_arg, tile_second


def _merge_top2(run, tile):
    """Merge two (top1, arg, top2) summaries; the RUNNING (earlier-tile)
    summary wins ties so the argmax matches jnp.argmax's
    first-occurrence rule."""
    run_best, run_arg, run_second = run
    tile_best, tile_arg, tile_second = tile
    new_best = jnp.where(tile_best > run_best, tile_best, run_best)
    new_arg = jnp.where(tile_best > run_best, tile_arg, run_arg)
    # second = max of the loser's best and both seconds
    loser_best = jnp.where(tile_best > run_best, run_best, tile_best)
    new_second = jnp.maximum(loser_best, jnp.maximum(run_second, tile_second))
    return new_best, new_arg, new_second


def _bid_kernel(
    a_ref,      # (BR, BC) benefit tile
    p_ref,      # (1, BC) price tile
    best_v_ref,  # (BR, 1) out
    best_j_ref,  # (BR, 1) out int32
    second_ref,  # (BR, 1) out
    *,
    block_cols: int,
    valid_cols: int,
):
    ci = pl.program_id(1)
    vals = mask_ragged_cols(a_ref[...] - p_ref[...], ci * block_cols, valid_cols, NEG_INF)
    summary = _tile_top2(vals, ci * block_cols)

    @pl.when(ci == 0)
    def _init():
        best_v_ref[...], best_j_ref[...], second_ref[...] = summary

    @pl.when(ci > 0)
    def _accum():
        run = (best_v_ref[...], best_j_ref[...], second_ref[...])
        best_v_ref[...], best_j_ref[...], second_ref[...] = _merge_top2(
            run, summary
        )


def _bid_kernel_batched(
    a_ref,      # (1, BR, BC) benefit tile of one batch instance
    p_ref,      # (1, 1, BC) price tile
    best_v_ref,  # (1, BR, 1) out
    best_j_ref,  # (1, BR, 1) out int32
    second_ref,  # (1, BR, 1) out
    *,
    block_cols: int,
    valid_cols: int,
):
    """Batched variant of :func:`_bid_kernel` (same tile summary + merge).

    The grid is (batch, rows/BLOCK_ROWS, cols/BLOCK_COLS) with the column
    axis minor; the leading batch axis maps one grid step per instance so a
    single ``pallas_call`` covers a whole instance stack.  This is the
    explicit counterpart of what ``jax.vmap`` over :func:`lap_bid_pallas`
    produces via the lifted pallas batching rule (the path the batched
    auction actually takes); it exists for direct 3-D callers and as a
    parity oracle for that lifted path.
    """
    ci = pl.program_id(2)
    vals = mask_ragged_cols(a_ref[0] - p_ref[0], ci * block_cols, valid_cols, NEG_INF)
    summary = _tile_top2(vals, ci * block_cols)

    @pl.when(ci == 0)
    def _init():
        best_v_ref[0], best_j_ref[0], second_ref[0] = summary

    @pl.when(ci > 0)
    def _accum():
        run = (best_v_ref[0], best_j_ref[0], second_ref[0])
        best_v_ref[0], best_j_ref[0], second_ref[0] = _merge_top2(run, summary)


def lap_bid_pallas_batched(
    a: jax.Array, prices: jax.Array, interpret: bool | None = None
):
    """Batched bid step: ``a`` (B, n, m), ``prices`` (B, m).

    Returns (best_v, best_j, second_v), each (B, n).  Same padding contract
    as :func:`lap_bid_pallas`; the batch axis becomes the leading (major)
    grid dimension, so column tiles still run sequentially per instance and
    the running top-2 carry in the output refs stays per-instance.
    ``interpret=None`` resolves automatically: compiled on TPU, interpret
    mode elsewhere (the previous hard default of True silently ran the
    interpreter on TPU when callers forgot the flag).
    """
    return _lap_bid_pallas_batched_jit(a, prices, _resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _lap_bid_pallas_batched_jit(a: jax.Array, prices: jax.Array, interpret: bool):
    b, n, m = a.shape
    br, bc = _block_dims(n, m)
    n_pad = (n + br - 1) // br * br
    m_pad = (m + bc - 1) // bc * bc
    # zero padding only — the ragged edge is masked in-kernel by column id
    a_p = jnp.pad(a, ((0, 0), (0, n_pad - n), (0, m_pad - m)))
    p_p = jnp.pad(prices, ((0, 0), (0, m_pad - m)))[:, None, :]

    grid = (b, n_pad // br, m_pad // bc)
    best_v, best_j, second = pl.pallas_call(
        functools.partial(_bid_kernel_batched, block_cols=bc, valid_cols=m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, br, bc), lambda bi, ri, ci: (bi, ri, ci)),
            pl.BlockSpec((1, 1, bc), lambda bi, ri, ci: (bi, 0, ci)),
        ],
        out_specs=[
            pl.BlockSpec((1, br, 1), lambda bi, ri, ci: (bi, ri, 0)),
            pl.BlockSpec((1, br, 1), lambda bi, ri, ci: (bi, ri, 0)),
            pl.BlockSpec((1, br, 1), lambda bi, ri, ci: (bi, ri, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n_pad, 1), a.dtype),
            jax.ShapeDtypeStruct((b, n_pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((b, n_pad, 1), a.dtype),
        ],
        interpret=interpret,
    )(a_p, p_p)
    return best_v[:, :n, 0], best_j[:, :n, 0], second[:, :n, 0]


def _fused_vals(cost_tile, price_tile, tb_scale, row_base, col_offset):
    """In-kernel benefit assembly for one tile:

        vals[i, j] = -cost[i, j] + tb_scale * (gi+1)^2 * (gj+1) - p[j]

    with ``gi``/``gj`` the GLOBAL row/column indices — the positional
    tie-break ramp of ``engine._tie_break_perturb`` (identity ranks ==
    positions when ids increase with position, as the migration fan-out's
    slot/node ids do).  ``tb_scale = 0`` degenerates to the plain bid.

    Exactness: ``tb_scale`` is a power of two and ``(gi+1)^2 * (gj+1)`` an
    integer, so for instances with ``n^2 * m < 2^24`` (every fan-out pair
    LAP and any node match below ~256 nodes) the ramp term is exact in f32
    and the assembled value is bit-identical to the host path's
    f64-assemble-then-cast — the fused auction's plans can then be
    compared bit-for-bit against the host engine.
    """
    shape = cost_tile.shape
    gi = (
        jax.lax.broadcasted_iota(jnp.int32, shape, len(shape) - 2) + row_base + 1
    ).astype(cost_tile.dtype)
    gj = (tile_col_ids(shape, col_offset) + 1).astype(cost_tile.dtype)
    return (tb_scale * (gi * gi) * gj - cost_tile) - price_tile


def _bid_fused_kernel(
    a_ref,      # (BR, BC) COST tile (not benefit)
    p_ref,      # (1, BC) price tile
    tb_ref,     # (1, 1) tie-break scale
    best_v_ref,  # (BR, 1) out
    best_j_ref,  # (BR, 1) out int32
    second_ref,  # (BR, 1) out
    *,
    block_rows: int,
    block_cols: int,
    valid_cols: int,
):
    ri = pl.program_id(0)
    ci = pl.program_id(1)
    vals = _fused_vals(
        a_ref[...], p_ref[...], tb_ref[0, 0], ri * block_rows, ci * block_cols
    )
    vals = mask_ragged_cols(vals, ci * block_cols, valid_cols, NEG_INF)
    summary = _tile_top2(vals, ci * block_cols)

    @pl.when(ci == 0)
    def _init():
        best_v_ref[...], best_j_ref[...], second_ref[...] = summary

    @pl.when(ci > 0)
    def _accum():
        run = (best_v_ref[...], best_j_ref[...], second_ref[...])
        best_v_ref[...], best_j_ref[...], second_ref[...] = _merge_top2(run, summary)


def _bid_fused_kernel_batched(
    a_ref,      # (1, BR, BC) cost tile of one batch instance
    p_ref,      # (1, 1, BC) price tile
    tb_ref,     # (1, 1) per-instance tie-break scale
    best_v_ref,  # (1, BR, 1) out
    best_j_ref,  # (1, BR, 1) out int32
    second_ref,  # (1, BR, 1) out
    *,
    block_rows: int,
    block_cols: int,
    valid_cols: int,
):
    ri = pl.program_id(1)
    ci = pl.program_id(2)
    vals = _fused_vals(
        a_ref[0], p_ref[0], tb_ref[0, 0], ri * block_rows, ci * block_cols
    )
    vals = mask_ragged_cols(vals, ci * block_cols, valid_cols, NEG_INF)
    summary = _tile_top2(vals, ci * block_cols)

    @pl.when(ci == 0)
    def _init():
        best_v_ref[0], best_j_ref[0], second_ref[0] = summary

    @pl.when(ci > 0)
    def _accum():
        run = (best_v_ref[0], best_j_ref[0], second_ref[0])
        best_v_ref[0], best_j_ref[0], second_ref[0] = _merge_top2(run, summary)


def lap_bid_fused_pallas(
    cost: jax.Array,
    prices: jax.Array,
    tb_scale: jax.Array | float = 0.0,
    interpret: bool | None = None,
):
    """Fused-benefit bid step: ``cost`` (n, m) raw COST matrix.

    The benefit — ``-cost`` plus the positional tie-break ramp — is
    assembled inside the kernel's tiled sweep (see :func:`_fused_vals`),
    so the auction driver never materialises the perturbed (n, m) benefit
    in HBM at all: one cost upload serves every bid round, and only the
    (m,) price vector changes between rounds.  Same padding contract and
    return shape as :func:`lap_bid_pallas`.
    """
    return _lap_bid_fused_jit(
        cost,
        prices,
        jnp.asarray(tb_scale, cost.dtype).reshape(1, 1),
        _resolve_interpret(interpret),
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def _lap_bid_fused_jit(
    cost: jax.Array, prices: jax.Array, tb_scale: jax.Array, interpret: bool
):
    n, m = cost.shape
    br, bc = _block_dims(n, m)
    n_pad = (n + br - 1) // br * br
    m_pad = (m + bc - 1) // bc * bc
    a_p = jnp.pad(cost, ((0, n_pad - n), (0, m_pad - m)))
    p_p = jnp.pad(prices, (0, m_pad - m))[None, :]

    grid = (n_pad // br, m_pad // bc)
    best_v, best_j, second = pl.pallas_call(
        functools.partial(
            _bid_fused_kernel, block_rows=br, block_cols=bc, valid_cols=m
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bc), lambda ri, ci: (ri, ci)),
            pl.BlockSpec((1, bc), lambda ri, ci: (0, ci)),
            pl.BlockSpec((1, 1), lambda ri, ci: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, 1), lambda ri, ci: (ri, 0)),
            pl.BlockSpec((br, 1), lambda ri, ci: (ri, 0)),
            pl.BlockSpec((br, 1), lambda ri, ci: (ri, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, 1), cost.dtype),
            jax.ShapeDtypeStruct((n_pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((n_pad, 1), cost.dtype),
        ],
        interpret=interpret,
    )(a_p, p_p, tb_scale)
    return best_v[:n, 0], best_j[:n, 0], second[:n, 0]


def lap_bid_fused_pallas_batched(
    cost: jax.Array,
    prices: jax.Array,
    tb_scale: jax.Array | float = 0.0,
    interpret: bool | None = None,
):
    """Batched fused-benefit bid step: ``cost`` (B, n, m), ``prices``
    (B, m), ``tb_scale`` scalar or (B,) per instance.  Returns
    (best_v, best_j, second_v), each (B, n) — the bid path of the fused
    migration fan-out, where all pair LAPs share one cost upload and the
    tie-break ramp never exists as data."""
    b = cost.shape[0]
    tb = jnp.broadcast_to(
        jnp.asarray(tb_scale, cost.dtype).reshape(-1), (b,)
    ).reshape(b, 1)
    return _lap_bid_fused_batched_jit(cost, prices, tb, _resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _lap_bid_fused_batched_jit(
    cost: jax.Array, prices: jax.Array, tb_scale: jax.Array, interpret: bool
):
    b, n, m = cost.shape
    br, bc = _block_dims(n, m)
    n_pad = (n + br - 1) // br * br
    m_pad = (m + bc - 1) // bc * bc
    a_p = jnp.pad(cost, ((0, 0), (0, n_pad - n), (0, m_pad - m)))
    p_p = jnp.pad(prices, ((0, 0), (0, m_pad - m)))[:, None, :]

    grid = (b, n_pad // br, m_pad // bc)
    best_v, best_j, second = pl.pallas_call(
        functools.partial(
            _bid_fused_kernel_batched, block_rows=br, block_cols=bc, valid_cols=m
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, br, bc), lambda bi, ri, ci: (bi, ri, ci)),
            pl.BlockSpec((1, 1, bc), lambda bi, ri, ci: (bi, 0, ci)),
            pl.BlockSpec((1, 1), lambda bi, ri, ci: (bi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, br, 1), lambda bi, ri, ci: (bi, ri, 0)),
            pl.BlockSpec((1, br, 1), lambda bi, ri, ci: (bi, ri, 0)),
            pl.BlockSpec((1, br, 1), lambda bi, ri, ci: (bi, ri, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n_pad, 1), cost.dtype),
            jax.ShapeDtypeStruct((b, n_pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((b, n_pad, 1), cost.dtype),
        ],
        interpret=interpret,
    )(a_p, p_p, tb_scale)
    return best_v[:, :n, 0], best_j[:, :n, 0], second[:, :n, 0]


def lap_bid_pallas(a: jax.Array, prices: jax.Array, interpret: bool | None = None):
    """Returns (best_v, best_j, second_v), each (n,).

    ``a`` may be rectangular (n, m); the grid covers only the real columns
    (rounded up to one tile) and the ragged edge is masked in-kernel, so
    padding is plain zeros (callers guarantee m >= 2 real columns).
    ``interpret=None`` resolves automatically (see
    :func:`lap_bid_pallas_batched`).
    """
    return _lap_bid_pallas_jit(a, prices, _resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _lap_bid_pallas_jit(a: jax.Array, prices: jax.Array, interpret: bool):
    n, m = a.shape
    br, bc = _block_dims(n, m)
    n_pad = (n + br - 1) // br * br
    m_pad = (m + bc - 1) // bc * bc
    a_p = jnp.pad(a, ((0, n_pad - n), (0, m_pad - m)))
    p_p = jnp.pad(prices, (0, m_pad - m))[None, :]

    grid = (n_pad // br, m_pad // bc)
    best_v, best_j, second = pl.pallas_call(
        functools.partial(_bid_kernel, block_cols=bc, valid_cols=m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bc), lambda ri, ci: (ri, ci)),
            pl.BlockSpec((1, bc), lambda ri, ci: (0, ci)),
        ],
        out_specs=[
            pl.BlockSpec((br, 1), lambda ri, ci: (ri, 0)),
            pl.BlockSpec((br, 1), lambda ri, ci: (ri, 0)),
            pl.BlockSpec((br, 1), lambda ri, ci: (ri, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, 1), a.dtype),
            jax.ShapeDtypeStruct((n_pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((n_pad, 1), a.dtype),
        ],
        interpret=interpret,
    )(a_p, p_p)
    return best_v[:n, 0], best_j[:n, 0], second[:n, 0]
