"""Pallas kernel: auction bid step — masked row-wise top-2 reduction.

Given the benefit matrix ``a`` (n, m) and prices ``p`` (m,), each
*unassigned person* (row) needs, per auction round:

    vals[i, j] = a[i, j] - p[j]
    best_v[i]  = max_j vals[i, j]
    best_j[i]  = argmax_j vals[i, j]
    second[i]  = max_{j != best_j} vals[i, j]

TPU mapping: the matrix streams HBM->VMEM in (BLOCK_ROWS x BLOCK_COLS)
tiles; the grid is (rows/BLOCK_ROWS, cols/BLOCK_COLS) with the column axis
minor (sequential on TPU), so each row-block keeps a running (top-1, arg,
top-2) carry in VMEM scratch across column tiles.  Blocks are 128-aligned
for the VPU lanes; a (128, 512) f32 tile is 256 KiB — far under the ~16 MiB
v5e VMEM budget even with double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

BLOCK_ROWS = 128
BLOCK_COLS = 512


def _bid_kernel(
    a_ref,      # (BR, BC) benefit tile
    p_ref,      # (1, BC) price tile
    best_v_ref,  # (BR, 1) out
    best_j_ref,  # (BR, 1) out int32
    second_ref,  # (BR, 1) out
    *,
    block_cols: int,
):
    ci = pl.program_id(1)
    ncols = pl.num_programs(1)

    vals = a_ref[...] - p_ref[...]  # (BR, BC)
    col_ids = jax.lax.broadcasted_iota(jnp.int32, vals.shape, 1) + ci * block_cols

    tile_best = jnp.max(vals, axis=1, keepdims=True)  # (BR, 1)
    tile_arg_local = jnp.argmax(vals, axis=1)
    tile_arg = (tile_arg_local + ci * block_cols).astype(jnp.int32)[:, None]
    masked = jnp.where(col_ids == tile_arg, NEG_INF, vals)
    tile_second = jnp.max(masked, axis=1, keepdims=True)

    @pl.when(ci == 0)
    def _init():
        best_v_ref[...] = tile_best
        best_j_ref[...] = tile_arg
        second_ref[...] = tile_second

    @pl.when(ci > 0)
    def _accum():
        run_best = best_v_ref[...]
        run_arg = best_j_ref[...]
        run_second = second_ref[...]
        # merge two (top1, top2) summaries; earlier tile wins ties so the
        # argmax matches jnp.argmax's first-occurrence rule.
        new_best = jnp.where(tile_best > run_best, tile_best, run_best)
        new_arg = jnp.where(tile_best > run_best, tile_arg, run_arg)
        # second = max of the losers' best and both seconds
        loser_best = jnp.where(tile_best > run_best, run_best, tile_best)
        new_second = jnp.maximum(loser_best, jnp.maximum(run_second, tile_second))
        best_v_ref[...] = new_best
        best_j_ref[...] = new_arg
        second_ref[...] = new_second


@functools.partial(jax.jit, static_argnames=("interpret",))
def lap_bid_pallas(a: jax.Array, prices: jax.Array, interpret: bool = True):
    """Returns (best_v, best_j, second_v), each (n,).

    Pads rows to BLOCK_ROWS and cols to BLOCK_COLS with NEG_INF (padding
    never wins; callers guarantee m >= 2 real columns).
    """
    n, m = a.shape
    br, bc = BLOCK_ROWS, BLOCK_COLS
    n_pad = (n + br - 1) // br * br
    m_pad = (m + bc - 1) // bc * bc
    a_p = jnp.full((n_pad, m_pad), NEG_INF, a.dtype).at[:n, :m].set(a)
    # padded columns get +inf price so (a - p) stays NEG-ish even if a=0
    p_p = jnp.zeros((1, m_pad), a.dtype).at[0, :m].set(prices)

    grid = (n_pad // br, m_pad // bc)
    best_v, best_j, second = pl.pallas_call(
        functools.partial(_bid_kernel, block_cols=bc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bc), lambda ri, ci: (ri, ci)),
            pl.BlockSpec((1, bc), lambda ri, ci: (0, ci)),
        ],
        out_specs=[
            pl.BlockSpec((br, 1), lambda ri, ci: (ri, 0)),
            pl.BlockSpec((br, 1), lambda ri, ci: (ri, 0)),
            pl.BlockSpec((br, 1), lambda ri, ci: (ri, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, 1), a.dtype),
            jax.ShapeDtypeStruct((n_pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((n_pad, 1), a.dtype),
        ],
        interpret=interpret,
    )(a_p, p_p)
    return best_v[:n, 0], best_j[:n, 0], second[:n, 0]
