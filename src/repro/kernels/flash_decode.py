"""Pallas kernel: flash-decoding — one query vs a long KV cache.

The serving hot spot (decode_32k / long_500k): every step each sequence
attends ONE query token against a 32k–524k entry cache.  The unfused path
materialises (H, S) logits through HBM; this kernel streams the cache in
(BLOCK_K x D) tiles with an online-softmax carry, touching each cache byte
exactly once.

GQA without materialisation: the grid runs one program per (batch x Q-head)
and the K/V BlockSpec *index map* routes head h to its KV group h // (H/KV)
— the repeated-KV tensor is never built.

Ring-buffer semantics: ``valid_len`` (SMEM scalar) masks cache slots beyond
the valid prefix, matching the model's ``kv_valid_len`` mask — via the
shared ragged-edge helper :mod:`repro.kernels.tile_mask` (same code path
the ``lap_bid`` kernels use for their column padding).  Validated against
``ref.flash_decode`` in interpret mode (CPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tile_mask import mask_ragged_cols

NEG_INF = -1e30

BLOCK_K = 512


def _decode_kernel(
    vl_ref,    # (1, 1) int32 in SMEM: number of valid cache slots
    q_ref,     # (1, D)
    k_ref,     # (1, BK, D)
    v_ref,     # (1, BK, D)
    o_ref,     # (1, D)
    acc_ref,   # (1, D) f32 scratch
    m_ref,     # (1, 1) f32 scratch
    l_ref,     # (1, 1) f32 scratch
    *,
    scale: float,
    block_k: int,
):
    ki = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    vl = vl_ref[0, 0]

    @pl.when(ki * block_k < vl)  # skip tiles entirely past the valid prefix
    def _step():
        q = q_ref[...].astype(jnp.float32) * scale         # (1, D)
        k = k_ref[0].astype(jnp.float32)                   # (BK, D)
        v = v_ref[0].astype(jnp.float32)                   # (BK, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (1, BK)
        s = mask_ragged_cols(s, ki * block_k, vl, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_cur)                              # (1, BK)
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_cur

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def flash_decode_pallas(
    q: jax.Array,          # (B, H, D)
    k: jax.Array,          # (B, S, KV, D)
    v: jax.Array,          # (B, S, KV, D)
    valid_len: jax.Array,  # scalar int32
    block_k: int = BLOCK_K,
    interpret: bool = True,
) -> jax.Array:
    """Single-token GQA attention over a (ring-buffer) cache; (B, H, D)."""
    b, h, d = q.shape
    s, kvh = k.shape[1], k.shape[2]
    g = h // kvh

    s_pad = max((s + block_k - 1) // block_k * block_k, block_k)
    block_k = min(block_k, s_pad)

    # (B, KV, S, D) so a grid row can slice one kv head's cache
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    if s_pad != s:
        pad = ((0, 0), (0, 0), (0, s_pad - s), (0, 0))
        kt = jnp.pad(kt, pad)
        vt = jnp.pad(vt, pad)
    kt = kt.reshape(b * kvh, s_pad, d)
    vt = vt.reshape(b * kvh, s_pad, d)
    qf = q.reshape(b * h, d)
    vl = jnp.broadcast_to(jnp.asarray(valid_len, jnp.int32), (1, 1))

    def kv_row(bh, ki):
        return ((bh // h) * kvh + (bh % h) // g, ki, 0)

    grid = (b * h, s_pad // block_k)
    out = pl.pallas_call(
        functools.partial(
            _decode_kernel, scale=1.0 / (d**0.5), block_k=block_k
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, d), lambda bh, ki: (bh, 0)),
            pl.BlockSpec((1, block_k, d), kv_row),
            pl.BlockSpec((1, block_k, d), kv_row),
        ],
        out_specs=pl.BlockSpec((1, d), lambda bh, ki: (bh, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(vl, qf, kt, vt)
    return out.reshape(b, h, d)
