"""Pallas kernel: Algorithm-3 pairwise migration-cost matrix.

For GPU u (round i) with job set JS_u and GPU v (round i+1) with job set
JS_v the migration cost is

    C[u, v] = sum_{j in JS_u symdiff JS_v} 1 / (2 * num_gpus(j)).

Inputs are the dense slot encoding (MAX_PACK = 2 jobs per GPU, §5): job-id
matrices ``slots_u`` (U, P), ``slots_v`` (V, P) with -1 for empty, plus
per-slot weight matrices (0 for empty slots, so empties never contribute).

TPU mapping: grid tiles the (U, V) output in (BLOCK_U x BLOCK_V) blocks;
each step loads a (BLOCK_U, P) and (BLOCK_V, P) strip (P = 2), broadcasts
the (BLOCK_U, BLOCK_V, P, P) equality cube in VREGs and reduces.  At
BLOCK = 128 the cube is 64 KiB of bool — VMEM-trivial; the kernel is
embarrassingly output-tiled so it scales to the k_c^2-node-pair fan-out of
Algorithm 2 (this construction is the O(k^2) term that dominates the
migration policy's runtime at 256+ GPUs, Fig. 14b).

On physical TPU the P axis would be laid out along sublanes; interpret mode
(CPU validation here) is layout-agnostic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_U = 128
BLOCK_V = 128
EMPTY = -1


def _cost_kernel(su_ref, sv_ref, wu_ref, wv_ref, out_ref):
    su = su_ref[...]  # (BU, P) int32
    sv = sv_ref[...]  # (BV, P) int32
    wu = wu_ref[...]  # (BU, P) f32
    wv = wv_ref[...]  # (BV, P) f32
    eq = su[:, None, :, None] == sv[None, :, None, :]  # (BU, BV, P, P)
    u_in_v = eq.any(axis=-1)  # (BU, BV, P)
    v_in_u = eq.any(axis=-2)  # (BU, BV, P)
    cost_out = (wu[:, None, :] * (~u_in_v)).sum(-1)
    cost_in = (wv[None, :, :] * (~v_in_u)).sum(-1)
    out_ref[...] = (cost_out + cost_in).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def migration_cost_pallas(
    slots_u: jax.Array,
    slots_v: jax.Array,
    w_u: jax.Array,
    w_v: jax.Array,
    interpret: bool = True,
) -> jax.Array:
    """(U, V) cost matrix; inputs (U, P) / (V, P) slot ids + weights."""
    u, p = slots_u.shape
    v, _ = slots_v.shape
    bu, bv = BLOCK_U, BLOCK_V
    u_pad = (u + bu - 1) // bu * bu
    v_pad = (v + bv - 1) // bv * bv

    # Padding uses EMPTY ids with zero weight -> contributes nothing.  Use
    # two *distinct* negative ids so padded u never "matches" padded v.
    su = jnp.full((u_pad, p), -2, jnp.int32).at[:u].set(slots_u.astype(jnp.int32))
    sv = jnp.full((v_pad, p), -3, jnp.int32).at[:v].set(slots_v.astype(jnp.int32))
    wu = jnp.zeros((u_pad, p), jnp.float32).at[:u].set(w_u.astype(jnp.float32))
    wv = jnp.zeros((v_pad, p), jnp.float32).at[:v].set(w_v.astype(jnp.float32))

    grid = (u_pad // bu, v_pad // bv)
    out = pl.pallas_call(
        _cost_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bu, p), lambda ui, vi: (ui, 0)),
            pl.BlockSpec((bv, p), lambda ui, vi: (vi, 0)),
            pl.BlockSpec((bu, p), lambda ui, vi: (ui, 0)),
            pl.BlockSpec((bv, p), lambda ui, vi: (vi, 0)),
        ],
        out_specs=pl.BlockSpec((bu, bv), lambda ui, vi: (ui, vi)),
        out_shape=jax.ShapeDtypeStruct((u_pad, v_pad), jnp.float32),
        interpret=interpret,
    )(su, sv, wu, wv)
    return out[:u, :v]
