"""Pallas TPU kernels for Tesserae's compute hot spots.

Three kernels, each with a ``ref.py`` pure-jnp oracle and a jit'd wrapper in
``ops.py``; all are validated in ``interpret=True`` mode on CPU (this
container) and written with explicit BlockSpec VMEM tiling for TPU v5e as
the target:

* ``lap_bid``        — the auction-algorithm bid step (masked row top-2 over
                       the benefit-minus-price matrix).  This is the inner
                       loop of the §4.1/§4.2 assignment solves.
* ``migration_cost`` — Algorithm 3 lines 2-7: the pairwise symmetric-
                       difference cost matrix over GPU job-sets, the O(k^2)
                       construction that dominates Algorithm 2 at large
                       cluster sizes.
* ``flash_attention``— causal flash attention for the workload substrate
                       (the perf-critical compute layer of the jobs
                       Tesserae schedules).
* ``flash_decode``   — flash-decoding: one query token against a long
                       (ring-buffer) KV cache, GQA-aware without
                       materialising repeated KV heads.  The decode_32k /
                       long_500k serving hot spot.
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
