"""Pallas kernel: causal flash attention (forward) for the job substrate.

Online-softmax tiling (Dao et al.) adapted to the TPU grid model: the grid
is (batch*heads, q_blocks, k_blocks) with the k axis minor — on TPU the
minor grid dimension executes sequentially per (bh, q) pair, so the running
(max, denom, accumulator) state lives in VMEM scratch across k steps.

Block sizes: (BLOCK_Q x D) query tile and (BLOCK_K x D) key/value tiles with
D <= 128 kept whole (MXU-aligned); the (BLOCK_Q x BLOCK_K) logits tile is
f32 in VREG/VMEM.  Defaults (128, 512) give a worst-case VMEM working set of
~1.2 MiB — comfortable with double buffering on v5e (~16 MiB*).

Causality: k tiles strictly above the diagonal are skipped entirely
(``pl.when``), halving compute; the diagonal tile applies an element mask.

Validated against ``ref.flash_attention`` in interpret mode; the backward
pass is left to autodiff on the reference path (kernels are used for
serving/prefill where only forward runs).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

BLOCK_Q = 128
BLOCK_K = 512


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale: float, block_q: int, block_k: int, causal: bool, kv_len: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Skip k tiles fully above the causal diagonal.
    if causal:
        should_run = ki * block_k <= qi * block_q + block_q - 1
    else:
        should_run = ki >= 0

    @pl.when(should_run)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale  # (BQ, D)
        k = k_ref[0].astype(jnp.float32)          # (BK, D)
        v = v_ref[0].astype(jnp.float32)          # (BK, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (BQ, BK)
        cols = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = cols < kv_len  # mask padded keys
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            valid &= rows >= cols
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]                        # (BQ, 1)
        m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_cur)                     # (BQ, BK)
        alpha = jnp.exp(m_prev - m_cur)            # (BQ, 1)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_cur

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    block_q: int = BLOCK_Q,
    block_k: int = BLOCK_K,
    interpret: bool = True,
) -> jax.Array:
    """q/k/v: (BH, S, D) with the batch*heads axis flattened; returns (BH, S, D)."""
    bh, s, d = q.shape
    scale = 1.0 / (d**0.5)
    # Pad seq to a 128 multiple (VPU sublane alignment); both block sizes
    # must divide s_pad exactly, so shrink them for short sequences.
    s_pad = max((s + 127) // 128 * 128, 128)
    block_q = min(block_q, s_pad)
    if s_pad % block_q:
        block_q = 128
    block_k = min(block_k, s_pad)
    if s_pad % block_k:
        block_k = 128

    def pad(x):
        return jnp.zeros((bh, s_pad, d), x.dtype).at[:, :s].set(x)

    qp, kp, vp = pad(q), pad(k), pad(v)
    grid = (bh, s_pad // block_q, s_pad // block_k)
    out = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            scale=scale,
            block_q=block_q,
            block_k=block_k,
            causal=causal,
            kv_len=s,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :s]
