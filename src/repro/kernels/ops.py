"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU (this CPU container) and False on
TPU; every wrapper has identical semantics to its ``ref.py`` oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.lap_bid import (
    lap_bid_fused_pallas,
    lap_bid_fused_pallas_batched,
    lap_bid_pallas,
    lap_bid_pallas_batched,
)
from repro.kernels.migration_cost import migration_cost_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _require(cond: bool, msg: str) -> None:
    """Shape/dtype contract check.  Runs against static metadata only, so
    under jit it fires at trace time and costs nothing per call."""
    if not cond:
        raise ValueError(msg)


def _check_bid_args(name: str, mat: jax.Array, prices: jax.Array) -> None:
    _require(
        mat.ndim in (2, 3),
        f"{name}: matrix must be (n, m) or (B, n, m), got shape {mat.shape}",
    )
    _require(
        jnp.issubdtype(mat.dtype, jnp.floating),
        f"{name}: matrix must be floating, got dtype {mat.dtype}",
    )
    want = (
        (mat.shape[0], mat.shape[-1]) if mat.ndim == 3 else (mat.shape[-1],)
    )
    _require(
        tuple(prices.shape) == want,
        f"{name}: prices shape {prices.shape} does not match matrix "
        f"{mat.shape} (want {want})",
    )


def lap_bid_top2(vals: jax.Array):
    """Auction bid step on a precomputed (benefit - price) matrix.

    Drop-in replacement for ``ref.lap_bid_top2`` (kept as the parity-test
    oracle surface; ``auction_lap(use_kernel=True)`` now calls
    :func:`lap_bid` directly so the price subtraction fuses into the
    kernel's tiled sweep instead of materialising ``vals`` per bid
    round).  Accepts (n, m) or an explicit (B, n, m) stack, which routes
    to :func:`lap_bid_pallas_batched`.
    NOTE: the auction fan-out does NOT reach the 3-D branch — under
    ``jax.vmap`` each instance is a 2-D tracer and vmap's pallas batching
    rule lifts the 2-D kernel into one batched ``pallas_call`` itself;
    the explicit branch serves direct 3-D callers and parity tests.

    Shapes: ``vals`` (n, m) or (B, n, m), floating.  Returns
    ``(best_v, best_j, second_v)``, each (n,) / (B, n).
    """
    _require(
        vals.ndim in (2, 3),
        f"lap_bid_top2: vals must be (n, m) or (B, n, m), got shape {vals.shape}",
    )
    _require(
        jnp.issubdtype(vals.dtype, jnp.floating),
        f"lap_bid_top2: vals must be floating, got dtype {vals.dtype}",
    )
    if vals.ndim == 3:
        return lap_bid_pallas_batched(
            vals,
            jnp.zeros(vals.shape[::2], vals.dtype),
            interpret=_default_interpret(),
        )
    return lap_bid_pallas(
        vals, jnp.zeros((vals.shape[-1],), vals.dtype), interpret=_default_interpret()
    )


def lap_bid(a: jax.Array, prices: jax.Array):
    """Auction bid step on a BENEFIT matrix; prices subtract in-kernel.

    Shapes: ``a`` (n, m) with ``prices`` (m,), or batched ``a`` (B, n, m)
    with ``prices`` (B, m); both floating.  Returns
    ``(best_v, best_j, second_v)``, each (n,) / (B, n).
    """
    _check_bid_args("lap_bid", a, prices)
    if a.ndim == 3:
        return lap_bid_pallas_batched(a, prices, interpret=_default_interpret())
    return lap_bid_pallas(a, prices, interpret=_default_interpret())


def lap_bid_fused(cost: jax.Array, prices: jax.Array, tb_scale=0.0):
    """Fused-benefit bid step on a raw COST matrix (2-D or batched 3-D):
    the ``-cost`` negation and the positional tie-break ramp assemble
    inside the kernel's tiled sweep, so no perturbed benefit matrix is
    ever materialised in HBM (see ``lap_bid.lap_bid_fused_pallas``).
    ``tb_scale=0`` is the plain (un-perturbed) bid on ``-cost``.

    Shapes: ``cost`` (n, m) with ``prices`` (m,), or batched ``cost``
    (B, n, m) with ``prices`` (B, m); both floating.  ``tb_scale`` is a
    scalar (or (B,) when batched).  Returns ``(best_v, best_j, second_v)``,
    each (n,) / (B, n).
    """
    _check_bid_args("lap_bid_fused", cost, prices)
    if cost.ndim == 3:
        return lap_bid_fused_pallas_batched(
            cost, prices, tb_scale, interpret=_default_interpret()
        )
    return lap_bid_fused_pallas(cost, prices, tb_scale, interpret=_default_interpret())


def migration_cost_matrix(
    slots_u, slots_v, num_gpus_of: dict[int, int]
) -> np.ndarray:
    """Algorithm-3 cost matrix via the Pallas kernel.

    ``slots_u``/``slots_v``: (U, MAX_PACK) int arrays of job ids (-1 empty).
    Returns a host (U, V) float64 matrix.
    """
    slots_u = np.asarray(slots_u)
    slots_v = np.asarray(slots_v)
    _require(
        slots_u.ndim == 2 and slots_v.ndim == 2,
        "migration_cost_matrix: slots must be (U, MAX_PACK) / (V, MAX_PACK), "
        f"got shapes {slots_u.shape} and {slots_v.shape}",
    )
    _require(
        slots_u.shape[1] == slots_v.shape[1],
        "migration_cost_matrix: slots_u and slots_v disagree on MAX_PACK "
        f"({slots_u.shape[1]} vs {slots_v.shape[1]})",
    )
    _require(
        np.issubdtype(slots_u.dtype, np.integer)
        and np.issubdtype(slots_v.dtype, np.integer),
        "migration_cost_matrix: slots must hold integer job ids, got "
        f"dtypes {slots_u.dtype} and {slots_v.dtype}",
    )
    max_id = max(num_gpus_of, default=0)
    lookup = np.zeros(max_id + 2, dtype=np.float32)
    for j, g in num_gpus_of.items():
        lookup[j] = 1.0 / (2.0 * g)
    w_u = lookup[slots_u]  # EMPTY=-1 hits the zero tail
    w_v = lookup[slots_v]
    out = migration_cost_pallas(
        jnp.asarray(slots_u, jnp.int32),
        jnp.asarray(slots_v, jnp.int32),
        jnp.asarray(w_u),
        jnp.asarray(w_v),
        interpret=_default_interpret(),
    )
    return np.asarray(out, dtype=np.float64)  # tessalint: sync-ok(this wrapper's documented contract is a host float64 matrix; one readout of the kernel output)


def flash_decode(q, k, v, valid_len):
    """Single-token GQA decode attention; q (B,H,D), cache k/v (B,S,KV,D).

    ``H`` must be a multiple of ``KV`` (query-head groups share a KV
    head); ``valid_len`` is (B,) integer occupancy of the ring buffer.
    Returns (B, H, D).
    """
    from repro.kernels.flash_decode import flash_decode_pallas

    _require(
        q.ndim == 3 and k.ndim == 4 and v.ndim == 4,
        f"flash_decode: want q (B,H,D), k/v (B,S,KV,D); got q {q.shape}, "
        f"k {k.shape}, v {v.shape}",
    )
    _require(
        k.shape == v.shape,
        f"flash_decode: k/v cache shapes differ ({k.shape} vs {v.shape})",
    )
    _require(
        q.shape[0] == k.shape[0] and q.shape[-1] == k.shape[-1],
        f"flash_decode: q {q.shape} and cache {k.shape} disagree on "
        "batch or head dim",
    )
    _require(
        q.shape[1] % k.shape[2] == 0,
        f"flash_decode: H={q.shape[1]} must be a multiple of KV={k.shape[2]}",
    )
    return flash_decode_pallas(q, k, v, valid_len, interpret=_default_interpret())


def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True
) -> jax.Array:
    """Causal flash attention; q/k/v (B, H, S, D) or (BH, S, D).

    All three inputs must share one shape; returns that shape.
    """
    _require(
        q.ndim in (3, 4),
        f"flash_attention: q must be (B,H,S,D) or (BH,S,D), got {q.shape}",
    )
    _require(
        q.shape == k.shape == v.shape,
        f"flash_attention: q/k/v shapes differ: {q.shape}, {k.shape}, {v.shape}",
    )
    squeeze = False
    if q.ndim == 4:
        b, h, s, d = q.shape
        q = q.reshape(b * h, s, d)
        k = k.reshape(b * h, s, d)
        v = v.reshape(b * h, s, d)
        squeeze = True
    out = flash_attention_pallas(q, k, v, causal=causal, interpret=_default_interpret())
    if squeeze:
        out = out.reshape(b, h, s, d)
    return out
