"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU (this CPU container) and False on
TPU; every wrapper has identical semantics to its ``ref.py`` oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.lap_bid import (
    lap_bid_fused_pallas,
    lap_bid_fused_pallas_batched,
    lap_bid_pallas,
    lap_bid_pallas_batched,
)
from repro.kernels.migration_cost import migration_cost_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def lap_bid_top2(vals: jax.Array):
    """Auction bid step on a precomputed (benefit - price) matrix.

    Drop-in replacement for ``ref.lap_bid_top2`` (kept as the parity-test
    oracle surface; ``auction_lap(use_kernel=True)`` now calls
    :func:`lap_bid` directly so the price subtraction fuses into the
    kernel's tiled sweep instead of materialising ``vals`` per bid
    round).  Accepts (n, m) or an explicit (B, n, m) stack, which routes
    to :func:`lap_bid_pallas_batched`.
    NOTE: the auction fan-out does NOT reach the 3-D branch — under
    ``jax.vmap`` each instance is a 2-D tracer and vmap's pallas batching
    rule lifts the 2-D kernel into one batched ``pallas_call`` itself;
    the explicit branch serves direct 3-D callers and parity tests.
    """
    if vals.ndim == 3:
        return lap_bid_pallas_batched(
            vals,
            jnp.zeros(vals.shape[::2], vals.dtype),
            interpret=_default_interpret(),
        )
    return lap_bid_pallas(
        vals, jnp.zeros((vals.shape[-1],), vals.dtype), interpret=_default_interpret()
    )


def lap_bid(a: jax.Array, prices: jax.Array):
    if a.ndim == 3:
        return lap_bid_pallas_batched(a, prices, interpret=_default_interpret())
    return lap_bid_pallas(a, prices, interpret=_default_interpret())


def lap_bid_fused(cost: jax.Array, prices: jax.Array, tb_scale=0.0):
    """Fused-benefit bid step on a raw COST matrix (2-D or batched 3-D):
    the ``-cost`` negation and the positional tie-break ramp assemble
    inside the kernel's tiled sweep, so no perturbed benefit matrix is
    ever materialised in HBM (see ``lap_bid.lap_bid_fused_pallas``).
    ``tb_scale=0`` is the plain (un-perturbed) bid on ``-cost``."""
    if cost.ndim == 3:
        return lap_bid_fused_pallas_batched(
            cost, prices, tb_scale, interpret=_default_interpret()
        )
    return lap_bid_fused_pallas(cost, prices, tb_scale, interpret=_default_interpret())


def migration_cost_matrix(
    slots_u, slots_v, num_gpus_of: dict[int, int]
) -> np.ndarray:
    """Algorithm-3 cost matrix via the Pallas kernel.

    ``slots_u``/``slots_v``: (U, MAX_PACK) int arrays of job ids (-1 empty).
    """
    slots_u = np.asarray(slots_u)
    slots_v = np.asarray(slots_v)
    max_id = max(num_gpus_of, default=0)
    lookup = np.zeros(max_id + 2, dtype=np.float32)
    for j, g in num_gpus_of.items():
        lookup[j] = 1.0 / (2.0 * g)
    w_u = lookup[slots_u]  # EMPTY=-1 hits the zero tail
    w_v = lookup[slots_v]
    out = migration_cost_pallas(
        jnp.asarray(slots_u, jnp.int32),
        jnp.asarray(slots_v, jnp.int32),
        jnp.asarray(w_u),
        jnp.asarray(w_v),
        interpret=_default_interpret(),
    )
    return np.asarray(out, dtype=np.float64)


def flash_decode(q, k, v, valid_len):
    """Single-token GQA decode attention; q (B,H,D), cache k/v (B,S,KV,D)."""
    from repro.kernels.flash_decode import flash_decode_pallas

    return flash_decode_pallas(q, k, v, valid_len, interpret=_default_interpret())


def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True
) -> jax.Array:
    """Causal flash attention; q/k/v (B, H, S, D) or (BH, S, D)."""
    squeeze = False
    if q.ndim == 4:
        b, h, s, d = q.shape
        q = q.reshape(b * h, s, d)
        k = k.reshape(b * h, s, d)
        v = v.reshape(b * h, s, d)
        squeeze = True
    out = flash_attention_pallas(q, k, v, causal=causal, interpret=_default_interpret())
    if squeeze:
        out = out.reshape(b, h, s, d)
    return out
