"""Pure-jnp oracles for every Pallas kernel (the `ref.py` contract).

These are the semantics the kernels must reproduce; tests sweep shapes and
dtypes asserting allclose between kernel (interpret mode) and oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def lap_bid_top2(vals: jnp.ndarray):
    """Row-wise (best value, best index, second-best value).

    ``vals``: (n, m) benefit-minus-price matrix.  Ties broken toward the
    lowest column index (matching jnp.argmax).
    """
    best_j = jnp.argmax(vals, axis=-1)
    best_v = jnp.take_along_axis(vals, best_j[..., None], axis=-1)[..., 0]
    masked = jnp.where(
        jax.nn.one_hot(best_j, vals.shape[-1], dtype=bool), NEG_INF, vals
    )
    second_v = jnp.max(masked, axis=-1)
    return best_v, best_j.astype(jnp.int32), second_v


def lap_bid_fused_top2(vals_or_cost, prices=None, tb_scale=0.0):
    """Oracle for the fused-benefit bid step (``lap_bid_fused_pallas``).

    ``vals_or_cost``: (n, m) raw COST matrix; the benefit is assembled
    here exactly as the kernel does per tile —
    ``(tb_scale * (i+1)^2 * (j+1) - cost) - p`` with global indices and
    matching operation order, so integer costs + power-of-two scales give
    bit-identical f32 values.
    """
    cost = vals_or_cost
    n, m = cost.shape[-2], cost.shape[-1]
    if prices is None:
        prices = jnp.zeros(cost.shape[:-2] + (m,), cost.dtype)
    gi = (jnp.arange(n, dtype=cost.dtype) + 1.0)[:, None]
    gj = (jnp.arange(m, dtype=cost.dtype) + 1.0)[None, :]
    tb = jnp.asarray(tb_scale, cost.dtype)
    vals = (tb * (gi * gi) * gj - cost) - prices[..., None, :]
    return lap_bid_top2(vals)


def migration_cost(
    slots_u: jnp.ndarray,
    slots_v: jnp.ndarray,
    w_u: jnp.ndarray,
    w_v: jnp.ndarray,
):
    """Algorithm 3 cost matrix.

    ``slots_u``: (U, P) int job ids (-1 empty), ``slots_v``: (V, P);
    ``w_u``/``w_v``: per-slot weights 1/(2*num_gpus) with 0 for empty slots.
    Returns (U, V):  C[u,v] = sum_a w_u[u,a]*[su[u,a] not in sv[v]]
                             + sum_b w_v[v,b]*[sv[v,b] not in su[u]].
    """
    su = slots_u[:, None, :, None]  # (U,1,P,1)
    sv = slots_v[None, :, None, :]  # (1,V,1,P)
    eq = su == sv  # (U,V,P,P)
    u_in_v = eq.any(axis=-1)  # (U,V,P)
    v_in_u = eq.any(axis=-2)  # (U,V,P)
    cost_out = (w_u[:, None, :] * (~u_in_v)).sum(-1)
    cost_in = (w_v[None, :, :] * (~v_in_u)).sum(-1)
    return cost_out + cost_in


def flash_decode(
    q: jnp.ndarray,          # (B, H, D)
    k: jnp.ndarray,          # (B, S, KV, D)
    v: jnp.ndarray,          # (B, S, KV, D)
    valid_len,               # scalar int
):
    """Single-query GQA attention over a cache, slots >= valid_len masked."""
    b, h, d = q.shape
    s, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, d).astype(jnp.float32)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(jnp.float32))
    logits = logits / (d**0.5)
    mask = jnp.arange(s)[None, None, None, :] < valid_len
    logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


def flash_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, causal: bool = True,
    scale: float | None = None,
):
    """Naive softmax attention oracle.

    q/k/v: (BH, S, D) — batch*heads flattened.  fp32 accumulation.
    """
    bh, s, d = q.shape
    if scale is None:
        scale = 1.0 / (d**0.5)
    logits = jnp.einsum(
        "bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        logits = jnp.where(mask[None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
