"""Shared ragged-edge tile masking for Pallas kernels.

Several kernels stream a logically-ragged array through fixed-size VMEM
tiles: ``flash_decode`` masks cache slots beyond ``valid_len`` and the
``lap_bid`` family masks benefit columns beyond the instance's real column
count.  Both used to hand-roll the same ``broadcasted_iota`` + ``where``
dance (and ``lap_bid`` additionally *materialised* a NEG_INF-filled padded
copy of its input in HBM).  This module is the one implementation both
kernels now share:

* :func:`tile_col_ids` — global column ids of one (..., BC) tile given the
  tile's column offset (TPU requires >= 2-D iota, which this wraps).
* :func:`mask_ragged_cols` — replace entries whose global column id is
  ``>= valid_cols`` with ``fill``.  ``valid_cols`` may be a static Python
  int (shape-derived, as in ``lap_bid``) or a traced scalar read from SMEM
  (runtime occupancy, as in ``flash_decode``'s ring buffer).

Because masking happens *inside* the kernel against column ids, callers can
pad their inputs with plain zeros (``jnp.pad``) instead of materialising a
sentinel-filled copy — the padding-free-bids contract of the rectangular
auction path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def tile_col_ids(shape: tuple, col_offset) -> jax.Array:
    """Global column ids for a tile of ``shape`` whose minor (last) axis
    starts at ``col_offset``.  Uses ``broadcasted_iota`` (>= 2-D on TPU).

    ``shape`` must be a static tuple of >= 2 dims (the TPU iota floor);
    returns an int32 array of ``shape``.
    """
    if len(shape) < 2:
        raise ValueError(
            f"tile_col_ids: TPU iota needs a >= 2-D tile, got shape {shape}"
        )
    return jax.lax.broadcasted_iota(jnp.int32, shape, len(shape) - 1) + col_offset


def mask_ragged_cols(x: jax.Array, col_offset, valid_cols, fill) -> jax.Array:
    """Mask the ragged column edge of one tile.

    ``x``: (..., BC) tile whose minor axis holds global columns
    ``[col_offset, col_offset + BC)``.  Entries at global column id
    ``>= valid_cols`` become ``fill``; the rest pass through unchanged.
    ``valid_cols`` may be static (int) or traced (SMEM scalar).
    """
    if x.ndim < 2:
        raise ValueError(
            f"mask_ragged_cols: tile must be >= 2-D (TPU iota floor), got {x.shape}"
        )
    return jnp.where(tile_col_ids(x.shape, col_offset) < valid_cols, x, fill)
