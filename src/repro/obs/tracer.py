"""Structured span tracing for the scheduler's decision pipeline.

A :class:`Tracer` records a tree of named, attributed spans per thread:
``span("decide") > span("policy_sort") > span("migrate.fused") > ...``.
Span *structure* (names, nesting, attribute values, per-thread sequence)
is deterministic for a seeded run; wall-clock timings ride along but are
excluded from :meth:`Tracer.fingerprint` so two identical seeded runs
hash identically even though their timings differ.

Design constraints (the instrument-without-perturbing contract):

* **stdlib only** — this module must never import jax/numpy, so the obs
  layer cannot originate device work or device→host syncs; tessalint's
  ``sync``/``det`` passes are scoped over ``src/repro/obs/`` to keep it
  that way.
* **monotonic clock only** — ``time.perf_counter`` (exempted by the
  ``det`` pass) is the sole time source; no wall-clock reads.
* **thread-correct** — the speculative-prewarm thread traces into its
  own root list via ``threading.local`` span stacks; tids are mapped to
  small stable ints in first-seen order (main thread is always 0).
* **no-op when disabled** — :data:`NULL_TRACER` swallows every call; the
  instrumented code paths take it by default so a run with ``obs=None``
  executes the identical decision sequence.
"""

from __future__ import annotations

import hashlib
import json
import threading
import time
from typing import Any, Dict, List, Optional


class Span:
    """One node of the span tree.  Attribute values must be JSON-safe
    (ints/floats/strs/bools/lists) — they are part of the deterministic
    fingerprint, so only put *decision-derived* values here, never
    wall-clock readings (timings live on the dedicated fields)."""

    __slots__ = ("name", "attrs", "children", "t0", "dur_s", "seq", "tid")

    def __init__(self, name: str, attrs: Dict[str, Any], seq: int, tid: int):
        self.name = name
        self.attrs = dict(attrs)
        self.children: List["Span"] = []
        self.t0 = 0.0
        self.dur_s = 0.0
        self.seq = seq
        self.tid = tid

    def annotate(self, **attrs: Any) -> None:
        """Attach attributes after the span opened (e.g. outcome counts
        known only once the stage finished)."""
        self.attrs.update(attrs)

    # -- deterministic view (no timings) ------------------------------- #
    def structure(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {"name": self.name, "tid": self.tid, "seq": self.seq}
        if self.attrs:
            d["attrs"] = {k: self.attrs[k] for k in sorted(self.attrs)}
        if self.children:
            d["children"] = [c.structure() for c in self.children]
        return d

    # -- full view (timings included) ---------------------------------- #
    def to_dict(self) -> Dict[str, Any]:
        d = self.structure()
        d["t0_s"] = self.t0
        d["dur_s"] = self.dur_s
        if self.children:
            d["children"] = [c.to_dict() for c in self.children]
        return d


class _SpanContext:
    """Context manager opening/closing one span on the tracer."""

    __slots__ = ("_tracer", "_span")

    def __init__(self, tracer: "Tracer", span: Span):
        self._tracer = tracer
        self._span = span

    def __enter__(self) -> Span:
        return self._span

    def __exit__(self, exc_type, exc, tb) -> None:
        self._tracer._close(self._span)


class Tracer:
    """Collects nested spans across threads.

    Usage::

        with tracer.span("decide", round=3) as sp:
            with tracer.span("policy_sort"):
                ...
            sp.annotate(degrade="none")
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._local = threading.local()
        self._roots: List[Span] = []
        self._tids: Dict[int, int] = {threading.get_ident(): 0}
        self._seq = 0
        # epoch so exported timestamps are small offsets, not raw
        # perf_counter readings
        self._epoch = time.perf_counter()

    # ------------------------------------------------------------------ #
    def _tid(self) -> int:
        ident = threading.get_ident()
        with self._lock:
            if ident not in self._tids:
                self._tids[ident] = len(self._tids)
            return self._tids[ident]

    def _stack(self) -> List[Span]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def span(self, name: str, **attrs: Any) -> _SpanContext:
        with self._lock:
            seq = self._seq
            self._seq += 1
        sp = Span(name, attrs, seq, self._tid())
        sp.t0 = time.perf_counter() - self._epoch
        stack = self._stack()
        if stack:
            stack[-1].children.append(sp)
        else:
            with self._lock:
                self._roots.append(sp)
        stack.append(sp)
        return _SpanContext(self, sp)

    def _close(self, sp: Span) -> None:
        sp.dur_s = (time.perf_counter() - self._epoch) - sp.t0
        stack = self._stack()
        # close any children left open by an exception, then the span
        while stack and stack[-1] is not sp:
            stack.pop()
        if stack:
            stack.pop()

    # ------------------------------------------------------------------ #
    def roots(self) -> List[Span]:
        """Completed + in-flight root spans, ordered by (tid, seq) so the
        export is stable regardless of thread interleaving."""
        with self._lock:
            return sorted(self._roots, key=lambda s: (s.tid, s.seq))

    def structure(self) -> List[Dict[str, Any]]:
        """The deterministic (timing-free) span forest."""
        return [r.structure() for r in self.roots()]

    def fingerprint(self) -> str:
        """sha256 over the canonical JSON of the timing-free span forest.
        Equal across two identical seeded runs; any divergence in span
        names, nesting, attributes or per-thread ordering changes it."""
        blob = json.dumps(self.structure(), sort_keys=True, separators=(",", ":"))
        return hashlib.sha256(blob.encode()).hexdigest()

    def reset(self) -> None:
        with self._lock:
            self._roots = []
            self._seq = 0
            self._tids = {threading.get_ident(): 0}
            self._epoch = time.perf_counter()


class _NullSpan:
    """Inert stand-in for :class:`Span` — every instrumentation point can
    unconditionally call ``annotate`` without an obs-enabled check."""

    __slots__ = ()

    def annotate(self, **attrs: Any) -> None:
        pass

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        pass


class NullTracer:
    """No-op tracer: the default wiring when observability is disabled.
    ``span(...)`` allocates nothing and records nothing, so the traced
    code path is byte-identical in behaviour to the uninstrumented one."""

    _NULL_SPAN = _NullSpan()

    def span(self, name: str, **attrs: Any) -> _NullSpan:
        return self._NULL_SPAN

    def roots(self) -> List[Span]:
        return []

    def structure(self) -> List[Dict[str, Any]]:
        return []

    def fingerprint(self) -> str:
        return hashlib.sha256(b"[]").hexdigest()

    def reset(self) -> None:
        pass


#: module-level no-op singleton — instrumented call sites do
#: ``tracer = obs.tracer if obs is not None else NULL_TRACER``.
NULL_TRACER = NullTracer()


def tracer_of(obs: Optional[Any]):
    """The tracer of an ``Observability`` bundle, or :data:`NULL_TRACER`
    when obs is disabled (``None``) — the one-liner every instrumented
    module uses."""
    return obs.tracer if obs is not None else NULL_TRACER
