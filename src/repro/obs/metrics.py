"""Counters, gauges and exact-observation histograms.

The registry is the single aggregation substrate for the simulator's
telemetry: per-round ``match_stats`` deltas, degradation-ladder tags,
fault/lost-work counters and decide-stage latencies all land here, and
``SimResult``'s legacy telemetry fields are *views* over it.

Histograms store every observation exactly (bounded by rounds-per-run,
so a few thousand floats at most) and compute nearest-rank percentiles —
p50/p95/p99 are exact order statistics, not bucket interpolations, which
is what lets the tests pin them on known distributions.

A histogram created with ``timing=True`` is excluded from
:meth:`MetricsRegistry.deterministic_snapshot` — wall-clock latencies
are never part of bit-identity or CI gating.

stdlib only; see :mod:`repro.obs.tracer` for the contract.
"""

from __future__ import annotations

import math
import threading
from typing import Any, Dict, List, Optional


class Counter:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, delta: int = 1) -> None:
        self.value += delta


class Gauge:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = value


class Histogram:
    """Exact-observation histogram with nearest-rank percentiles."""

    __slots__ = ("name", "timing", "values")

    def __init__(self, name: str, timing: bool = False):
        self.name = name
        #: timing histograms hold wall-clock observations and are excluded
        #: from deterministic snapshots / CI gates
        self.timing = timing
        self.values: List[float] = []

    def observe(self, value: float) -> None:
        self.values.append(float(value))

    @property
    def count(self) -> int:
        return len(self.values)

    @property
    def total(self) -> float:
        return float(sum(self.values))

    def percentile(self, p: float) -> float:
        """Nearest-rank percentile: the ``ceil(p/100 * n)``-th smallest
        observation (1-indexed).  Exact — e.g. over 1..100, p50 is 50.0,
        p95 is 95.0, p99 is 99.0.  Raises on an empty histogram."""
        if not self.values:
            raise ValueError(f"histogram {self.name!r} has no observations")
        if not 0.0 < p <= 100.0:
            raise ValueError(f"percentile must be in (0, 100], got {p}")
        ordered = sorted(self.values)
        rank = max(1, math.ceil(p / 100.0 * len(ordered)))
        return ordered[rank - 1]

    def summary(self) -> Dict[str, float]:
        if not self.values:
            return {"count": 0}
        return {
            "count": self.count,
            "total": self.total,
            "min": min(self.values),
            "max": max(self.values),
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
        }


class MetricsRegistry:
    """Get-or-create registry of named counters/gauges/histograms.

    Thread-safe creation (the prewarm thread may race the sim loop on
    first touch); increments on an existing instrument are plain int/list
    ops under the GIL, matching the single-writer-per-metric usage here.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    # -- get-or-create -------------------------------------------------- #
    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter(name)
            return c

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge(name)
            return g

    def histogram(self, name: str, timing: bool = False) -> Histogram:
        with self._lock:
            h = self._histograms.get(name)
            if h is None:
                h = self._histograms[name] = Histogram(name, timing=timing)
            return h

    # -- read-only views ------------------------------------------------ #
    def counter_value(self, name: str, default: int = 0) -> int:
        c = self._counters.get(name)
        return c.value if c is not None else default

    def counters_with_prefix(self, prefix: str) -> Dict[str, int]:
        """``{suffix: value}`` for every counter named ``prefix + suffix``."""
        return {
            name[len(prefix):]: c.value
            for name, c in self._counters.items()
            if name.startswith(prefix)
        }

    def histogram_values(self, name: str) -> List[float]:
        h = self._histograms.get(name)
        return list(h.values) if h is not None else []

    # -- snapshots ------------------------------------------------------ #
    def snapshot(self) -> Dict[str, Any]:
        """Everything, timing histograms summarised alongside the rest."""
        return {
            "counters": {n: c.value for n, c in sorted(self._counters.items())},
            "gauges": {n: g.value for n, g in sorted(self._gauges.items())},
            "histograms": {
                n: h.summary() for n, h in sorted(self._histograms.items())
            },
        }

    def deterministic_snapshot(self) -> Dict[str, Any]:
        """The snapshot minus wall-clock content: counters, gauges and
        non-timing histograms only.  Two identical seeded runs produce
        equal deterministic snapshots; this is what CI gates compare."""
        return {
            "counters": {n: c.value for n, c in sorted(self._counters.items())},
            "gauges": {n: g.value for n, g in sorted(self._gauges.items())},
            "histograms": {
                n: h.summary()
                for n, h in sorted(self._histograms.items())
                if not h.timing
            },
        }

    def reset(self) -> None:
        with self._lock:
            self._counters = {}
            self._gauges = {}
            self._histograms = {}


class Observability:
    """The bundle a caller passes down as ``obs=``: one tracer + one
    metrics registry, shared by the simulator, scheduler, fused planner
    and matching engine for the duration of a run."""

    def __init__(
        self,
        tracer: Optional["Tracer"] = None,
        metrics: Optional[MetricsRegistry] = None,
    ):
        from repro.obs.tracer import Tracer

        self.tracer = tracer if tracer is not None else Tracer()
        self.metrics = metrics if metrics is not None else MetricsRegistry()

    def reset(self) -> None:
        self.tracer.reset()
        self.metrics.reset()
