"""Unified observability layer: structured round tracing + metrics.

Opt-in (``obs=None`` everywhere by default) and provably inert: with obs
disabled every instrumented call site routes through no-op singletons
and the decision sequence is bit-identical to the uninstrumented path;
with obs enabled, only host-side Python bookkeeping runs — no device
reads, no decision inputs touched.

Entry point::

    from repro.obs import Observability
    obs = Observability()
    sim = Simulator(..., obs=obs)          # or scheduler.decide(..., via obs=)
    sim.run()
    write_chrome_trace(obs.tracer, "trace.json")   # load in Perfetto
    obs.metrics.histogram("decide.latency_s").percentile(99)
"""

from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Observability,
)
from repro.obs.tracer import NULL_TRACER, NullTracer, Span, Tracer, tracer_of
from repro.obs.trace_export import (
    OBS_SCHEMA_VERSION,
    to_chrome_trace,
    to_obs_doc,
    validate_chrome_trace,
    validate_obs_doc,
    write_chrome_trace,
    write_obs_doc,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Observability",
    "NULL_TRACER",
    "NullTracer",
    "Span",
    "Tracer",
    "tracer_of",
    "OBS_SCHEMA_VERSION",
    "to_chrome_trace",
    "to_obs_doc",
    "validate_chrome_trace",
    "validate_obs_doc",
    "write_chrome_trace",
    "write_obs_doc",
]
