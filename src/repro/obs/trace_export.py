"""Exporters for the observability layer.

Two formats:

* **Chrome trace / Perfetto JSON** (:func:`to_chrome_trace`): the
  ``traceEvents`` array of complete (``"ph": "X"``) events that
  ``chrome://tracing`` and https://ui.perfetto.dev load directly.
  Timestamps/durations are microseconds relative to the tracer's epoch;
  span attributes ride in ``args``.

* **``tesserae-obs-v1``** (:func:`to_obs_doc`): the repo's own versioned
  envelope — schema version, the deterministic span-forest fingerprint,
  the full span forest (timings included) and a metrics snapshot.  The
  deterministic *subset* of the doc (fingerprint + structure + the
  non-timing metrics) is equal across identical seeded runs.

Both have matching ``validate_*`` functions used by the tests and the
obs-smoke CI lane.  stdlib only.
"""

from __future__ import annotations

import json
from typing import Any, Dict, List

from repro.obs.metrics import MetricsRegistry
from repro.obs.tracer import Span, Tracer

#: version tag of the exported observability document.
OBS_SCHEMA_VERSION = "tesserae-obs-v1"


# ---------------------------------------------------------------------- #
# Chrome trace / Perfetto
# ---------------------------------------------------------------------- #
def _emit_events(sp: Span, out: List[Dict[str, Any]]) -> None:
    ev: Dict[str, Any] = {
        "name": sp.name,
        "ph": "X",
        "ts": round(sp.t0 * 1e6, 3),
        "dur": round(sp.dur_s * 1e6, 3),
        "pid": 0,
        "tid": sp.tid,
    }
    if sp.attrs:
        ev["args"] = {k: sp.attrs[k] for k in sorted(sp.attrs)}
    out.append(ev)
    for c in sp.children:
        _emit_events(c, out)


def to_chrome_trace(tracer: Tracer) -> Dict[str, Any]:
    events: List[Dict[str, Any]] = []
    for root in tracer.roots():
        _emit_events(root, events)
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {"schema": OBS_SCHEMA_VERSION},
    }


def write_chrome_trace(tracer: Tracer, path: str) -> None:
    with open(path, "w") as f:
        json.dump(to_chrome_trace(tracer), f)


def validate_chrome_trace(doc: Dict[str, Any]) -> List[str]:
    """Structural check that a Perfetto/chrome://tracing load will accept
    the document.  Returns a list of problems (empty = valid)."""
    problems: List[str] = []
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            problems.append(f"event {i}: bad name")
        if ev.get("ph") != "X":
            problems.append(f"event {i}: ph != 'X'")
        for k in ("ts", "dur"):
            if not isinstance(ev.get(k), (int, float)) or ev[k] < 0:
                problems.append(f"event {i}: bad {k}")
        for k in ("pid", "tid"):
            if not isinstance(ev.get(k), int):
                problems.append(f"event {i}: bad {k}")
        if "args" in ev and not isinstance(ev["args"], dict):
            problems.append(f"event {i}: args not an object")
    return problems


# ---------------------------------------------------------------------- #
# tesserae-obs-v1
# ---------------------------------------------------------------------- #
def to_obs_doc(tracer: Tracer, metrics: MetricsRegistry) -> Dict[str, Any]:
    return {
        "version": OBS_SCHEMA_VERSION,
        "fingerprint": tracer.fingerprint(),
        "spans": [r.to_dict() for r in tracer.roots()],
        "metrics": metrics.snapshot(),
        "deterministic_metrics": metrics.deterministic_snapshot(),
    }


def write_obs_doc(tracer: Tracer, metrics: MetricsRegistry, path: str) -> None:
    with open(path, "w") as f:
        json.dump(to_obs_doc(tracer, metrics), f)


def _check_span_dict(d: Any, where: str, problems: List[str]) -> None:
    if not isinstance(d, dict):
        problems.append(f"{where}: not an object")
        return
    if not isinstance(d.get("name"), str) or not d["name"]:
        problems.append(f"{where}: bad name")
    for k in ("tid", "seq"):
        if not isinstance(d.get(k), int):
            problems.append(f"{where}: bad {k}")
    for k in ("t0_s", "dur_s"):
        if not isinstance(d.get(k), (int, float)):
            problems.append(f"{where}: bad {k}")
    for i, c in enumerate(d.get("children", [])):
        _check_span_dict(c, f"{where}.children[{i}]", problems)


def validate_obs_doc(doc: Dict[str, Any]) -> List[str]:
    """Structural check of a ``tesserae-obs-v1`` document.  Returns a
    list of problems (empty = valid)."""
    problems: List[str] = []
    if doc.get("version") != OBS_SCHEMA_VERSION:
        problems.append(f"version != {OBS_SCHEMA_VERSION!r}")
    fp = doc.get("fingerprint")
    if not (isinstance(fp, str) and len(fp) == 64):
        problems.append("fingerprint missing or not a sha256 hex digest")
    spans = doc.get("spans")
    if not isinstance(spans, list):
        problems.append("spans missing or not a list")
    else:
        for i, sp in enumerate(spans):
            _check_span_dict(sp, f"spans[{i}]", problems)
    for key in ("metrics", "deterministic_metrics"):
        m = doc.get(key)
        if not isinstance(m, dict):
            problems.append(f"{key} missing or not an object")
            continue
        for section in ("counters", "gauges", "histograms"):
            if not isinstance(m.get(section), dict):
                problems.append(f"{key}.{section} missing or not an object")
    return problems
