"""Production mesh construction (TPU v5e target).

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; callers (dryrun.py) must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` BEFORE the first
jax import to fabricate the placeholder devices.
"""

from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).  Multi-pod: 2 pods of
    256 = 512 chips with a leading "pod" axis (data-parallel across the
    inter-pod DCN/ICI boundary)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes_of(mesh) -> Tuple[str, ...]:
    """Names of the data-parallel axes (pod included when present)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_smoke_mesh():
    """1-device mesh for CPU smoke runs of the sharded code path."""
    return jax.make_mesh((1, 1), ("data", "model"))
