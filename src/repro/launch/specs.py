"""Input shapes, ShapeDtypeStruct stand-ins, and per-leaf sharding rules.

``input_specs(cfg, shape)`` builds weak-type-correct ShapeDtypeStructs for
every model input — no device allocation; ``.lower()`` consumes them
directly.  ``logical_axes_for(path, leaf)`` names each param/optimizer/cache
leaf's logical axes; :class:`repro.launch.pspec.ShardingRules` maps those to
mesh axes with divisibility fallbacks (e.g. qwen2-vl's 12 heads stay
replicated on a 16-way model axis while its 8960-wide FFN shards).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

# --------------------------------------------------------------------------- #
# The four assigned input shapes
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def token_dtype():
    return jnp.int32


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model-input stand-ins for one (arch, shape) pair."""
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "decode":
        return {"tokens": sds((b, 1), token_dtype())}

    batch: Dict[str, jax.ShapeDtypeStruct] = {
        "tokens": sds((b, s), token_dtype()),
    }
    if shape.kind == "train":
        batch["targets"] = sds((b, s), token_dtype())
    if cfg.frontend == "vision":
        batch["image_embeds"] = sds((b, cfg.frontend_len, cfg.d_model), jnp.float32)
        if cfg.mrope:
            batch["mrope_positions"] = sds(
                (3, b, s + cfg.frontend_len), token_dtype()
            )
    elif cfg.frontend == "audio":
        batch["audio_frames"] = sds((b, cfg.frontend_len, cfg.d_model), jnp.float32)
    return batch


def batch_logical_axes(name: str, ndim: int) -> Tuple[Optional[str], ...]:
    if name == "mrope_positions":
        return (None, "batch") + (None,) * (ndim - 2)
    return ("batch",) + (None,) * (ndim - 1)


# --------------------------------------------------------------------------- #
# Parameter / optimizer / cache leaf -> logical axes
# --------------------------------------------------------------------------- #
_RULES = [
    # (regex on the dict path, logical axes WITHOUT the stacked-layer dim)
    (r"embed$", ("vocab", "fsdp")),
    (r"lm_head$", ("fsdp", "vocab")),
    (r"(final_norm|enc_norm|norm\d?|norm_x|q_norm|k_norm|kv_norm)$", None),  # 1-D: replicate
    # attention
    (r"attn.*wq$", ("fsdp", "heads", None)),
    (r"attn.*w[kv]$", ("fsdp", "kv_heads", None)),
    (r"attn.*wo$", ("heads_flat", "fsdp")),
    (r"attn.*wkv_a$", ("fsdp", None)),
    (r"attn.*wkv_b$", (None, "heads", None)),
    # dense ffn
    (r"(ffn|shared).*w_(gate|up)$", ("fsdp", "ff")),
    (r"(ffn|shared).*w_down$", ("ff", "fsdp")),
    # moe
    (r"moe.*router$", ("fsdp", None)),
    (r"moe\.w_(gate|up)$", ("expert", "fsdp", None)),
    (r"moe\.w_down$", ("expert", None, "fsdp")),
    # mamba
    (r"mamba\.in_proj$", ("fsdp", "ssm_inner")),
    (r"mamba\.out_proj$", ("ssm_inner", "fsdp")),
    (r"mamba\.(conv_w|conv_b|a_log|d_skip|dt_bias|norm)$", None),
    # zamba shared block concat projection
    (r"shared_attn\.in_proj$", ("fsdp", None)),
]


def logical_axes_for(path: str, shape: Tuple[int, ...]) -> Tuple[Optional[str], ...]:
    """Logical axes for a leaf.  Leaves under "layers"/"enc_layers"/...
    carry a leading stacked-layer dim (never sharded)."""
    stacked = bool(re.search(r"(^|\.)((dec_|enc_)?layers)\.", path))
    ndim = len(shape)
    body_ndim = ndim - 1 if stacked else ndim
    axes: Tuple[Optional[str], ...] = (None,) * body_ndim
    for pat, rule in _RULES:
        if re.search(pat, path):
            if rule is None:
                axes = (None,) * body_ndim
            else:
                axes = tuple(rule)[:body_ndim]
                if len(axes) < body_ndim:
                    axes = axes + (None,) * (body_ndim - len(axes))
            break
    else:
        axes = (None,) * body_ndim
    if stacked:
        axes = (None,) + axes
    return axes


def cache_logical_axes(path: str, shape: Tuple[int, ...]) -> Tuple[Optional[str], ...]:
    ndim = len(shape)
    if "cross_" in path:  # (L, B, F, KV, hd)
        return (None, "batch", None, "kv_heads", None)
    if path.endswith("state"):  # (L, B, H, P, N)
        return (None, "batch", "ssm_heads", None, None)
    if path.endswith("conv"):  # (L, B, W, CH)
        return (None, "batch", None, None)
    if path.endswith("ckv") or path.endswith("k_rope"):  # (L, B, S, r)
        return (None, "batch", "cache_seq", None)
    if path.endswith("k") or path.endswith("v"):  # (L, B, S, KV, hd)
        return (None, "batch", "cache_seq", "kv_heads", None)
    return (None,) * ndim


def tree_paths_and_leaves(tree):
    """[(dotted_path, leaf)] for a nested dict/pytree."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        out.append((".".join(parts), leaf))
    return out


def sharding_tree(tree, rules, axes_fn):
    """NamedSharding pytree matching ``tree`` via ``axes_fn(path, shape)``."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    shardings = []
    for path, leaf in flat:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
        dotted = ".".join(parts)
        axes = axes_fn(dotted, leaf.shape)
        shardings.append(rules.sharding_for(leaf.shape, axes))
    return jax.tree_util.tree_unflatten(treedef, shardings)


def bytes_per_device(tree, sharding_tree_) -> int:
    """Exact per-device bytes of a sharded pytree (shape/spec arithmetic)."""
    total = 0
    leaves = jax.tree.leaves(tree)
    shards = jax.tree.leaves(sharding_tree_, is_leaf=lambda x: hasattr(x, "spec"))
    for leaf, sh in zip(leaves, shards):
        n = int(np.prod(leaf.shape)) if len(leaf.shape) else 1
        denom = 1
        mesh = sh.mesh
        for dim_size, spec in zip(leaf.shape, tuple(sh.spec) + (None,) * len(leaf.shape)):
            if spec is None:
                continue
            names = spec if isinstance(spec, tuple) else (spec,)
            ax = 1
            for nm in names:
                ax *= dict(mesh.shape)[nm]
            denom *= ax
        total += n * np.dtype(leaf.dtype).itemsize // denom
    return total
