"""Logical-axis sharding: model code names axes, the launcher maps them.

Model code calls ``constrain(x, "batch", "seq", "embed")`` with *logical*
axis names; the launcher installs a :class:`ShardingRules` context mapping
logical names to physical mesh axes (or None).  Outside any context (CPU
smoke tests) ``constrain`` is a no-op, so the same model code runs
unsharded on one device and sharded on the 512-chip dry-run mesh.

Divisibility-safe: a logical axis is only sharded if its size divides the
mesh-axis extent (e.g. qwen2-vl's 12 heads are NOT sharded over a 16-way
model axis; its 8960-wide FFN is).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisName = Union[str, Tuple[str, ...], None]

#: default logical -> physical mapping for the production mesh.
#: "dp" expands to ("pod", "data") when a pod axis exists.
DEFAULT_RULES: Dict[str, AxisName] = {
    "batch": "dp",
    "seq": None,
    "embed": None,
    "ff": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "vocab": "model",
    "expert": "model",
    "expert_ff": None,
    "fsdp": "dp",      # weight dim sharded ZeRO-3 style over the data axis
    "heads_flat": "model",  # flattened H*head_dim dim (wo input)
    "ssm_inner": "model",   # mamba d_inner projections
    "ssm_heads": "model",   # mamba recurrent-state heads
    "layers": None,
    "state": None,
    "cache_seq": None,  # decode KV-cache sequence axis (context parallel)
    #: MoE dispatch buffers (E, C, D): experts over "model", capacity over
    #: the data axes — without this every device computes the FULL capacity
    #: of its expert shard (found via the H1 dot-level FLOPs audit,
    #: EXPERIMENTS.md §Perf).
    "capacity": "dp",
}


class ShardingRules:
    def __init__(
        self,
        mesh: Mesh,
        rules: Optional[Dict[str, AxisName]] = None,
        dp_axes: Tuple[str, ...] = ("data",),
    ):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if rules:
            self.rules.update(rules)
        self.dp_axes = dp_axes

    def _physical(self, logical: str) -> AxisName:
        phys = self.rules.get(logical)
        if phys == "dp":
            return self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]
        return phys

    def axis_size(self, phys: AxisName) -> int:
        if phys is None:
            return 1
        if isinstance(phys, tuple):
            out = 1
            for a in phys:
                out *= self.mesh.shape[a]
            return out
        return self.mesh.shape[phys]

    def spec_for(self, dim_sizes: Sequence[int], logical_axes: Sequence[Optional[str]]) -> P:
        parts = []
        used: set = set()
        for size, name in zip(dim_sizes, logical_axes):
            if name is None:
                parts.append(None)
                continue
            phys = self._physical(name)
            names = phys if isinstance(phys, tuple) else (phys,) if phys else ()
            # a mesh axis may appear at most once per spec: first dim wins
            # (e.g. seq-parallel "seq"->model beats "heads"->model inside one
            # activation, because it comes first in the constrain() call)
            if (
                phys is None
                or size % self.axis_size(phys) != 0
                or any(n in used for n in names)
            ):
                parts.append(None)
            else:
                parts.append(phys)
                used.update(names)
        return P(*parts)

    def sharding_for(self, dim_sizes, logical_axes) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(dim_sizes, logical_axes))


_state = threading.local()


def current_rules() -> Optional[ShardingRules]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield rules
    finally:
        _state.rules = prev


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Apply with_sharding_constraint per the active rules (no-op outside)."""
    rules = current_rules()
    if rules is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(
            f"constrain: {len(logical_axes)} axes for rank-{x.ndim} array"
        )
    spec = rules.spec_for(x.shape, logical_axes)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec)
    )
