"""Serving launcher: batched greedy decoding on a reduced config.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b \
        --batch 4 --prompt-len 16 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced, list_archs
from repro.models import get_model
from repro.serve.engine import ServeConfig, greedy_generate


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--context", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed), cfg)
    rng = np.random.default_rng(args.seed)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(args.batch, args.prompt_len)),
        jnp.int32,
    )
    sc = ServeConfig(batch_size=args.batch, context_len=args.context)
    t0 = time.perf_counter()
    out = greedy_generate(params, cfg, prompt, args.gen, sc)
    dt = time.perf_counter() - t0
    total_new = args.batch * args.gen
    print(f"arch={cfg.name} generated {total_new} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s, CPU reduced config)")
    print("sample:", np.asarray(out[0, : args.prompt_len + 8]).tolist())


if __name__ == "__main__":
    main()
