import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("REPRO_XLA_FLAGS_OVERRIDE")
    or "--xla_force_host_platform_device_count=512"
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

The two lines above run BEFORE any jax import (jax locks the device count
on first init): 512 placeholder host devices back both the 16x16 single-pod
mesh and the 2x16x16 multi-pod mesh.  Do NOT import this module from code
that needs the real 1-device view (smoke tests / benches) — run it as
``python -m repro.launch.dryrun --arch llama3-8b --shape train_4k``.

For each combination we build abstract inputs (ShapeDtypeStruct — zero
allocation), jit with explicit in/out shardings, ``.lower().compile()``,
print ``memory_analysis()`` / ``cost_analysis()``, and emit the roofline
terms as JSON for EXPERIMENTS.md.
"""

import argparse
import dataclasses
import json
import sys
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.configs.base import ModelConfig
from repro.launch.mesh import dp_axes_of, make_production_mesh
from repro.launch.pspec import ShardingRules, use_rules
from repro.launch.specs import (
    INPUT_SHAPES,
    InputShape,
    batch_logical_axes,
    bytes_per_device,
    cache_logical_axes,
    input_specs,
    logical_axes_for,
    sharding_tree,
)
from repro.models import get_model
from repro.roofline import RooflineReport, model_flops, parse_collectives
from repro.serve.engine import ServeConfig
from repro.train.optimizer import AdamWConfig
from repro.train.step import TrainConfig, make_train_step, train_state_init


def dryrun_train_config(cfg: ModelConfig) -> TrainConfig:
    """Microbatching + moment-dtype policy by model scale (DESIGN.md §3)."""
    n = cfg.param_count()
    if os.environ.get("REPRO_MICROBATCHES"):
        mb = int(os.environ["REPRO_MICROBATCHES"])
        return TrainConfig(
            optimizer=AdamWConfig(
                moment_dtype="bfloat16" if n > 30e9 else "float32"
            ),
            microbatches=mb,
        )
    if n > 100e9:
        return TrainConfig(
            optimizer=AdamWConfig(moment_dtype="bfloat16"), microbatches=16
        )
    if n > 30e9:
        return TrainConfig(
            optimizer=AdamWConfig(moment_dtype="bfloat16"), microbatches=16
        )
    if n > 5e9:
        return TrainConfig(microbatches=8)
    return TrainConfig(microbatches=1)


def _smallest_divisor(n: int) -> int:
    for d in range(2, n + 1):
        if n % d == 0:
            return d
    return n


def layer_trips(cfg: ModelConfig, kind: str) -> int:
    """Static trip count of each scan-over-layers in the program."""
    if cfg.arch_type == "hybrid" and cfg.hybrid_attn_every:
        return cfg.hybrid_attn_every  # per-group scans
    if cfg.is_encoder_decoder and kind != "decode":
        assert cfg.encoder_layers == cfg.num_layers, (
            "trip-count correction assumes equal enc/dec depth"
        )
    return cfg.num_layers


def rules_for(cfg: ModelConfig, shape: InputShape, mesh) -> ShardingRules:
    overrides: Dict[str, object] = {}
    if shape.kind == "train" and cfg.param_count() > 30e9:
        # Megatron-style sequence parallelism on the residual stream: scan
        # carries shrink by the model-axis factor (needed to fit 340B remat
        # boundaries in 16 GB HBM).
        overrides["seq"] = "model"
    if shape.kind == "decode" and shape.global_batch < 16:
        # long_500k: batch of 1 cannot use the data axis -> context-parallel
        # cache (sequence axis sharded over data).
        overrides["cache_seq"] = "data"
    if os.environ.get("REPRO_OPT_DECODE_CACHE") == "1" and shape.kind == "decode":
        # Beyond-paper optimisation (EXPERIMENTS.md §Perf): GQA kv_heads
        # (2-8) often don't divide the 16-way model axis, so baseline decode
        # caches replicate over "model" and blow past HBM.  Shard the cache
        # SEQUENCE axis over the model axis instead (flash-decoding style:
        # XLA inserts the partial-softmax combine).  Archs whose kv_heads
        # already shard (seamless kv=16, zamba2 kv=32) keep head sharding —
        # context sharding measured slightly WORSE there (§Perf, refuted
        # sub-iteration).
        kv_shardable = (
            cfg.num_kv_heads > 0
            and not cfg.use_mla
            and cfg.num_kv_heads % mesh.shape["model"] == 0
        )
        if not kv_shardable:
            if shape.global_batch < 16:
                overrides["cache_seq"] = ("data", "model")
            else:
                overrides["cache_seq"] = "model"
    return ShardingRules(mesh, overrides, dp_axes=dp_axes_of(mesh))


@dataclasses.dataclass
class DryrunResult:
    report: RooflineReport
    memory_analysis: Optional[str]
    compile_s: float
    state_bytes_per_device: int
    ok: bool
    error: Optional[str] = None


def run_dryrun(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    verbose: bool = True,
    keep_hlo: bool = False,
    correct_loops: bool = True,
):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    mesh_name = "x".join(str(s) for s in mesh.shape.values())
    rules = rules_for(cfg, shape, mesh)
    model = get_model(cfg)
    rng = jax.random.PRNGKey(0)

    batch_specs = input_specs(cfg, shape)
    t0 = time.perf_counter()
    mb_trips = 1
    dp_size = int(np.prod([mesh.shape[a] for a in dp_axes_of(mesh)]))

    def _moe_groups(tokens_per_call: int) -> int:
        return dp_size if (cfg.num_experts and tokens_per_call % dp_size == 0) else 1

    env_backup = os.environ.get("REPRO_MOE_GROUPS")

    with mesh, use_rules(rules):
        if shape.kind == "train":
            tc = dryrun_train_config(cfg)
            # keep at least one sample per data shard
            mb_cap = max(1, shape.global_batch // dp_size)
            if tc.microbatches > mb_cap:
                tc = dataclasses.replace(tc, microbatches=mb_cap)
            os.environ["REPRO_MOE_GROUPS"] = str(
                _moe_groups((shape.global_batch // tc.microbatches) * shape.seq_len)
            )
            state_shapes = jax.eval_shape(
                lambda r: train_state_init(r, cfg, tc), rng
            )
            state_sh = sharding_tree(state_shapes, rules, logical_axes_for)
            batch_sh = {
                k: rules.sharding_for(v.shape, batch_logical_axes(k, len(v.shape)))
                for k, v in batch_specs.items()
            }
            mb_trips = tc.microbatches

            def make_lowered():
                # fresh step closure per call: the unroll env knob is read at
                # trace time, so the jit trace cache must not be reused.
                step = make_train_step(cfg, tc)
                jitted = jax.jit(
                    step,
                    in_shardings=(state_sh, batch_sh),
                    out_shardings=(state_sh, None),
                    donate_argnums=(0,),
                )
                return jitted.lower(state_shapes, batch_specs)

            state_bytes = bytes_per_device(state_shapes, state_sh)
            tokens = shape.global_batch * shape.seq_len
            mflops = model_flops(cfg.active_param_count(), tokens, "train")
        elif shape.kind == "prefill":
            os.environ["REPRO_MOE_GROUPS"] = str(
                _moe_groups(shape.global_batch * shape.seq_len)
            )
            params_shapes = jax.eval_shape(lambda r: model.init(r, cfg), rng)
            params_sh = sharding_tree(params_shapes, rules, logical_axes_for)
            batch_sh = {
                k: rules.sharding_for(v.shape, batch_logical_axes(k, len(v.shape)))
                for k, v in batch_specs.items()
            }

            def make_lowered():
                def prefill(params, batch):
                    logits, _ = model.forward(params, cfg, batch)
                    return logits

                jitted = jax.jit(prefill, in_shardings=(params_sh, batch_sh))
                return jitted.lower(params_shapes, batch_specs)

            state_bytes = bytes_per_device(params_shapes, params_sh)
            tokens = shape.global_batch * shape.seq_len
            mflops = model_flops(cfg.active_param_count(), tokens, "prefill")
        else:  # decode
            os.environ["REPRO_MOE_GROUPS"] = str(_moe_groups(shape.global_batch))
            params_shapes = jax.eval_shape(lambda r: model.init(r, cfg), rng)
            params_sh = sharding_tree(params_shapes, rules, logical_axes_for)
            sc = ServeConfig(batch_size=shape.global_batch, context_len=shape.seq_len)
            cache_shapes = jax.eval_shape(
                lambda: model.init_cache(cfg, sc.batch_size, sc.cache_len(cfg))
            )
            cache_sh = sharding_tree(cache_shapes, rules, cache_logical_axes)
            tok_spec = batch_specs["tokens"]
            tok_sh = rules.sharding_for(tok_spec.shape, ("batch", None))
            pos_spec = jax.ShapeDtypeStruct((), jnp.int32)

            def make_lowered():
                def serve_step(params, tokens, cache, pos):
                    logits, new_cache = model.decode_step(
                        params, cfg, {"tokens": tokens}, cache, pos
                    )
                    return logits, new_cache

                jitted = jax.jit(
                    serve_step,
                    in_shardings=(params_sh, tok_sh, cache_sh, None),
                    out_shardings=(None, cache_sh),
                    donate_argnums=(2,),
                )
                return jitted.lower(params_shapes, tok_spec, cache_shapes, pos_spec)

            state_bytes = bytes_per_device(params_shapes, params_sh) + bytes_per_device(
                cache_shapes, cache_sh
            )
            tokens = shape.global_batch
            mflops = model_flops(cfg.active_param_count(), tokens, "decode")

        lowered = make_lowered()
        compiled = lowered.compile()

        # ---- trip-count correction for while-loop under-counting --------- #
        # XLA's cost_analysis counts each while body ONCE; we isolate the
        # per-body cost by compiling a partially-unrolled variant and
        # differencing, then multiply by the known static trip counts
        # (EXPERIMENTS.md §Roofline methodology).
        def _metrics(comp):
            c = comp.cost_analysis() or {}
            if isinstance(c, list):
                c = c[0] if c else {}
            cs = parse_collectives(comp.as_text())
            return (
                float(c.get("flops", 0.0)),
                float(c.get("bytes accessed", 0.0)),
                float(cs.total_bytes),
                cs.by_kind,
            )

        base_f, base_b, base_c, coll_kinds = _metrics(compiled)
        trips = layer_trips(cfg, shape.kind)
        layer_d = (0.0, 0.0, 0.0)
        mb_d = (0.0, 0.0, 0.0)
        if correct_loops and trips > 1:
            u = _smallest_divisor(trips)
            os.environ["REPRO_UNROLL_LAYERS"] = str(u)
            try:
                fu, bu, cu, _ = _metrics(make_lowered().compile())
            finally:
                os.environ.pop("REPRO_UNROLL_LAYERS", None)
            layer_d = tuple(
                max(0.0, (x - y) / (u - 1))
                for x, y in ((fu, base_f), (bu, base_b), (cu, base_c))
            )
        if correct_loops and mb_trips > 1:
            umb = _smallest_divisor(mb_trips)
            os.environ["REPRO_UNROLL_MB"] = str(umb)
            try:
                fm, bm, cm, _ = _metrics(make_lowered().compile())
            finally:
                os.environ.pop("REPRO_UNROLL_MB", None)
            mb_d = tuple(
                max(0.0, (x - y) / (umb - 1))
                for x, y in ((fm, base_f), (bm, base_b), (cm, base_c))
            )

        def _correct(base, ld, md):
            # true = base + (mb-1)*mb_glue + (mb*trips - 1)*layer_bodies
            # with mb_glue = mb_body - layer_bodies  (see DESIGN notes)
            if mb_trips > 1:
                mb_glue = max(0.0, md - ld)
                return base + (mb_trips - 1) * mb_glue + (mb_trips * trips - 1) * ld
            return base + (trips - 1) * ld

        flops = _correct(base_f, layer_d[0], mb_d[0])
        byts = _correct(base_b, layer_d[1], mb_d[1])
        coll_bytes = _correct(base_c, layer_d[2], mb_d[2])

    if env_backup is None:
        os.environ.pop("REPRO_MOE_GROUPS", None)
    else:
        os.environ["REPRO_MOE_GROUPS"] = env_backup
    compile_s = time.perf_counter() - t0
    try:
        mem = compiled.memory_analysis()
        mem_str = str(mem)
        peak = getattr(mem, "temp_size_in_bytes", None)
        if peak is not None:
            peak = float(peak) + float(getattr(mem, "argument_size_in_bytes", 0) or 0)
    except Exception as e:  # pragma: no cover
        mem_str, peak = f"<memory_analysis unavailable: {e}>", None

    report = RooflineReport(
        arch=arch,
        shape=shape_name,
        mesh=mesh_name,
        chips=chips,
        hlo_flops_per_device=flops,
        hlo_bytes_per_device=byts,
        collective_bytes_per_device=coll_bytes,
        collective_counts=coll_kinds,
        model_flops_total=mflops,
        peak_memory_per_device=peak,
    )
    result = DryrunResult(
        report=report,
        memory_analysis=mem_str,
        compile_s=compile_s,
        state_bytes_per_device=state_bytes,
        ok=True,
    )
    if verbose:
        print(f"== dryrun {arch} x {shape_name} on mesh {mesh_name} ==")
        print(mem_str)
        d = report.to_dict()
        d["compile_s"] = compile_s
        d["state_bytes_per_device"] = state_bytes
        print(json.dumps(d))
    if keep_hlo:
        result.hlo = compiled.as_text()  # type: ignore[attr-defined]
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=list_archs() + ["all"])
    ap.add_argument("--shape", required=True, choices=list(INPUT_SHAPES) + ["all"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument(
        "--no-correct",
        action="store_true",
        help="skip the trip-count correction compiles (lower+compile proof only)",
    )
    ap.add_argument("--json-out", default=None, help="append one JSON line per run")
    args = ap.parse_args()
    # multi-pod runs prove the pod axis shards; the roofline table is
    # single-pod, so corrections default off there.
    correct = not (args.no_correct or args.multi_pod)

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    failures = []
    for arch in archs:
        for shape in shapes:
            try:
                res = run_dryrun(
                    arch, shape, multi_pod=args.multi_pod, correct_loops=correct
                )
                if args.json_out:
                    d = res.report.to_dict()
                    d["compile_s"] = res.compile_s
                    d["state_bytes_per_device"] = res.state_bytes_per_device
                    with open(args.json_out, "a") as f:
                        f.write(json.dumps(d) + "\n")
            except Exception as e:  # noqa: BLE001
                failures.append((arch, shape, repr(e)))
                print(f"FAILED {arch} x {shape}: {e!r}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
