"""Training launcher: real (reduced-size, CPU) or sharded (mesh) runs.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b \
        --reduced --steps 100 --batch 8 --seq 128

``--reduced`` swaps in the smoke config family (the full configs are only
lowered via dryrun.py on the placeholder mesh — they do not fit a CPU).
Supports periodic checkpointing and restart (the migration cost path).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced, list_archs
from repro.train.checkpoint import restore_checkpoint, save_checkpoint
from repro.train.data import batch_for
from repro.train.optimizer import AdamWConfig
from repro.train.step import TrainConfig, make_train_step, train_state_init


def train_loop(
    cfg,
    steps: int,
    batch_size: int,
    seq_len: int,
    lr: float = 1e-3,
    microbatches: int = 1,
    ckpt_path: str | None = None,
    ckpt_every: int = 0,
    resume: bool = False,
    log_every: int = 10,
    seed: int = 0,
):
    tc = TrainConfig(
        optimizer=AdamWConfig(learning_rate=lr, warmup_steps=max(steps // 10, 1)),
        microbatches=microbatches,
    )
    state = train_state_init(jax.random.PRNGKey(seed), cfg, tc)
    start_step = 0
    if resume and ckpt_path:
        state, start_step = restore_checkpoint(ckpt_path, state)
        print(f"resumed from {ckpt_path} at step {start_step}")
    step_fn = jax.jit(make_train_step(cfg, tc), donate_argnums=(0,))

    losses = []
    t0 = time.perf_counter()
    for step in range(start_step, steps):
        batch = batch_for(
            cfg.vocab_size,
            batch_size,
            seq_len,
            seed=seed,
            step=step,
            frontend=cfg.frontend,
            frontend_len=cfg.frontend_len,
            d_model=cfg.d_model,
        )
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % log_every == 0 or step == steps - 1:
            dt = time.perf_counter() - t0
            print(
                f"step {step:5d}  loss {loss:.4f}  nll {float(metrics['nll']):.4f}"
                f"  grad_norm {float(metrics['grad_norm']):.3f}  ({dt:.1f}s)"
            )
        if ckpt_path and ckpt_every and (step + 1) % ckpt_every == 0:
            save_checkpoint(ckpt_path, state, step + 1)
    return state, losses


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    print(f"training {cfg.name}: ~{cfg.param_count() / 1e6:.1f}M params")
    _, losses = train_loop(
        cfg,
        steps=args.steps,
        batch_size=args.batch,
        seq_len=args.seq,
        lr=args.lr,
        microbatches=args.microbatches,
        ckpt_path=args.ckpt,
        ckpt_every=args.ckpt_every,
        resume=args.resume,
        seed=args.seed,
    )
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"loss: first10={first:.4f} last10={last:.4f} improved={last < first}")


if __name__ == "__main__":
    main()
