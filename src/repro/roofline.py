"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

Three terms per (arch x shape x mesh), all in seconds:

    compute_term    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory_term     = HLO_bytes_per_device / HBM_bandwidth
    collective_term = collective_bytes_per_device / ICI_link_bandwidth

HLO FLOPs/bytes come from ``compiled.cost_analysis()`` (the partitioned
per-device module); collective bytes are NOT in cost_analysis, so we parse
the optimized HLO text and sum the operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute instruction.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (DESIGN.md §3).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12     # bf16 per chip
HBM_BW = 819e9          # bytes/s
ICI_BW = 50e9           # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def bytes_of_type(type_str: str) -> int:
    """Bytes of an HLO type string, incl. tuples: sums all dtype[dims]."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^=]*?\)|[\w\[\],{}\/#: ]+?))\s+([\w\-]+)\("
)


@dataclasses.dataclass
class CollectiveStats:
    #: op kind -> (count, operand_bytes)
    by_kind: Dict[str, Tuple[int, int]]

    @property
    def total_bytes(self) -> int:
        return sum(b for _, b in self.by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(c for c, _ in self.by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum operand sizes of collective ops in optimized HLO text.

    Builds a name -> result-bytes symbol table in a first pass, then sums
    operand bytes for each collective (``-start`` variants counted,
    ``-done`` skipped to avoid double counting).
    """
    sizes: Dict[str, int] = {}
    lines = hlo_text.splitlines()
    for ln in lines:
        m = _INSTR_RE.match(ln)
        if m:
            sizes[m.group(1)] = bytes_of_type(m.group(2))

    by_kind: Dict[str, List[int]] = {}
    for ln in lines:
        m = _INSTR_RE.match(ln)
        if not m:
            continue
        name, _type, op = m.groups()
        base = op
        if base.endswith("-start"):
            base = base[: -len("-start")]
        elif base.endswith("-done"):
            continue
        if base not in _COLLECTIVES:
            continue
        # operand list: text between the op's '(' and its matching ')'
        start = ln.index(op + "(") + len(op) + 1
        depth, end = 1, start
        while end < len(ln) and depth:
            if ln[end] == "(":
                depth += 1
            elif ln[end] == ")":
                depth -= 1
            end += 1
        args = ln[start : end - 1]
        op_bytes = 0
        for ref in re.finditer(r"%?([\w.\-]+)", args):
            nm = ref.group(1)
            if nm in sizes:
                op_bytes += sizes[nm]
        if op_bytes == 0:
            # fallback: result size (exact for all-reduce/collective-permute)
            op_bytes = sizes.get(name, 0)
        cnt, tot = by_kind.get(base, (0, 0))
        by_kind[base] = (cnt + 1, tot + op_bytes)
    return CollectiveStats({k: tuple(v) for k, v in by_kind.items()})


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_device: float
    hlo_bytes_per_device: float
    collective_bytes_per_device: float
    collective_counts: Dict[str, Tuple[int, int]]
    model_flops_total: float          # 6*N*D (D = tokens this step, global)
    peak_memory_per_device: Optional[float]

    @property
    def compute_term_s(self) -> float:
        return self.hlo_flops_per_device / PEAK_FLOPS

    @property
    def memory_term_s(self) -> float:
        return self.hlo_bytes_per_device / HBM_BW

    @property
    def collective_term_s(self) -> float:
        return self.collective_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_term_s,
            "memory": self.memory_term_s,
            "collective": self.collective_term_s,
        }
        return max(terms, key=terms.get)

    @property
    def model_flops_ratio(self) -> float:
        """useful-FLOPs fraction: MODEL_FLOPS / (chips * HLO_FLOPs_per_dev).
        < 1 with remat (recompute) / dispatch overhead; > 1 would mean the
        compiler found algebraic savings (or our 6ND estimate is loose)."""
        denom = self.chips * self.hlo_flops_per_device
        return self.model_flops_total / denom if denom else 0.0

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops_per_device": self.hlo_flops_per_device,
            "hlo_bytes_per_device": self.hlo_bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "collective_counts": {k: list(v) for k, v in self.collective_counts.items()},
            "model_flops_total": self.model_flops_total,
            "compute_term_s": self.compute_term_s,
            "memory_term_s": self.memory_term_s,
            "collective_term_s": self.collective_term_s,
            "bottleneck": self.bottleneck,
            "model_flops_ratio": self.model_flops_ratio,
            "peak_memory_per_device": self.peak_memory_per_device,
        }


def model_flops(param_count_active: int, tokens: int, kind: str) -> float:
    """6*N*D for a train step (fwd+bwd), 2*N*D for inference steps."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * param_count_active * tokens
