"""Cluster topology and placement-plan representation.

A placement plan is the object the paper's Algorithms 2/3/5 operate on:
which job(s) sit on every GPU of every node.  We represent it densely as an
int array

    ``slots[node, gpu_in_node, pack_slot] = job_id`` (``-1`` = empty)

with ``pack_slot < MAX_PACK = 2`` because "Tesserae imposes a limit of two
models running simultaneously on each GPU" (§5).

GPUs are homogeneous within a cluster by default (§4.1 assumption).  The
workload scenario lab extends the spec with OPT-IN heterogeneity:
``node_gpu_types`` gives every node its own GPU type (A100 vs V100 mixes,
Fig. 12b / Gavel's heterogeneity regime) and ``nodes_per_rack`` imposes a
rack/pod topology.  Both default to off, in which case every code path
that consults them is bit-for-bit the homogeneous seed behaviour —
placement, migration and packing only become type/topology-aware when a
scenario asks for it.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, Iterable, List, Optional, Tuple

import numpy as np

MAX_PACK = 2
EMPTY = -1


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    num_nodes: int
    gpus_per_node: int
    #: label only (profiles key off it): "a100", "v100", "tpu-v5e", ...
    gpu_type: str = "a100"
    #: OPT-IN per-node GPU types (len == num_nodes).  ``None`` (default) =
    #: homogeneous cluster of ``gpu_type`` — the seed semantics, where the
    #: profile alone decides throughput.  When set, the cluster is the
    #: authority: schedulers/simulators derive per-node profiles from it.
    node_gpu_types: Optional[Tuple[str, ...]] = None
    #: OPT-IN rack topology: nodes [k*r, (k+1)*r) form rack k.  ``0``
    #: (default) = topology-unaware (single rack, no locality terms).
    nodes_per_rack: int = 0

    def __post_init__(self):
        if self.node_gpu_types is not None:
            types = tuple(self.node_gpu_types)
            object.__setattr__(self, "node_gpu_types", types)
            if len(types) != self.num_nodes:
                raise ValueError(
                    f"node_gpu_types has {len(types)} entries for "
                    f"{self.num_nodes} nodes"
                )
        if self.nodes_per_rack < 0:
            raise ValueError("nodes_per_rack must be >= 0")

    @property
    def num_gpus(self) -> int:
        return self.num_nodes * self.gpus_per_node

    def gpu_id(self, node: int, local: int) -> int:
        return node * self.gpus_per_node + local

    def node_of(self, gpu_id: int) -> int:
        return gpu_id // self.gpus_per_node

    def local_of(self, gpu_id: int) -> int:
        return gpu_id % self.gpus_per_node

    # -- heterogeneity / topology (all trivially constant when disabled) -- #
    @property
    def is_heterogeneous(self) -> bool:
        """True iff at least two nodes carry different GPU types."""
        return self.node_gpu_types is not None and len(set(self.node_gpu_types)) > 1

    @property
    def has_topology(self) -> bool:
        """True iff the rack structure partitions the nodes non-trivially."""
        return 0 < self.nodes_per_rack < self.num_nodes

    def gpu_type_of(self, node: int) -> str:
        return (
            self.gpu_type
            if self.node_gpu_types is None
            else self.node_gpu_types[node]
        )

    def node_types(self) -> Tuple[str, ...]:
        """Per-node GPU types, materialised even for homogeneous clusters."""
        return self.node_gpu_types or (self.gpu_type,) * self.num_nodes

    def rack_of(self, node: int) -> int:
        return 0 if self.nodes_per_rack <= 0 else node // self.nodes_per_rack

    @property
    def num_racks(self) -> int:
        if not self.has_topology:
            return 1
        return -(-self.num_nodes // self.nodes_per_rack)


class ClusterHealth:
    """Mutable per-node health state (the fault-injection layer's view of
    the cluster).

    ``up[k]`` — node k accepts placements; a down node is ZERO capacity
    for the scheduler (placement skips it, migration relabelling is
    penalised off it).  ``speed_factor[k]`` — the node's GPUs run at this
    fraction of nominal speed (gpu-degrade events; a health-aware
    scheduler drains jobs off such nodes via the relabelling benefit).
    ``outages`` counts node-down events observed so far; it feeds the
    pooled empirical MTBF estimate behind MTBF-aware consolidation
    (failure-aware policies spread large gangs across racks only when the
    outage process is measurably hot).  A freshly constructed health
    object is all-up / full-speed / zero-outage — every consumer treats
    that state bit-identically to "no health tracking at all" (the seed
    path).
    """

    def __init__(self, num_nodes: int):
        self.up = np.ones(num_nodes, dtype=bool)
        self.speed_factor = np.ones(num_nodes, dtype=np.float64)
        self.outages = 0

    @property
    def all_up(self) -> bool:
        return bool(self.up.all())

    @property
    def degraded(self) -> bool:
        """True iff any node runs below nominal speed."""
        return bool((self.speed_factor != 1.0).any())

    def down_nodes(self) -> np.ndarray:
        """Indices of nodes currently down (sorted ascending)."""
        return np.nonzero(~self.up)[0]

    def note_outage(self) -> None:
        """Record one node-down event (feeds :meth:`empirical_mtbf_s`)."""
        self.outages += 1

    def empirical_mtbf_s(self, now: float) -> Optional[float]:
        """Pooled per-node MTBF estimate from the applied outage stream.

        ``num_nodes * elapsed / outages`` — the maximum-likelihood rate for
        a homogeneous Poisson outage process observed over all nodes.
        ``None`` until the first outage (no evidence the process exists).
        """
        if self.outages <= 0:
            return None
        elapsed = max(float(now), 1.0)
        return elapsed * self.up.shape[0] / self.outages

    def hazard_hot(self, now: float, threshold_s: float) -> bool:
        """True iff the observed outage process is hot enough (empirical
        per-node MTBF below ``threshold_s``) to justify spreading large
        gangs across failure domains."""
        mtbf = self.empirical_mtbf_s(now)
        return mtbf is not None and mtbf < threshold_s

    def copy(self) -> "ClusterHealth":
        out = ClusterHealth(self.up.shape[0])
        out.up = self.up.copy()
        out.speed_factor = self.speed_factor.copy()
        out.outages = self.outages
        return out


class PlacementPlan:
    """Dense job-on-GPU map with set-style helpers used by the matchers."""

    def __init__(self, cluster: ClusterSpec, slots: np.ndarray | None = None):
        self.cluster = cluster
        if slots is None:
            slots = np.full(
                (cluster.num_nodes, cluster.gpus_per_node, MAX_PACK),
                EMPTY,
                dtype=np.int64,
            )
        expected = (cluster.num_nodes, cluster.gpus_per_node, MAX_PACK)
        if slots.shape != expected:
            raise ValueError(f"slots shape {slots.shape} != {expected}")
        self.slots = slots

    # ------------------------------------------------------------------ #
    def copy(self) -> "PlacementPlan":
        return PlacementPlan(self.cluster, self.slots.copy())

    def jobs_on_gpu(self, node: int, local: int) -> Tuple[int, ...]:
        js = self.slots[node, local]
        return tuple(int(j) for j in js if j != EMPTY)

    def job_ids(self) -> FrozenSet[int]:
        flat = self.slots[self.slots != EMPTY]
        return frozenset(int(j) for j in flat)

    def gpus_of_job(self, job_id: int) -> FrozenSet[int]:
        nodes, locals_, _ = np.nonzero(self.slots == job_id)
        return frozenset(
            self.cluster.gpu_id(int(n), int(l)) for n, l in zip(nodes, locals_)
        )

    def job_gpu_map(self) -> Dict[int, FrozenSet[int]]:
        out: Dict[int, set] = {}
        nodes, locals_, packs = np.nonzero(self.slots != EMPTY)
        for n, l, p in zip(nodes, locals_, packs):
            j = int(self.slots[n, l, p])
            out.setdefault(j, set()).add(self.cluster.gpu_id(int(n), int(l)))
        return {j: frozenset(g) for j, g in out.items()}

    def free_gpus_per_node(self) -> np.ndarray:
        """Number of completely empty GPUs on each node."""
        empty = (self.slots == EMPTY).all(axis=-1)
        return empty.sum(axis=-1)

    def pack_capacity(self, node: int, local: int) -> int:
        return int((self.slots[node, local] == EMPTY).sum())

    def place_job(self, job_id: int, gpu_ids: Iterable[int]) -> None:
        for g in gpu_ids:
            n, l = self.cluster.node_of(g), self.cluster.local_of(g)
            row = self.slots[n, l]
            free = np.nonzero(row == EMPTY)[0]
            if len(free) == 0:
                raise ValueError(f"GPU {g} already holds {MAX_PACK} jobs")
            row[free[0]] = job_id

    def remove_job(self, job_id: int) -> None:
        self.slots[self.slots == job_id] = EMPTY

    def without_jobs(self, drop: Iterable[int]) -> "PlacementPlan":
        out = self.copy()
        for j in drop:
            out.remove_job(j)
        return out

    def restricted_to(self, keep: Iterable[int]) -> "PlacementPlan":
        keep = set(keep)
        out = self.copy()
        mask = ~np.isin(out.slots, list(keep)) & (out.slots != EMPTY)
        out.slots[mask] = EMPTY
        return out

    def is_consolidated(self, job_id: int) -> bool:
        """True if the job occupies one node, or whole nodes only."""
        nodes, locals_, _ = np.nonzero(self.slots == job_id)
        if len(nodes) == 0:
            return True
        unique_nodes = np.unique(nodes)
        if len(unique_nodes) == 1:
            return True
        # multi-node: every touched node must be fully covered by this job
        for n in unique_nodes:
            covered = np.unique(locals_[nodes == n])
            if len(covered) != self.cluster.gpus_per_node:
                return False
        return True

    def __eq__(self, other) -> bool:  # slot-order-insensitive equality
        if not isinstance(other, PlacementPlan):
            return NotImplemented
        return self.job_gpu_map() == other.job_gpu_map()

    def __repr__(self) -> str:
        rows: List[str] = []
        for n in range(self.cluster.num_nodes):
            cells = []
            for l in range(self.cluster.gpus_per_node):
                js = self.jobs_on_gpu(n, l)
                cells.append("+".join(map(str, js)) if js else ".")
            rows.append(f"node{n}[{' '.join(cells)}]")
        return "Placement(" + " | ".join(rows) + ")"


def count_migrations(
    prev: PlacementPlan,
    new: PlacementPlan,
    num_gpus_of: Dict[int, int] | None = None,
) -> int:
    """Definition 1: a job migrated iff present in both rounds with a
    different physical GPU set."""
    prev_map = prev.job_gpu_map()
    new_map = new.job_gpu_map()
    common = set(prev_map) & set(new_map)
    return sum(1 for j in common if prev_map[j] != new_map[j])
