"""Job model for the Tesserae scheduler.

A *job* is a DL training run requesting ``num_gpus`` accelerators for
``total_iters`` iterations.  Jobs are opaque to the matcher — all the
placement policies need is (a) the GPU count, (b) throughput profiles
(isolated / packed / per-parallelism-strategy), and (c) migration overheads
(checkpoint save+load + warmup, Fig. 3).
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """Immutable description of a submitted job (one trace row)."""

    job_id: int
    model: str
    num_gpus: int
    total_iters: float
    arrival_time: float  # seconds since trace start
    batch_size: int = 32
    #: jobs with strict deadlines / high priority bypass packing (§4.3
    #: "Fairness": no edges are created for them in Algorithm 4).
    packable: bool = True
    #: 3D-parallel (Megatron-style) jobs expose a parallelism-strategy
    #: degree of freedom (§4.2 "Parallelism Strategy"); DDP jobs do not.
    is_llm: bool = False


@dataclasses.dataclass
class JobState:
    """Mutable per-job bookkeeping carried across scheduling rounds."""

    spec: JobSpec
    iters_done: float = 0.0
    #: 2D attained service = sum over rounds of num_gpus * executed seconds
    #: (Tiresias' LAS metric).
    attained_service: float = 0.0
    executed_time: float = 0.0
    first_run_time: Optional[float] = None
    finish_time: Optional[float] = None
    #: physical GPU ids currently assigned (empty when preempted/pending).
    gpus: frozenset = frozenset()
    #: job id this job is currently packed with (None = exclusive).
    packed_with: Optional[int] = None
    #: chosen parallelism strategy name (LLM jobs only).
    strategy: str = "dp"
    migrations: int = 0
    #: seconds of pending migration penalty still to pay off.
    migration_debt: float = 0.0
    # -- fault-injection bookkeeping (all inert on the failure-free path) -- #
    #: retries consumed (node crashes + job failures both count).
    retries: int = 0
    #: involuntary evictions suffered (node-down preemptions).
    preemptions: int = 0
    #: earliest time the job may be (re)placed — exponential backoff
    #: pushes this into the future after a failure.
    eligible_time: float = 0.0
    #: progress as of the last checkpoint; a crash rolls ``iters_done``
    #: back to this (the checkpoint-interval lost-work model).
    ckpt_iters: float = 0.0
    #: ``executed_time`` at the last checkpoint (drives the interval).
    ckpt_executed: float = 0.0
    #: ``attained_service`` at the last checkpoint.  A crash rewinds the
    #: LAS metric here too — the surviving checkpoint is all the service
    #: the job actually keeps, so Tiresias must not demote a crash victim
    #: for work that was lost.
    ckpt_service: float = 0.0
    #: cumulative iterations discarded by crash rollbacks.
    lost_iters: float = 0.0
    #: retry budget exhausted — terminally failed, never requeued.
    failed: bool = False

    @property
    def job_id(self) -> int:
        return self.spec.job_id

    @property
    def num_gpus(self) -> int:
        return self.spec.num_gpus

    @property
    def finished(self) -> bool:
        return self.finish_time is not None

    def remaining_iters(self) -> float:
        return max(0.0, self.spec.total_iters - self.iters_done)


# Migration overhead (checkpoint save + load + warmup, seconds) per model
# family, digitised from Fig. 3(a): vision/point-cloud models restart in tens
# of seconds, LLMs pay much more (optimizer state + pipeline warmup).
MIGRATION_OVERHEAD_S = {
    "resnet50": 25.0,
    "vgg19": 35.0,
    "dcgan": 20.0,
    "pointnet": 15.0,
    "gpt3-medium": 60.0,
    "gpt3-xl": 90.0,
    "gpt3-3b": 140.0,
}
_DEFAULT_MIGRATION_OVERHEAD_S = 45.0


def migration_overhead_s(model: str) -> float:
    return MIGRATION_OVERHEAD_S.get(model, _DEFAULT_MIGRATION_OVERHEAD_S)
