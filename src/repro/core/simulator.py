"""Round-based discrete-event simulator (§5 "Schedulers", §6.2).

The paper validates its simulator against a 32-GPU Perlmutter cluster
(Table 2, max deviation 5.42%) and then runs all large-scale comparisons in
simulation; we inherit that methodology.  Semantics:

* scheduling happens every ``round_duration_s`` (six minutes, §5);
* within a round a job progresses at
  ``isolated_tput(model, gpus, strategy) * packed_factor`` iters/sec,
* a migrated job first pays its migration debt (checkpoint save + load +
  warmup, Fig. 3) before making progress; a *newly started* job pays the
  ``startup_fraction`` of the debt (warmup / initial load only) and a
  *resumed* (previously preempted) job pays ``resume_fraction`` —
  defaulting to the same value, the paper's Fig. 3 model,
* jobs finishing mid-round release GPUs only at the next round boundary
  (round-based semantics; Tesserae "only preempts the job after the job
  finishes the current iteration").

Throughput truth vs. belief: the scheduler consults ``sched_profile``
(possibly noisy / estimated, Figs. 16 & 18) while the simulator advances
jobs with ``true_profile``.
"""

from __future__ import annotations

import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.cluster import ClusterSpec, PlacementPlan
from repro.core.jobs import JobSpec, JobState, migration_overhead_s
from repro.core.policies.base import SchedulingPolicy
from repro.core.policies.gavel import GavelPolicy
from repro.core.policies.themis import ThemisFtfPolicy
from repro.core.profiler import GPU_TYPES, ThroughputProfile
from repro.core.scheduler import RoundDecision, TesseraeScheduler


@dataclasses.dataclass
class SimConfig:
    round_duration_s: float = 360.0
    max_time_s: float = 60 * 24 * 3600.0
    migration_penalty: bool = True
    #: fraction of the migration debt charged on a COLD start (a job's
    #: first placement ever: warmup + initial load, no checkpoint to read)
    startup_fraction: float = 0.5
    #: fraction charged on a RESUME (a preempted job returning to GPUs:
    #: checkpoint load + warmup).  ``None`` = same as ``startup_fraction``
    #: — the paper's Fig. 3 model, and the seed behaviour.
    resume_fraction: Optional[float] = None
    #: speculatively run the next round's decision pipeline after each
    #: round (the simulator knows the exact next active set once the round
    #: has advanced), so the scheduler's :class:`MatchContext` is warm and
    #: the *measured* ``decide()`` critical path collapses to memo/warm
    #: hits.  Models a production scheduler using its idle time between
    #: rounds; off by default so seed timings stay comparable.  The
    #: speculation runs on a background thread that is joined before the
    #: next ``decide`` touches the scheduler, so the sim loop no longer
    #: pays the 2x serial decide work (overlap is reported in
    #: :attr:`SimResult.prewarm_overlap_s`).
    speculative_prewarm: bool = False


@dataclasses.dataclass
class SimResult:
    jobs: Dict[int, JobState]
    makespan_s: float
    num_rounds: int
    total_migrations: int
    #: per-round scheduler overhead breakdown (schedule/place/pack/migrate)
    overhead: Dict[str, float]
    lp_refresh_s: float
    contention_integral: Dict[int, float]  # job_id -> avg demand/capacity
    #: per-round MatchContext stat deltas (memo/warm/cold instances, price
    #: invalidations) — the identity-keyed warm-start telemetry the churn
    #: replay tests and the CI perf-smoke gate read.
    match_rounds: List[Dict[str, int]] = dataclasses.field(default_factory=list)
    #: total wall time the speculative-prewarm thread spent deciding, and
    #: the portion of it that OVERLAPPED the main sim loop (prewarm wall
    #: minus the time the loop actually blocked waiting for it) — both 0.0
    #: when ``speculative_prewarm`` is off.
    prewarm_wall_s: float = 0.0
    prewarm_overlap_s: float = 0.0

    @property
    def jcts(self) -> np.ndarray:
        return np.array(
            [s.finish_time - s.spec.arrival_time for s in self.jobs.values()]
        )

    @property
    def avg_jct_s(self) -> float:
        return float(self.jcts.mean())

    def ftf_ratios(self, profile: ThroughputProfile) -> np.ndarray:
        """rho = T_shared / T_fair; T_fair = isolated duration stretched by
        the average demand/capacity contention over the job's lifetime."""
        out = []
        for jid, s in self.jobs.items():
            tput = profile.isolated(s.spec.model, s.num_gpus, "dp")
            iso = s.spec.total_iters / max(tput, 1e-9)
            contention = max(1.0, self.contention_integral.get(jid, 1.0))
            t_fair = iso * contention
            t_shared = s.finish_time - s.spec.arrival_time
            out.append(t_shared / max(t_fair, 1e-9))
        return np.array(out)

    def summary(self, profile: Optional[ThroughputProfile] = None) -> Dict[str, float]:
        d = {
            "avg_jct_s": self.avg_jct_s,
            "p50_jct_s": float(np.median(self.jcts)),
            "p90_jct_s": float(np.percentile(self.jcts, 90)),
            "makespan_s": self.makespan_s,
            "migrations": float(self.total_migrations),
            "rounds": float(self.num_rounds),
            "overhead_total_s": float(sum(self.overhead.values())) + self.lp_refresh_s,
        }
        if profile is not None:
            rho = self.ftf_ratios(profile)
            d["ftf_worst"] = float(rho.max())
            d["ftf_p90"] = float(np.percentile(rho, 90))
        return d

    def warm_hit_rounds(self, skip: int = 1) -> int:
        """Rounds (after the first ``skip`` warmup rounds) in which the
        scheduler served at least one LAP instance from its identity-keyed
        context — the churn-replay acceptance metric."""
        return sum(
            1
            for rs in self.match_rounds[skip:]
            if rs.get("warm_instances", 0) > 0
        )

    @property
    def total_bid_iters(self) -> int:
        """Not tracked per round by the scheduler timings — derived from
        the context stats the rounds accumulated (0 when the backend is
        exact)."""
        return sum(rs.get("bid_iters", 0) for rs in self.match_rounds)


class Simulator:
    def __init__(
        self,
        cluster: ClusterSpec,
        trace: Sequence[JobSpec],
        scheduler: TesseraeScheduler,
        true_profile: ThroughputProfile,
        config: SimConfig | None = None,
    ):
        self.cluster = cluster
        self.trace = sorted(trace, key=lambda s: (s.arrival_time, s.job_id))
        self.scheduler = scheduler
        self.true_profile = true_profile
        self.config = config or SimConfig()

    # ------------------------------------------------------------------ #
    def run(self) -> SimResult:
        cfg = self.config
        states: Dict[int, JobState] = {
            s.job_id: JobState(spec=s) for s in self.trace
        }
        num_gpus_of = {s.job_id: s.num_gpus for s in self.trace}
        now = 0.0
        prev_plan: Optional[PlacementPlan] = None
        prev_gpus: Dict[int, frozenset] = {}
        total_migrations = 0
        match_rounds: List[Dict[str, int]] = []
        overhead: Dict[str, float] = {}
        lp_refresh_s = 0.0
        contention_num: Dict[int, float] = {}
        contention_den: Dict[int, float] = {}
        rounds = 0
        executor: Optional[ThreadPoolExecutor] = None
        pending_prewarm = None
        prewarm_wall = 0.0
        prewarm_overlap = 0.0
        if cfg.speculative_prewarm:
            executor = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="sim-prewarm"
            )

        def _timed_prewarm(spec_active, t, plan, gmap):
            t0 = time.perf_counter()
            self.scheduler.prewarm(spec_active, t, plan, gmap)
            return time.perf_counter() - t0

        try:
            while now < cfg.max_time_s:
                # the prewarm thread owns the scheduler (MatchContext and
                # policy state) until joined — block before anything below
                # touches it.  Join wait below the prewarm's own wall time
                # is loop work the speculation overlapped with.
                if pending_prewarm is not None:
                    t_join = time.perf_counter()
                    w = pending_prewarm.result()
                    waited = time.perf_counter() - t_join
                    prewarm_wall += w
                    prewarm_overlap += max(0.0, w - waited)
                    pending_prewarm = None
                active = [
                    s
                    for s in states.values()
                    if s.spec.arrival_time <= now and not s.finished
                ]
                future = [
                    s
                    for s in states.values()
                    if s.spec.arrival_time > now and not s.finished
                ]
                if not active and not future:
                    break
                if not active:
                    # idle until the next arrival's round boundary
                    next_arrival = min(s.spec.arrival_time for s in future)
                    k = int(np.floor(next_arrival / cfg.round_duration_s))
                    now = max(now + cfg.round_duration_s, k * cfg.round_duration_s)
                    continue

                # LP-based policies re-solve their optimisation once per round.
                if isinstance(self.scheduler.policy, GavelPolicy):
                    lp_refresh_s += self.scheduler.policy.refresh(active, self.cluster)
                if isinstance(self.scheduler.policy, ThemisFtfPolicy):
                    demand = sum(j.num_gpus for j in active)
                    self.scheduler.policy.avg_contention = max(
                        1.0, demand / self.cluster.num_gpus
                    )

                decision = self.scheduler.decide(active, now, prev_plan, num_gpus_of)
                match_rounds.append(dict(decision.match_stats))
                for k, v in decision.timings.items():
                    overhead[k] = overhead.get(k, 0.0) + v
                if decision.migration is not None:
                    total_migrations += decision.migration.num_migrations
                if isinstance(self.scheduler.policy, GavelPolicy):
                    self.scheduler.policy.note_round(
                        [j.job_id for j in decision.placed]
                    )

                self._advance_round(
                    decision, states, now, prev_gpus, num_gpus_of
                )

                plan_map = decision.plan.job_gpu_map()
                prev_gpus = dict(plan_map)
                prev_plan = decision.plan.restricted_to(
                    [j for j in plan_map if not states[j].finished]
                )
                now += cfg.round_duration_s
                rounds += 1

                if executor is not None:
                    # The round has advanced, so the NEXT round's active
                    # set is known exactly; batch its expected LAP
                    # fan-outs through the engine on the prewarm thread
                    # (in production: the scheduler's idle time between
                    # rounds) so the next decide() memo/warm-hits.
                    # Purely a cache side effect — decisions are
                    # unaffected.  The FTF bookkeeping below overlaps it.
                    spec_active = [
                        s
                        for s in states.values()
                        if s.spec.arrival_time <= now and not s.finished
                    ]
                    if spec_active:
                        pending_prewarm = executor.submit(
                            _timed_prewarm, spec_active, now, prev_plan, num_gpus_of
                        )

                # contention bookkeeping for FTF
                demand = sum(j.num_gpus for j in active)
                ratio = demand / self.cluster.num_gpus
                for j in active:
                    contention_num[j.job_id] = (
                        contention_num.get(j.job_id, 0.0) + ratio
                    )
                    contention_den[j.job_id] = contention_den.get(j.job_id, 0.0) + 1.0
        finally:
            if pending_prewarm is not None:
                prewarm_wall += pending_prewarm.result()
            if executor is not None:
                executor.shutdown(wait=True)

        unfinished = [s for s in states.values() if not s.finished]
        for s in unfinished:  # should not happen with max_time high enough
            s.finish_time = cfg.max_time_s
        makespan = max((s.finish_time for s in states.values()), default=0.0)
        contention = {
            j: contention_num[j] / contention_den[j]
            for j in contention_num
            if contention_den.get(j)
        }
        return SimResult(
            states,
            makespan,
            rounds,
            total_migrations,
            overhead,
            lp_refresh_s,
            contention,
            match_rounds,
            prewarm_wall_s=prewarm_wall,
            prewarm_overlap_s=prewarm_overlap,
        )

    # ------------------------------------------------------------------ #
    def _typed_profile(self, gpus) -> ThroughputProfile:
        """Ground-truth profile for a job on ``gpus`` (physical GPU ids).

        Homogeneous clusters (``node_gpu_types`` unset) always return
        ``true_profile`` itself.  On heterogeneous clusters the job runs
        at the profile of the SLOWEST GPU type it touches (synchronous
        training is bound by its slowest worker)."""
        if self.cluster.node_gpu_types is None or not gpus:
            return self.true_profile
        types = {
            self.cluster.gpu_type_of(self.cluster.node_of(g)) for g in gpus
        }
        slowest = min(types, key=lambda t: (GPU_TYPES[t].speed, t))
        return self.true_profile.for_gpu_type(slowest)

    def _advance_round(
        self,
        decision: RoundDecision,
        states: Dict[int, JobState],
        now: float,
        prev_gpus: Dict[int, frozenset],
        num_gpus_of: Dict[int, int],
    ) -> None:
        cfg = self.config
        plan_map = decision.plan.job_gpu_map()
        packed_partner: Dict[int, int] = {}
        for pending_id, placed_id in decision.packing.matches.items():
            packed_partner[pending_id] = placed_id
            packed_partner[placed_id] = pending_id

        for jid, gpus in plan_map.items():
            s = states[jid]
            if s.finished:
                continue
            # strategy chosen by the packing matcher applies WHILE PACKED;
            # an unpacked job reverts to its best isolated strategy (dp)
            s.strategy = decision.packing.strategies.get(jid, "dp")
            # migration / startup debt: a job entering the plan from the
            # outside pays the cold-start fraction on its FIRST placement
            # ever (warmup + initial load) and the resume fraction when it
            # returns from preemption (checkpoint load + warmup); a job
            # changing GPUs within the plan pays the full migration debt.
            if cfg.migration_penalty:
                prev = prev_gpus.get(jid)
                if prev is None:
                    cold_start = s.executed_time == 0.0
                    frac = (
                        cfg.startup_fraction
                        if cold_start or cfg.resume_fraction is None
                        else cfg.resume_fraction
                    )
                    s.migration_debt += frac * migration_overhead_s(s.spec.model)
                elif prev != gpus:
                    s.migrations += 1
                    s.migration_debt += migration_overhead_s(s.spec.model)
            s.gpus = gpus

            # heterogeneous clusters: the job's TRUE rate (and packing
            # interference, incl. HBM feasibility) is profiled on the GPU
            # type it actually landed on — the slowest participating node
            # bounds a synchronous job.  Homogeneous clusters return
            # ``true_profile`` itself (the bit-identical seed path).
            prof = self._typed_profile(gpus)
            partner = packed_partner.get(jid)
            factor = 1.0
            if partner is not None and partner in plan_map:
                me, other = s.spec.model, states[partner].spec.model
                na, nb = prof.normalized_packed(
                    me, other, strat_a=s.strategy, strat_b=states[partner].strategy
                )
                factor = na if na > 0 else 1.0
            rate = prof.isolated(s.spec.model, s.num_gpus, s.strategy) * factor

            debt = min(s.migration_debt, cfg.round_duration_s)
            s.migration_debt -= debt
            run_time = cfg.round_duration_s - debt
            if s.first_run_time is None:
                s.first_run_time = now + debt
            remaining = s.remaining_iters()
            if rate * run_time >= remaining and rate > 0:
                finish_delay = debt + remaining / rate
                s.iters_done = s.spec.total_iters
                s.finish_time = now + finish_delay
                s.executed_time += remaining / rate
                s.attained_service += s.num_gpus * (remaining / rate)
            else:
                s.iters_done += rate * run_time
                s.executed_time += run_time
                s.attained_service += s.num_gpus * run_time

        # jobs not in the plan keep waiting (attain no service)
        for jid, s in states.items():
            if jid not in plan_map and not s.finished:
                s.gpus = frozenset()
