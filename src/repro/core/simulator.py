"""Round-based discrete-event simulator (§5 "Schedulers", §6.2).

The paper validates its simulator against a 32-GPU Perlmutter cluster
(Table 2, max deviation 5.42%) and then runs all large-scale comparisons in
simulation; we inherit that methodology.  Semantics:

* scheduling happens every ``round_duration_s`` (six minutes, §5);
* within a round a job progresses at
  ``isolated_tput(model, gpus, strategy) * packed_factor`` iters/sec,
* a migrated job first pays its migration debt (checkpoint save + load +
  warmup, Fig. 3) before making progress; a *newly started* job pays the
  ``startup_fraction`` of the debt (warmup / initial load only) and a
  *resumed* (previously preempted) job pays ``resume_fraction`` —
  defaulting to the same value, the paper's Fig. 3 model,
* jobs finishing mid-round release GPUs only at the next round boundary
  (round-based semantics; Tesserae "only preempts the job after the job
  finishes the current iteration").

Throughput truth vs. belief: the scheduler consults ``sched_profile``
(possibly noisy / estimated, Figs. 16 & 18) while the simulator advances
jobs with ``true_profile``.

**Fault injection** (:mod:`repro.core.faults`): an optional event stream
drives node-down / node-up / gpu-degrade / job-fail events, applied at
round boundaries.  A node-down evicts every job touching the node WITHOUT
a checkpoint save — progress rolls back to the last checkpoint (the
checkpoint-interval lost-work model), a retry is consumed and the job
re-enters the queue after an exponential backoff; a job that exhausts its
retry budget fails terminally.  Voluntary preemptions and migrations DO
checkpoint (the scheduler drains gracefully), so only genuine crashes
lose work.  GPU degradations slow the job's real rate to the slowest
touched node's ``speed_factor``; a health-BLIND scheduler's beliefs are
unchanged (an undetected straggler), while a health-aware one
(``health_aware=True``) sees the speed factors and drains jobs off
degraded nodes through the relabelling benefit.  With no failure events
every fault code path is inert and the simulation is bit-identical to
the failure-free seed.

**Crash-resume**: ``run(stop_after_rounds=k)`` pauses the loop with all
round state retained; :meth:`Simulator.save_state` /
:meth:`Simulator.load_state` serialise it (one versioned ``.npz``,
embedding the scheduler's :class:`MatchContext` warm state), and a
resumed run finishes bit-identical to an uninterrupted one.  Policy
objects with internal state (Gavel's LP refresh) are NOT captured — use
stateless policies (Tesserae, Tiresias) when snapshotting.
"""

from __future__ import annotations

import dataclasses
import json
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.cluster import ClusterHealth, ClusterSpec, PlacementPlan
from repro.core.faults import (
    GPU_DEGRADE,
    JOB_FAIL,
    NODE_DOWN,
    NODE_UP,
    FailureEvent,
)
from repro.core.jobs import JobSpec, JobState, migration_overhead_s
from repro.core.matching import MatchContext
from repro.core.policies.base import SchedulingPolicy
from repro.core.policies.gavel import GavelPolicy
from repro.core.policies.themis import ThemisFtfPolicy
from repro.core.profiler import GPU_TYPES, ThroughputProfile
from repro.core.scheduler import RoundDecision, TesseraeScheduler
from repro.obs.metrics import MetricsRegistry
from repro.obs.tracer import tracer_of


@dataclasses.dataclass
class SimConfig:
    round_duration_s: float = 360.0
    max_time_s: float = 60 * 24 * 3600.0
    migration_penalty: bool = True
    #: fraction of the migration debt charged on a COLD start (a job's
    #: first placement ever: warmup + initial load, no checkpoint to read)
    startup_fraction: float = 0.5
    #: fraction charged on a RESUME (a preempted job returning to GPUs:
    #: checkpoint load + warmup).  ``None`` = same as ``startup_fraction``
    #: — the paper's Fig. 3 model, and the seed behaviour.
    resume_fraction: Optional[float] = None
    #: speculatively run the next round's decision pipeline after each
    #: round (the simulator knows the exact next active set once the round
    #: has advanced), so the scheduler's :class:`MatchContext` is warm and
    #: the *measured* ``decide()`` critical path collapses to memo/warm
    #: hits.  Models a production scheduler using its idle time between
    #: rounds; off by default so seed timings stay comparable.  The
    #: speculation runs on a background thread that is joined before the
    #: next ``decide`` touches the scheduler, so the sim loop no longer
    #: pays the 2x serial decide work (overlap is reported in
    #: :attr:`SimResult.prewarm_overlap_s`).
    speculative_prewarm: bool = False
    # -- fault-model knobs (all inert without failure events) ------------- #
    #: retries a job may consume (node crashes + software failures both
    #: count) before it fails terminally.
    max_retries: int = 5
    #: backoff before a failed job is eligible again:
    #: ``backoff_base_s * backoff_factor ** (retries - 1)``.
    backoff_base_s: float = 360.0
    backoff_factor: float = 2.0
    #: periodic checkpoint cadence (seconds of EXECUTED time); a crash
    #: rolls progress back to the last checkpoint.  Voluntary migrations
    #: and graceful preemptions always checkpoint first.
    checkpoint_interval_s: float = 1800.0
    #: adapt the periodic cadence per job against the lost-work integral:
    #: once the outage process has been observed (``ClusterHealth``'s
    #: empirical MTBF exists), each job checkpoints at Young's interval
    #: ``sqrt(2 * delta * MTBF_job)`` where ``delta`` is half the job's
    #: migration overhead and ``MTBF_job`` the pooled per-node MTBF divided
    #: by the nodes the job spans (any node failing kills the gang).  The
    #: result is clamped to ``[round_duration_s, checkpoint_interval_s]``
    #: — the sim charges no checkpoint-write cost, so the lower clamp is
    #: what bounds the cadence's aggressiveness.  Off by default (the seed
    #: fixed-interval behaviour).
    adaptive_checkpoint: bool = False


@dataclasses.dataclass
class SimResult:
    jobs: Dict[int, JobState]
    makespan_s: float
    num_rounds: int
    total_migrations: int
    #: per-round scheduler overhead breakdown (schedule/place/pack/migrate)
    overhead: Dict[str, float]
    lp_refresh_s: float
    contention_integral: Dict[int, float]  # job_id -> avg demand/capacity
    #: per-round MatchContext stat deltas (memo/warm/cold instances, price
    #: invalidations) — the identity-keyed warm-start telemetry the churn
    #: replay tests and the CI perf-smoke gate read.
    match_rounds: List[Dict[str, int]] = dataclasses.field(default_factory=list)
    #: total wall time the speculative-prewarm thread spent deciding, and
    #: the portion of it that OVERLAPPED the main sim loop (prewarm wall
    #: minus the time the loop actually blocked waiting for it) — both 0.0
    #: when ``speculative_prewarm`` is off.
    prewarm_wall_s: float = 0.0
    prewarm_overlap_s: float = 0.0
    # -- fault / degradation telemetry ------------------------------------ #
    #: per-round ``DegradeReason`` tags (same length as ``match_rounds``).
    degrade_rounds: List[str] = dataclasses.field(default_factory=list)
    #: involuntary evictions (node-down preemptions) across all jobs.
    preemptions: int = 0
    #: retries consumed across all jobs (crashes + software failures).
    retries_total: int = 0
    #: iterations discarded by crash rollbacks (the lost-work integral).
    lost_iters_total: float = 0.0
    #: jobs that exhausted their retry budget (terminal failures).
    failed_jobs: List[int] = dataclasses.field(default_factory=list)
    #: failure-model events actually applied during the run.
    fault_events_applied: int = 0
    #: seconds of executed time discarded by crash rollbacks (the
    #: lost-work integral the adaptive checkpoint cadence minimises).
    lost_work_s_total: float = 0.0
    #: voluntary migrations that moved a job OFF a degraded node onto
    #: strictly faster ones — the straggler-drain relabel penalty at work.
    drain_migrations: int = 0
    #: the run's metrics registry (repro.obs) — the single aggregation
    #: substrate the simulator records per-round telemetry into.  The
    #: legacy aggregate properties below (``fused_host_fallbacks``,
    #: ``degrade_counts``, ``warm_hit_rounds``, ``total_bid_iters``) are
    #: views over it; per-round detail stays on ``match_rounds`` /
    #: ``degrade_rounds``.
    metrics: MetricsRegistry = dataclasses.field(default_factory=MetricsRegistry)

    @property
    def jcts(self) -> np.ndarray:
        return np.array(
            [s.finish_time - s.spec.arrival_time for s in self.jobs.values()]
        )

    @property
    def avg_jct_s(self) -> float:
        return float(self.jcts.mean())

    @property
    def fused_host_fallbacks(self) -> int:
        """Rounds the fused migrate stage served from the host planner
        (mantissa-budget overflow or non-converged auction)."""
        return self.metrics.counter_value("match.fused_host_fallbacks")

    @property
    def degrade_counts(self) -> Dict[str, int]:
        """Histogram of per-round degradation-ladder steps (``"none"``
        rounds included)."""
        return self.metrics.counters_with_prefix("sim.degrade.")

    def ftf_ratios(self, profile: ThroughputProfile) -> np.ndarray:
        """rho = T_shared / T_fair; T_fair = isolated duration stretched by
        the average demand/capacity contention over the job's lifetime."""
        out = []
        for jid, s in self.jobs.items():
            tput = profile.isolated(s.spec.model, s.num_gpus, "dp")
            iso = s.spec.total_iters / max(tput, 1e-9)
            contention = max(1.0, self.contention_integral.get(jid, 1.0))
            t_fair = iso * contention
            t_shared = s.finish_time - s.spec.arrival_time
            out.append(t_shared / max(t_fair, 1e-9))
        return np.array(out)

    def summary(self, profile: Optional[ThroughputProfile] = None) -> Dict[str, float]:
        d = {
            "avg_jct_s": self.avg_jct_s,
            "p50_jct_s": float(np.median(self.jcts)),
            "p90_jct_s": float(np.percentile(self.jcts, 90)),
            "makespan_s": self.makespan_s,
            "migrations": float(self.total_migrations),
            "rounds": float(self.num_rounds),
            "overhead_total_s": float(sum(self.overhead.values())) + self.lp_refresh_s,
        }
        if profile is not None:
            rho = self.ftf_ratios(profile)
            d["ftf_worst"] = float(rho.max())
            d["ftf_p90"] = float(np.percentile(rho, 90))
        lat = self.metrics.histogram_values("decide.latency_s")
        if lat:
            # SLO telemetry for the online-serving arc: exact nearest-rank
            # percentiles of per-round decide() wall time
            h = self.metrics.histogram("decide.latency_s", timing=True)
            d["decide_p50_s"] = h.percentile(50)
            d["decide_p99_s"] = h.percentile(99)
        return d

    def warm_hit_rounds(self, skip: int = 1) -> int:
        """Rounds (after the first ``skip`` warmup rounds) in which the
        scheduler served at least one LAP instance from its identity-keyed
        context — the churn-replay acceptance metric."""
        warm = self.metrics.histogram_values("match.warm_instances_per_round")
        return sum(1 for v in warm[skip:] if v > 0)

    @property
    def total_bid_iters(self) -> int:
        """Not tracked per round by the scheduler timings — derived from
        the context stats the rounds accumulated (0 when the backend is
        exact)."""
        return self.metrics.counter_value("match.bid_iters")


@dataclasses.dataclass
class _SimState:
    """The whole between-rounds loop state — one object so stop/resume
    and the crash snapshot have a single thing to carry."""

    states: Dict[int, JobState]
    num_gpus_of: Dict[int, int]
    health: ClusterHealth
    now: float = 0.0
    rounds: int = 0
    prev_plan: Optional[PlacementPlan] = None
    prev_gpus: Dict[int, frozenset] = dataclasses.field(default_factory=dict)
    total_migrations: int = 0
    match_rounds: List[Dict[str, int]] = dataclasses.field(default_factory=list)
    overhead: Dict[str, float] = dataclasses.field(default_factory=dict)
    lp_refresh_s: float = 0.0
    contention_num: Dict[int, float] = dataclasses.field(default_factory=dict)
    contention_den: Dict[int, float] = dataclasses.field(default_factory=dict)
    degrade_rounds: List[str] = dataclasses.field(default_factory=list)
    event_idx: int = 0
    events_applied: int = 0
    preemptions: int = 0
    retries_total: int = 0
    lost_iters: float = 0.0
    lost_work_s: float = 0.0
    drain_migrations: int = 0
    failed_jobs: List[int] = dataclasses.field(default_factory=list)
    prewarm_wall: float = 0.0
    prewarm_overlap: float = 0.0


#: version tag of the simulator round-state snapshot format.  v2 adds the
#: per-job ``ckpt_service`` field (crash-accounting fix: LAS service is
#: rewound with the checkpoint) plus the outage counter and drain/lost-work
#: telemetry.
SIM_STATE_VERSION = "tesserae-simstate-v2"

#: JobState fields the snapshot round-trips (spec fields come from the
#: trace the resuming simulator is constructed with).
_JOB_STATE_FIELDS = (
    "iters_done",
    "attained_service",
    "executed_time",
    "first_run_time",
    "finish_time",
    "packed_with",
    "strategy",
    "migrations",
    "migration_debt",
    "retries",
    "preemptions",
    "eligible_time",
    "ckpt_iters",
    "ckpt_executed",
    "ckpt_service",
    "lost_iters",
    "failed",
)


class Simulator:
    def __init__(
        self,
        cluster: ClusterSpec,
        trace: Sequence[JobSpec],
        scheduler: TesseraeScheduler,
        true_profile: ThroughputProfile,
        config: SimConfig | None = None,
        failures: Optional[Sequence[FailureEvent]] = None,
        round_hook=None,
        obs=None,
    ):
        self.cluster = cluster
        self.trace = sorted(trace, key=lambda s: (s.arrival_time, s.job_id))
        self.scheduler = scheduler
        self.true_profile = true_profile
        self.config = config or SimConfig()
        events = sorted(failures or [], key=FailureEvent.sort_key)
        for ev in events:
            if ev.node is not None and not (0 <= ev.node < cluster.num_nodes):
                raise ValueError(
                    f"failure event targets node {ev.node}, cluster has "
                    f"{cluster.num_nodes} nodes"
                )
        self._events: List[FailureEvent] = events
        #: optional per-round callback
        #: ``hook(round_idx, now, decision, states, health)`` invoked after
        #: the round advanced — the chaos suite asserts its safety
        #: invariants here.
        self.round_hook = round_hook
        #: in-progress loop state (``run(stop_after_rounds=...)`` retains
        #: it for :meth:`save_state` / a continued :meth:`run` call).
        self._state: Optional[_SimState] = None
        #: opt-in observability bundle (repro.obs.Observability): span
        #: tracing of the round loop + the scheduler pipeline.  ``None``
        #: (default) keeps every decision code path bit-identical to the
        #: uninstrumented one.  The METRICS registry is always on — it is
        #: pure host-side aggregation of numbers the loop already computes,
        #: and ``SimResult``'s telemetry views read from it.
        self.obs = obs
        if obs is not None and hasattr(scheduler, "set_observability"):
            scheduler.set_observability(obs)
        self._metrics: MetricsRegistry = (
            obs.metrics if obs is not None else MetricsRegistry()
        )

    # ------------------------------------------------------------------ #
    def run(self, stop_after_rounds: Optional[int] = None) -> Optional[SimResult]:
        """Run (or continue) the simulation.

        Returns the :class:`SimResult` when the workload completes.  With
        ``stop_after_rounds=k`` the loop pauses after the k-th round of
        THIS call and returns ``None`` — all state stays on the simulator
        (snapshot it with :meth:`save_state`, or call :meth:`run` again to
        continue).
        """
        cfg = self.config
        tracer = tracer_of(self.obs)
        if self._state is None:
            if self.obs is None:
                # fresh run, internal registry: start clean so a reused
                # Simulator object never double-counts (the previous
                # SimResult keeps its own registry reference)
                self._metrics = MetricsRegistry()
            self._state = _SimState(
                states={s.job_id: JobState(spec=s) for s in self.trace},
                num_gpus_of={s.job_id: s.num_gpus for s in self.trace},
                health=ClusterHealth(self.cluster.num_nodes),
            )
        st = self._state
        rounds_this_call = 0
        executor: Optional[ThreadPoolExecutor] = None
        pending_prewarm = None
        if cfg.speculative_prewarm:
            executor = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="sim-prewarm"
            )

        def _timed_prewarm(spec_active, t, plan, gmap):
            t0 = time.perf_counter()
            # traces into the prewarm thread's own root list (the tracer
            # keeps per-thread span stacks), so speculative decides never
            # nest under the measured round's spans
            with tracer.span("prewarm", jobs=len(spec_active)):
                self.scheduler.prewarm(spec_active, t, plan, gmap)
            return time.perf_counter() - t0

        try:
            while st.now < cfg.max_time_s:
                # the prewarm thread owns the scheduler (MatchContext and
                # policy state) until joined — block before anything below
                # touches it.  Join wait below the prewarm's own wall time
                # is loop work the speculation overlapped with.
                if pending_prewarm is not None:
                    t_join = time.perf_counter()
                    w = pending_prewarm.result()
                    waited = time.perf_counter() - t_join
                    st.prewarm_wall += w
                    st.prewarm_overlap += max(0.0, w - waited)
                    pending_prewarm = None

                self._apply_events(st)

                active = [
                    s
                    for s in st.states.values()
                    if s.spec.arrival_time <= st.now
                    and s.eligible_time <= st.now
                    and not s.finished
                ]
                waiting = [
                    s
                    for s in st.states.values()
                    if not s.finished
                    and (s.spec.arrival_time > st.now or s.eligible_time > st.now)
                ]
                if not active and not waiting:
                    break
                if not active:
                    # idle until the next arrival's (or backoff expiry's)
                    # round boundary; fault events in the skipped window
                    # are applied at the next loop top
                    next_t = min(
                        max(s.spec.arrival_time, s.eligible_time) for s in waiting
                    )
                    k = int(np.floor(next_t / cfg.round_duration_s))
                    now_new = max(
                        st.now + cfg.round_duration_s, k * cfg.round_duration_s
                    )
                    # never skip past a pending fault event's boundary
                    if st.event_idx < len(self._events):
                        ev_t = self._events[st.event_idx].time_s
                        ke = int(np.ceil(ev_t / cfg.round_duration_s))
                        now_new = min(
                            now_new,
                            max(
                                st.now + cfg.round_duration_s,
                                ke * cfg.round_duration_s,
                            ),
                        )
                    st.now = now_new
                    continue

                # LP-based policies re-solve their optimisation once per round.
                if isinstance(self.scheduler.policy, GavelPolicy):
                    st.lp_refresh_s += self.scheduler.policy.refresh(
                        active, self.cluster
                    )
                if isinstance(self.scheduler.policy, ThemisFtfPolicy):
                    demand = sum(j.num_gpus for j in active)
                    self.scheduler.policy.avg_contention = max(
                        1.0, demand / self.cluster.num_gpus
                    )

                # Only pass health when it carries signal the scheduler
                # can act on: a node down (any scheduler routes around
                # it), or — for health-AWARE schedulers only — degraded
                # speeds (straggler drain) / an observed outage history
                # (MTBF hazard for domain spread, which must stay visible
                # after nodes recover).  decide() treats an all-up,
                # full-speed health identically to None (tested), and
                # omitting the kwarg keeps pre-fault decide() overrides
                # (e.g. differential-shadow schedulers) working unchanged.
                health_signal = not st.health.all_up or (
                    getattr(self.scheduler, "health_aware", False)
                    and (st.health.degraded or st.health.outages > 0)
                )
                with tracer.span(
                    "round", index=st.rounds, active=len(active)
                ) as sp_round:
                    if st.health is not None and health_signal:
                        decision = self.scheduler.decide(
                            active,
                            st.now,
                            st.prev_plan,
                            st.num_gpus_of,
                            health=st.health,
                        )
                    else:
                        decision = self.scheduler.decide(
                            active, st.now, st.prev_plan, st.num_gpus_of
                        )
                    st.match_rounds.append(dict(decision.match_stats))
                    st.degrade_rounds.append(decision.degrade_reason)
                    self._record_round_metrics(decision)
                    for k, v in decision.timings.items():
                        st.overhead[k] = st.overhead.get(k, 0.0) + v
                    if decision.migration is not None:
                        st.total_migrations += decision.migration.num_migrations
                    if isinstance(self.scheduler.policy, GavelPolicy):
                        self.scheduler.policy.note_round(
                            [j.job_id for j in decision.placed]
                        )

                    self._advance_round(
                        decision, st.states, st.now, st.prev_gpus,
                        st.num_gpus_of, st.health, sim_state=st,
                    )
                    sp_round.annotate(degrade=decision.degrade_reason)

                plan_map = decision.plan.job_gpu_map()
                st.prev_gpus = dict(plan_map)
                st.prev_plan = decision.plan.restricted_to(
                    [j for j in plan_map if not st.states[j].finished]
                )
                st.now += cfg.round_duration_s
                st.rounds += 1
                rounds_this_call += 1

                if self.round_hook is not None:
                    self.round_hook(
                        st.rounds, st.now, decision, st.states, st.health
                    )

                if executor is not None:
                    # The round has advanced, so the NEXT round's active
                    # set is known exactly; batch its expected LAP
                    # fan-outs through the engine on the prewarm thread
                    # (in production: the scheduler's idle time between
                    # rounds) so the next decide() memo/warm-hits.
                    # Purely a cache side effect — decisions are
                    # unaffected.  The FTF bookkeeping below overlaps it.
                    spec_active = [
                        s
                        for s in st.states.values()
                        if s.spec.arrival_time <= st.now
                        and s.eligible_time <= st.now
                        and not s.finished
                    ]
                    if spec_active:
                        pending_prewarm = executor.submit(
                            _timed_prewarm,
                            spec_active,
                            st.now,
                            st.prev_plan,
                            st.num_gpus_of,
                        )

                # contention bookkeeping for FTF
                demand = sum(j.num_gpus for j in active)
                ratio = demand / self.cluster.num_gpus
                for j in active:
                    st.contention_num[j.job_id] = (
                        st.contention_num.get(j.job_id, 0.0) + ratio
                    )
                    st.contention_den[j.job_id] = (
                        st.contention_den.get(j.job_id, 0.0) + 1.0
                    )

                if (
                    stop_after_rounds is not None
                    and rounds_this_call >= stop_after_rounds
                ):
                    return None  # paused: state retained on self._state
        finally:
            if pending_prewarm is not None:
                st.prewarm_wall += pending_prewarm.result()
            if executor is not None:
                executor.shutdown(wait=True)

        unfinished = [s for s in st.states.values() if not s.finished]
        for s in unfinished:  # should not happen with max_time high enough
            s.finish_time = cfg.max_time_s
        makespan = max((s.finish_time for s in st.states.values()), default=0.0)
        contention = {
            j: st.contention_num[j] / st.contention_den[j]
            for j in st.contention_num
            if st.contention_den.get(j)
        }
        result = SimResult(
            st.states,
            makespan,
            st.rounds,
            st.total_migrations,
            st.overhead,
            st.lp_refresh_s,
            contention,
            st.match_rounds,
            prewarm_wall_s=st.prewarm_wall,
            prewarm_overlap_s=st.prewarm_overlap,
            degrade_rounds=st.degrade_rounds,
            preemptions=st.preemptions,
            retries_total=st.retries_total,
            lost_iters_total=st.lost_iters,
            failed_jobs=list(st.failed_jobs),
            fault_events_applied=st.events_applied,
            lost_work_s_total=st.lost_work_s,
            drain_migrations=st.drain_migrations,
            metrics=self._metrics,
        )
        self._state = None
        return result

    # ------------------------------------------------------------------ #
    # Metrics recording (host-side aggregation; always on, decision-inert)
    # ------------------------------------------------------------------ #
    def _record_round_metrics(self, decision: RoundDecision) -> None:
        """Fold one measured round into the registry.  Only numbers the
        loop already holds on the host — no device reads, no decision
        inputs touched.  ``match_stats`` keys land as ``match.*`` counters
        (so ``SimResult``'s views re-derive the legacy aggregates), the
        per-round warm/bid-iter series as exact histograms, and the stage
        wall times as timing histograms (excluded from deterministic
        snapshots)."""
        m = self._metrics
        m.counter("sim.rounds").inc()
        m.counter("sim.degrade." + decision.degrade_reason).inc()
        for k, v in decision.match_stats.items():
            m.counter("match." + k).inc(int(v))
        m.histogram("match.warm_instances_per_round").observe(
            float(decision.match_stats.get("warm_instances", 0))
        )
        m.histogram("match.bid_iters_per_round").observe(
            float(
                decision.match_stats.get("bid_iters", 0)
                + decision.match_stats.get("fused_bid_iters", 0)
            )
        )
        m.histogram("decide.latency_s", timing=True).observe(
            decision.total_overhead_s
        )
        for k, v in decision.timings.items():
            m.histogram("decide.stage." + k, timing=True).observe(v)

    def _reseed_metrics(self, st: _SimState) -> None:
        """Rebuild the registry's deterministic content from a restored
        snapshot so a resumed run's counters/histograms finish equal to an
        uninterrupted run's.  Wall-clock (timing) histograms are NOT
        reconstructed — timings were never part of bit-identity.  Guarded
        increments mirror the live recording paths exactly: an instrument
        the live run never touched must not exist after a reseed either."""
        m = self._metrics
        if st.match_rounds:
            m.counter("sim.rounds").inc(len(st.match_rounds))
        for rs in st.match_rounds:
            for k, v in rs.items():
                m.counter("match." + k).inc(int(v))
            m.histogram("match.warm_instances_per_round").observe(
                float(rs.get("warm_instances", 0))
            )
            m.histogram("match.bid_iters_per_round").observe(
                float(rs.get("bid_iters", 0) + rs.get("fused_bid_iters", 0))
            )
        for reason in st.degrade_rounds:
            m.counter("sim.degrade." + reason).inc()
        if st.events_applied:
            m.counter("faults.events_applied").inc(st.events_applied)
        if st.preemptions:
            m.counter("faults.preemptions").inc(st.preemptions)
        if st.retries_total:
            m.counter("faults.retries").inc(st.retries_total)
            m.gauge("faults.lost_iters").set(st.lost_iters)
            m.gauge("faults.lost_work_s").set(st.lost_work_s)
        if st.failed_jobs:
            m.counter("faults.failed_jobs").inc(len(st.failed_jobs))

    # ------------------------------------------------------------------ #
    # Fault-event application (round boundaries)
    # ------------------------------------------------------------------ #
    def _apply_events(self, st: _SimState) -> None:
        if not (
            st.event_idx < len(self._events)
            and self._events[st.event_idx].time_s <= st.now
        ):
            return
        with tracer_of(self.obs).span("apply_events") as sp:
            n0 = st.events_applied
            self._apply_events_impl(st)
            applied = st.events_applied - n0
            sp.annotate(applied=applied)
        self._metrics.counter("faults.events_applied").inc(applied)

    def _apply_events_impl(self, st: _SimState) -> None:
        while (
            st.event_idx < len(self._events)
            and self._events[st.event_idx].time_s <= st.now
        ):
            ev = self._events[st.event_idx]
            st.event_idx += 1
            st.events_applied += 1
            if ev.kind == NODE_DOWN:
                if st.health.up[ev.node]:
                    st.health.up[ev.node] = False
                    st.health.speed_factor[ev.node] = 1.0
                    st.health.note_outage()
                    self._evict_node(st, ev.node)
                    self.scheduler.invalidate_node(ev.node)
            elif ev.kind == NODE_UP:
                if not st.health.up[ev.node]:
                    st.health.up[ev.node] = True
                    st.health.speed_factor[ev.node] = 1.0
                    # the node returns empty: its cached occupancy rows are
                    # stale the moment placement starts using it again
                    self.scheduler.invalidate_node(ev.node)
            elif ev.kind == GPU_DEGRADE:
                if st.health.up[ev.node] and st.health.speed_factor[
                    ev.node
                ] != float(ev.factor):
                    st.health.speed_factor[ev.node] = float(ev.factor)
                    # health-aware benefits fold the speed factor into the
                    # relabel penalties, so the node's cached matching
                    # identities (and fused occupancy rows) are stale the
                    # same way a down/up transition makes them — route
                    # degrades AND recoveries (factor back to 1.0) through
                    # the same targeted invalidation; untouched nodes'
                    # warm state survives
                    self.scheduler.invalidate_node(ev.node)
            elif ev.kind == JOB_FAIL:
                s = st.states.get(ev.job_id)
                # only a RUNNING job can crash; a queued/done job is
                # unaffected (the hazard missed)
                if s is not None and not s.finished and s.gpus:
                    self._crash_job(st, s, preempt=False)

    def _evict_node(self, st: _SimState, node: int) -> None:
        """Node-down: every job with at least one GPU on the node crashes
        (no checkpoint save — gang-synchronous training dies whole)."""
        for s in st.states.values():
            if s.finished or not s.gpus:
                continue
            if any(self.cluster.node_of(g) == node for g in s.gpus):
                self._crash_job(st, s, preempt=True)

    def _crash_job(self, st: _SimState, s: JobState, preempt: bool) -> None:
        cfg = self.config
        lost = max(0.0, s.iters_done - s.ckpt_iters)
        s.iters_done = s.ckpt_iters
        s.lost_iters += lost
        st.lost_iters += lost
        # the lost work is gone from EVERY progress metric, not just
        # iters_done: un-rewound, Tiresias' LAS queues would charge the
        # crash victim for service it no longer has (demoting it behind
        # never-crashed peers with identical surviving progress) and the
        # periodic-checkpoint cadence would fire immediately on
        # re-placement (executed_time - ckpt_executed still >= interval)
        st.lost_work_s += max(0.0, s.executed_time - s.ckpt_executed)
        s.attained_service = s.ckpt_service
        s.executed_time = s.ckpt_executed
        s.gpus = frozenset()
        s.packed_with = None
        s.migration_debt = 0.0
        if preempt:
            s.preemptions += 1
            st.preemptions += 1
            self._metrics.counter("faults.preemptions").inc()
        s.retries += 1
        st.retries_total += 1
        self._metrics.counter("faults.retries").inc()
        self._metrics.gauge("faults.lost_iters").set(st.lost_iters)
        self._metrics.gauge("faults.lost_work_s").set(st.lost_work_s)
        # drop the job from the relabelling's view of the previous round so
        # its eventual re-placement is a RESUME (checkpoint load), not a
        # migration of live state that no longer exists
        st.prev_gpus.pop(s.job_id, None)
        if st.prev_plan is not None:
            st.prev_plan.remove_job(s.job_id)
        if s.retries > cfg.max_retries:
            s.failed = True
            s.finish_time = st.now
            st.failed_jobs.append(s.job_id)
            self._metrics.counter("faults.failed_jobs").inc()
        else:
            s.eligible_time = st.now + cfg.backoff_base_s * (
                cfg.backoff_factor ** (s.retries - 1)
            )

    # ------------------------------------------------------------------ #
    def _typed_profile(self, gpus) -> ThroughputProfile:
        """Ground-truth profile for a job on ``gpus`` (physical GPU ids).

        Homogeneous clusters (``node_gpu_types`` unset) always return
        ``true_profile`` itself.  On heterogeneous clusters the job runs
        at the profile of the SLOWEST GPU type it touches (synchronous
        training is bound by its slowest worker)."""
        if self.cluster.node_gpu_types is None or not gpus:
            return self.true_profile
        types = {
            self.cluster.gpu_type_of(self.cluster.node_of(g)) for g in gpus
        }
        slowest = min(types, key=lambda t: (GPU_TYPES[t].speed, t))
        return self.true_profile.for_gpu_type(slowest)

    def _ckpt_interval_s(
        self, s: JobState, health: Optional[ClusterHealth], now: float
    ) -> float:
        """Per-job periodic-checkpoint cadence for this round.

        Fixed ``checkpoint_interval_s`` unless ``adaptive_checkpoint`` is
        on AND the outage process has been observed; then Young's interval
        ``sqrt(2 * delta * MTBF_job)`` with ``delta`` = half the job's
        migration overhead (the checkpoint write is the save half of the
        save+load+warmup cost, Fig. 3) and the job's effective MTBF the
        pooled per-node estimate divided by the nodes it spans (a gang
        dies when ANY of its nodes does).  Clamped to
        ``[round_duration_s, checkpoint_interval_s]``.
        """
        cfg = self.config
        base = cfg.checkpoint_interval_s
        if not cfg.adaptive_checkpoint or health is None:
            return base
        mtbf = health.empirical_mtbf_s(now)
        if mtbf is None:
            return base
        nodes_spanned = len({self.cluster.node_of(g) for g in s.gpus}) or 1
        delta = 0.5 * migration_overhead_s(s.spec.model)
        young = (2.0 * delta * mtbf / nodes_spanned) ** 0.5
        return min(base, max(cfg.round_duration_s, young))

    def _advance_round(
        self,
        decision: RoundDecision,
        states: Dict[int, JobState],
        now: float,
        prev_gpus: Dict[int, frozenset],
        num_gpus_of: Dict[int, int],
        health: Optional[ClusterHealth] = None,
        sim_state: Optional[_SimState] = None,
    ) -> None:
        with tracer_of(self.obs).span("advance_round"):
            self._advance_round_impl(
                decision, states, now, prev_gpus, num_gpus_of, health, sim_state
            )

    def _advance_round_impl(
        self,
        decision: RoundDecision,
        states: Dict[int, JobState],
        now: float,
        prev_gpus: Dict[int, frozenset],
        num_gpus_of: Dict[int, int],
        health: Optional[ClusterHealth] = None,
        sim_state: Optional[_SimState] = None,
    ) -> None:
        cfg = self.config
        plan_map = decision.plan.job_gpu_map()
        packed_partner: Dict[int, int] = {}
        for pending_id, placed_id in decision.packing.matches.items():
            packed_partner[pending_id] = placed_id
            packed_partner[placed_id] = pending_id
        degraded = health is not None and health.degraded

        for jid, gpus in plan_map.items():
            s = states[jid]
            if s.finished:
                continue
            # strategy chosen by the packing matcher applies WHILE PACKED;
            # an unpacked job reverts to its best isolated strategy (dp)
            s.strategy = decision.packing.strategies.get(jid, "dp")
            # migration / startup debt: a job entering the plan from the
            # outside pays the cold-start fraction on its FIRST placement
            # ever (warmup + initial load) and the resume fraction when it
            # returns from preemption (checkpoint load + warmup); a job
            # changing GPUs within the plan pays the full migration debt.
            if cfg.migration_penalty:
                prev = prev_gpus.get(jid)
                if prev is None:
                    cold_start = s.executed_time == 0.0
                    frac = (
                        cfg.startup_fraction
                        if cold_start or cfg.resume_fraction is None
                        else cfg.resume_fraction
                    )
                    s.migration_debt += frac * migration_overhead_s(s.spec.model)
                elif prev != gpus:
                    s.migrations += 1
                    s.migration_debt += migration_overhead_s(s.spec.model)
                    # a voluntary migration checkpoints before moving —
                    # only crashes lose work
                    s.ckpt_iters = s.iters_done
                    s.ckpt_executed = s.executed_time
                    s.ckpt_service = s.attained_service
                    if sim_state is not None and health is not None:
                        # drain telemetry: did this move leave a degraded
                        # node for strictly faster ones?
                        prev_speed = min(
                            health.speed_factor[self.cluster.node_of(g)]
                            for g in prev
                        )
                        new_speed = min(
                            health.speed_factor[self.cluster.node_of(g)]
                            for g in gpus
                        )
                        if prev_speed < 1.0 and new_speed > prev_speed:
                            sim_state.drain_migrations += 1
            s.gpus = gpus

            # heterogeneous clusters: the job's TRUE rate (and packing
            # interference, incl. HBM feasibility) is profiled on the GPU
            # type it actually landed on — the slowest participating node
            # bounds a synchronous job.  Homogeneous clusters return
            # ``true_profile`` itself (the bit-identical seed path).
            prof = self._typed_profile(gpus)
            partner = packed_partner.get(jid)
            factor = 1.0
            if partner is not None and partner in plan_map:
                me, other = s.spec.model, states[partner].spec.model
                na, nb = prof.normalized_packed(
                    me, other, strat_a=s.strategy, strat_b=states[partner].strategy
                )
                factor = na if na > 0 else 1.0
            rate = prof.isolated(s.spec.model, s.num_gpus, s.strategy) * factor
            if degraded:
                # truth-side straggler model: a synchronous job runs at the
                # slowest touched node's speed; the scheduler's beliefs
                # (and hence the plan) are unchanged
                slow = min(
                    health.speed_factor[self.cluster.node_of(g)] for g in gpus
                )
                if slow != 1.0:
                    rate *= slow

            debt = min(s.migration_debt, cfg.round_duration_s)
            s.migration_debt -= debt
            run_time = cfg.round_duration_s - debt
            if s.first_run_time is None:
                s.first_run_time = now + debt
            remaining = s.remaining_iters()
            if rate * run_time >= remaining and rate > 0:
                finish_delay = debt + remaining / rate
                s.iters_done = s.spec.total_iters
                s.finish_time = now + finish_delay
                s.executed_time += remaining / rate
                s.attained_service += s.num_gpus * (remaining / rate)
            else:
                s.iters_done += rate * run_time
                s.executed_time += run_time
                s.attained_service += s.num_gpus * run_time
                # periodic checkpoint (inert bookkeeping until a crash
                # reads it): cadence measured in executed time
                if (
                    s.executed_time - s.ckpt_executed
                    >= self._ckpt_interval_s(s, health, now)
                ):
                    s.ckpt_iters = s.iters_done
                    s.ckpt_executed = s.executed_time
                    s.ckpt_service = s.attained_service

        # jobs not in the plan keep waiting (attain no service); a job the
        # scheduler just released drained gracefully, i.e. it checkpointed
        for jid, s in states.items():
            if jid not in plan_map and not s.finished:
                if s.gpus:
                    s.ckpt_iters = s.iters_done
                    s.ckpt_executed = s.executed_time
                    s.ckpt_service = s.attained_service
                s.gpus = frozenset()

    # ------------------------------------------------------------------ #
    # Crash snapshot / resume
    # ------------------------------------------------------------------ #
    def save_state(self, path: str) -> None:
        """Serialise the paused round state (see ``run(stop_after_rounds)``)
        plus the scheduler's :class:`MatchContext` warm state into one
        versioned ``.npz``.  A simulator constructed with the same
        (cluster, trace, scheduler config, failures) that calls
        :meth:`load_state` then :meth:`run` finishes bit-identical to the
        uninterrupted run.  Policy-internal state (Gavel's LP) is not
        captured."""
        st = self._state
        if st is None:
            raise RuntimeError(
                "no paused run to snapshot — call run(stop_after_rounds=k) first"
            )
        jobs_meta: Dict[str, Dict] = {}
        for jid, s in st.states.items():
            d = {f: getattr(s, f) for f in _JOB_STATE_FIELDS}
            d["gpus"] = sorted(int(g) for g in s.gpus)
            jobs_meta[str(jid)] = d
        meta = {
            "version": SIM_STATE_VERSION,
            "now": st.now,
            "rounds": st.rounds,
            "total_migrations": st.total_migrations,
            "lp_refresh_s": st.lp_refresh_s,
            "event_idx": st.event_idx,
            "events_applied": st.events_applied,
            "preemptions": st.preemptions,
            "retries_total": st.retries_total,
            "lost_iters": st.lost_iters,
            "lost_work_s": st.lost_work_s,
            "drain_migrations": st.drain_migrations,
            "health_outages": st.health.outages,
            "failed_jobs": st.failed_jobs,
            "degrade_rounds": st.degrade_rounds,
            "overhead": st.overhead,
            "match_rounds": st.match_rounds,
            "contention_num": {str(k): v for k, v in st.contention_num.items()},
            "contention_den": {str(k): v for k, v in st.contention_den.items()},
            "prev_gpus": {
                str(j): sorted(int(g) for g in gs)
                for j, gs in st.prev_gpus.items()
            },
            "jobs": jobs_meta,
            "has_prev_plan": st.prev_plan is not None,
            "prewarm_wall": st.prewarm_wall,
            "prewarm_overlap": st.prewarm_overlap,
        }
        ctx_meta, ctx_arrays = self.scheduler.match_context.state_payload()
        meta["ctx"] = ctx_meta
        arrays = {f"ctx.{k}": v for k, v in ctx_arrays.items()}
        arrays["health_up"] = st.health.up
        arrays["health_speed"] = st.health.speed_factor
        if st.prev_plan is not None:
            arrays["prev_plan"] = st.prev_plan.slots
        arrays["meta_json"] = np.array(json.dumps(meta))
        with open(path, "wb") as f:
            np.savez(f, **arrays)

    def load_state(self, path: str) -> None:
        """Restore a :meth:`save_state` snapshot into this simulator (and
        its scheduler's :class:`MatchContext`); the next :meth:`run` call
        continues from the saved round."""
        with np.load(path, allow_pickle=False) as z:
            meta = json.loads(str(z["meta_json"][()]))
            if meta.get("version") != SIM_STATE_VERSION:
                raise ValueError(
                    f"{path}: simulator state version {meta.get('version')!r} "
                    f"!= {SIM_STATE_VERSION!r}"
                )
            states: Dict[int, JobState] = {
                s.job_id: JobState(spec=s) for s in self.trace
            }
            for jid_s, d in meta["jobs"].items():
                s = states[int(jid_s)]
                for f in _JOB_STATE_FIELDS:
                    setattr(s, f, d[f])
                s.gpus = frozenset(int(g) for g in d["gpus"])
            health = ClusterHealth(self.cluster.num_nodes)
            health.up = np.asarray(z["health_up"], bool).copy()
            health.speed_factor = np.asarray(z["health_speed"], np.float64).copy()
            health.outages = int(meta["health_outages"])
            prev_plan = None
            if meta["has_prev_plan"]:
                prev_plan = PlacementPlan(
                    self.cluster, np.asarray(z["prev_plan"], np.int64).copy()
                )
            self._state = _SimState(
                states=states,
                num_gpus_of={s.job_id: s.num_gpus for s in self.trace},
                health=health,
                now=float(meta["now"]),
                rounds=int(meta["rounds"]),
                prev_plan=prev_plan,
                prev_gpus={
                    int(j): frozenset(int(g) for g in gs)
                    for j, gs in meta["prev_gpus"].items()
                },
                total_migrations=int(meta["total_migrations"]),
                match_rounds=list(meta["match_rounds"]),
                overhead=dict(meta["overhead"]),
                lp_refresh_s=float(meta["lp_refresh_s"]),
                contention_num={
                    int(k): v for k, v in meta["contention_num"].items()
                },
                contention_den={
                    int(k): v for k, v in meta["contention_den"].items()
                },
                degrade_rounds=list(meta["degrade_rounds"]),
                event_idx=int(meta["event_idx"]),
                events_applied=int(meta["events_applied"]),
                preemptions=int(meta["preemptions"]),
                retries_total=int(meta["retries_total"]),
                lost_iters=float(meta["lost_iters"]),
                lost_work_s=float(meta["lost_work_s"]),
                drain_migrations=int(meta["drain_migrations"]),
                failed_jobs=[int(j) for j in meta["failed_jobs"]],
                prewarm_wall=float(meta["prewarm_wall"]),
                prewarm_overlap=float(meta["prewarm_overlap"]),
            )
            self.scheduler.match_context = MatchContext.from_payload(
                meta["ctx"], lambda name: z[f"ctx.{name}"]
            )
            # the fused planner's device cache is NOT serialised: a cold
            # cache only costs one all-dirty fused round, never changes the
            # plan (the fused program is exact within its budget)
            if self.scheduler._fused_planner is not None:
                self.scheduler._fused_planner.invalidate()
            # fresh registry, reseeded from the snapshot's deterministic
            # telemetry so the resumed run's counters finish equal to an
            # uninterrupted run's (timing histograms excepted — wall time
            # was never part of bit-identity).  Re-attach obs to the
            # restored MatchContext (from_payload builds a bare one).
            self._metrics = (
                self.obs.metrics if self.obs is not None else MetricsRegistry()
            )
            self._metrics.reset()
            self._reseed_metrics(self._state)
            if self.obs is not None and hasattr(
                self.scheduler, "set_observability"
            ):
                self.scheduler.set_observability(self.obs)
