"""Scheduling (priority-ordering) policies Tesserae composes with.

Tesserae deliberately does NOT invent a scheduling policy: it consumes the
priority order produced by an existing one (§3.1).  We implement the ones
the paper evaluates with — FIFO, SRTF, Tiresias 2D-LAS, Themis FTF — plus
the optimisation-based baselines Gavel (LP) and POP (partitioned LP), which
are *whole schedulers* used for the scalability and JCT comparisons.
"""

from repro.core.policies.base import SchedulingPolicy
from repro.core.policies.simple import FifoPolicy, SrtfPolicy
from repro.core.policies.tiresias import TiresiasPolicy
from repro.core.policies.themis import ThemisFtfPolicy
from repro.core.policies.gavel import GavelPolicy, PopPolicy
from repro.core.policies.failure_aware import FailureAwarePolicy

POLICIES = {
    "fifo": FifoPolicy,
    "srtf": SrtfPolicy,
    "tiresias": TiresiasPolicy,
    "ftf": ThemisFtfPolicy,
    "gavel": GavelPolicy,
    "pop": PopPolicy,
}

__all__ = [
    "SchedulingPolicy",
    "FifoPolicy",
    "SrtfPolicy",
    "TiresiasPolicy",
    "ThemisFtfPolicy",
    "GavelPolicy",
    "PopPolicy",
    "FailureAwarePolicy",
    "POLICIES",
]
