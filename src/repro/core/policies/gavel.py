"""Gavel (OSDI'20) and POP (SOSP'21) optimisation-based baselines.

Gavel folds scheduling + placement + packing into one linear program; POP
partitions that LP into k independent sub-problems to claw back
scalability.  We implement the single-GPU-type LAS variant with
space-sharing, which is what Figs. 2/11/14 compare against:

  max  sum_j w_j * ( tput_j * x_j + sum_k ctput_{jk} * x_{jk} )
  s.t. x_j + sum_k x_{jk} <= 1                 (per-job time fraction)
       sum_j g_j x_j + sum_{j<k} g_j x_{jk} <= G   (capacity; a packed pair
                                                    shares one set of GPUs)
       x >= 0

with w_j = 1 / (attained service + eps) (LAS weighting) and pair variables
x_{jk} only for equal-GPU-count packable pairs — the O(n^2) variable count
that causes the scalability cliff of Fig. 2.

The LP solution doubles as a *priority score* (Gavel's round-based
mechanism): priority_j = target allocation / (received allocation + eps),
which `GavelPolicy.sort_key` feeds to the round executor.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.cluster import ClusterSpec
from repro.core.jobs import JobState
from repro.core.policies.base import SchedulingPolicy
from repro.core.profiler import ThroughputProfile


@dataclasses.dataclass
class LpSolution:
    #: job_id -> solo time fraction
    solo: Dict[int, float]
    #: (job_id_a, job_id_b) -> packed time fraction
    pairs: Dict[Tuple[int, int], float]
    objective: float
    wall_time_s: float
    num_variables: int


def solve_gavel_lp(
    jobs: Sequence[JobState],
    profile: ThroughputProfile,
    cluster: ClusterSpec,
    packing: bool = True,
    max_pairs: int | None = None,
) -> LpSolution:
    """Build and solve the Gavel LP with scipy's HiGHS backend."""
    from scipy.optimize import linprog
    from scipy.sparse import lil_matrix

    t0 = time.perf_counter()
    n = len(jobs)
    # pair variables: equal gpu count, both packable, j < k
    pair_idx: List[Tuple[int, int]] = []
    if packing:
        by_gpus: Dict[int, List[int]] = {}
        for i, j in enumerate(jobs):
            if j.spec.packable:
                by_gpus.setdefault(j.num_gpus, []).append(i)
        for group in by_gpus.values():
            for a_pos, i in enumerate(group):
                for k in group[a_pos + 1 :]:
                    pair_idx.append((i, k))
                    if max_pairs is not None and len(pair_idx) >= max_pairs:
                        break
                if max_pairs is not None and len(pair_idx) >= max_pairs:
                    break
            if max_pairs is not None and len(pair_idx) >= max_pairs:
                break
    p = len(pair_idx)
    nv = n + p

    w = np.array(
        [1.0 / (j.attained_service + 3600.0) for j in jobs]
    )  # LAS weight
    tput = np.array(
        [
            profile.isolated(j.spec.model, j.num_gpus, j.strategy)
            for j in jobs
        ]
    )
    c = np.zeros(nv)
    c[:n] = -(w * tput)  # linprog minimises
    for v, (i, k) in enumerate(pair_idx):
        a, b = jobs[i], jobs[k]
        na, nb = profile.normalized_packed(a.spec.model, b.spec.model)
        ctput = na * tput[i] + nb * tput[k]
        c[n + v] = -(0.5 * (w[i] + w[k]) * ctput)

    a_ub = lil_matrix((n + 1, nv))
    b_ub = np.ones(n + 1)
    for i in range(n):  # per-job time fraction
        a_ub[i, i] = 1.0
    for v, (i, k) in enumerate(pair_idx):
        a_ub[i, n + v] = 1.0
        a_ub[k, n + v] = 1.0
    # capacity row
    for i, j in enumerate(jobs):
        a_ub[n, i] = j.num_gpus
    for v, (i, k) in enumerate(pair_idx):
        a_ub[n, n + v] = jobs[i].num_gpus
    b_ub[n] = cluster.num_gpus

    res = linprog(
        c,
        A_ub=a_ub.tocsr(),
        b_ub=b_ub,
        bounds=(0, 1),
        method="highs",
    )
    x = res.x if res.x is not None else np.zeros(nv)
    solo = {jobs[i].job_id: float(x[i]) for i in range(n)}
    pairs = {
        (jobs[i].job_id, jobs[k].job_id): float(x[n + v])
        for v, (i, k) in enumerate(pair_idx)
        if x[n + v] > 1e-6
    }
    return LpSolution(
        solo, pairs, -float(res.fun or 0.0), time.perf_counter() - t0, nv
    )


class GavelPolicy(SchedulingPolicy):
    """Priority order derived from the LP allocation targets.

    The simulator refreshes ``self.solution`` once per round (that solve IS
    Gavel's decision-making overhead, Fig. 2); between solves the sort key
    is (received - target), smaller (more starved) first.
    """

    name = "gavel"
    packing_in_lp = True

    def __init__(self, profile=None, cluster: ClusterSpec | None = None):
        super().__init__(profile)
        self.cluster = cluster
        self.solution: LpSolution | None = None
        self._received: Dict[int, float] = {}

    def refresh(self, jobs: Sequence[JobState], cluster: ClusterSpec) -> float:
        self.solution = solve_gavel_lp(
            jobs, self.profile, cluster, packing=self.packing_in_lp
        )
        return self.solution.wall_time_s

    def note_round(self, ran_job_ids) -> None:
        for j in ran_job_ids:
            self._received[j] = self._received.get(j, 0.0) + 1.0

    def sort_key(self, job: JobState, now: float, cluster: ClusterSpec):
        target = 0.0
        if self.solution is not None:
            target = self.solution.solo.get(job.job_id, 0.0)
            for (a, b), frac in self.solution.pairs.items():
                if job.job_id in (a, b):
                    target += frac
        received = self._received.get(job.job_id, 0.0)
        rounds = max(sum(self._received.values()), 1.0)
        return received / rounds - target  # most starved (neg) first


class PopPolicy(GavelPolicy):
    """POP: partition the Gavel LP into ceil(n / partition_size) pieces,
    each owning an equal slice of the cluster, and solve independently."""

    name = "pop"

    def __init__(self, profile=None, cluster=None, partition_size: int = 256):
        super().__init__(profile, cluster)
        self.partition_size = partition_size

    def refresh(self, jobs: Sequence[JobState], cluster: ClusterSpec) -> float:
        n = len(jobs)
        k = max(1, int(np.ceil(n / self.partition_size)))
        rng = np.random.default_rng(0)
        perm = rng.permutation(n)
        total_t = 0.0
        solo: Dict[int, float] = {}
        pairs: Dict[Tuple[int, int], float] = {}
        sub_cluster = ClusterSpec(
            max(1, cluster.num_nodes // k), cluster.gpus_per_node, cluster.gpu_type
        )
        nvars = 0
        for part in range(k):
            sel = [jobs[i] for i in perm[part::k]]
            if not sel:
                continue
            sol = solve_gavel_lp(sel, self.profile, sub_cluster, packing=True)
            total_t += sol.wall_time_s
            solo.update(sol.solo)
            pairs.update(sol.pairs)
            nvars += sol.num_variables
        self.solution = LpSolution(solo, pairs, 0.0, total_t, nvars)
        return total_t
