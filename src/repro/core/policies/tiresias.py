"""Tiresias 2D-LAS (Gu et al., NSDI'19).

Priority = least attained service, where service is the two-dimensional
product GPUs x executed-time.  Tiresias discretises service into queues to
avoid thrashing; we keep the discretisation (log-spaced thresholds) so jobs
within a queue are FIFO-ordered, exactly the behaviour the paper's
baselines exercise.
"""

from __future__ import annotations

import math

from repro.core.cluster import ClusterSpec
from repro.core.jobs import JobState
from repro.core.policies.base import SchedulingPolicy


class TiresiasPolicy(SchedulingPolicy):
    name = "tiresias"

    #: queue thresholds in GPU-seconds (log spaced; first queue ~ 1 GPU-hour)
    def __init__(self, profile=None, queue_base: float = 3600.0, num_queues: int = 5):
        super().__init__(profile)
        self.queue_base = queue_base
        self.num_queues = num_queues

    def queue_of(self, service: float) -> int:
        if service <= 0:
            return 0
        q = int(math.floor(math.log2(service / self.queue_base) + 1))
        return max(0, min(q, self.num_queues - 1))

    def sort_key(self, job: JobState, now: float, cluster: ClusterSpec):
        q = self.queue_of(job.attained_service)
        # within a queue: FIFO by arrival (2D-LAS demotes as service grows)
        return (q, job.spec.arrival_time)
