"""Themis finish-time-fairness (FTF) priority (Mahajan et al., NSDI'20).

FTF ratio rho = T_shared / T_fair where T_fair is the job's finish time in
an isolated cluster of 1/N-th the resources.  Themis runs an auction giving
GPUs to the jobs with the *worst* (largest) projected rho; as a priority
order that means sorting by descending rho estimate.
"""

from __future__ import annotations

from repro.core.cluster import ClusterSpec
from repro.core.jobs import JobState
from repro.core.policies.base import SchedulingPolicy


class ThemisFtfPolicy(SchedulingPolicy):
    name = "ftf"

    def __init__(self, profile=None, avg_contention: float = 4.0):
        super().__init__(profile)
        #: running estimate of cluster contention (jobs per fair share);
        #: updated by the simulator each round.
        self.avg_contention = avg_contention

    def rho(self, job: JobState, now: float, cluster: ClusterSpec) -> float:
        tput = self.profile.isolated(job.spec.model, job.num_gpus, job.strategy)
        iso_total = job.spec.total_iters / max(tput, 1e-9)
        # T_fair: isolated duration stretched by contention for its share.
        t_fair = job.spec.arrival_time + iso_total * max(self.avg_contention, 1.0)
        remaining = job.remaining_iters() / max(tput, 1e-9)
        t_shared_proj = now + remaining
        return (t_shared_proj - job.spec.arrival_time) / max(
            t_fair - job.spec.arrival_time, 1e-9
        )

    def sort_key(self, job: JobState, now: float, cluster: ClusterSpec):
        return -self.rho(job, now, cluster)  # worst-off first
