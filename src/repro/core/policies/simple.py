"""FIFO and SRTF priority orders."""

from __future__ import annotations

from repro.core.cluster import ClusterSpec
from repro.core.jobs import JobState
from repro.core.policies.base import SchedulingPolicy


class FifoPolicy(SchedulingPolicy):
    name = "fifo"

    def sort_key(self, job: JobState, now: float, cluster: ClusterSpec):
        return job.spec.arrival_time


class SrtfPolicy(SchedulingPolicy):
    """Shortest remaining (estimated) time first."""

    name = "srtf"

    def sort_key(self, job: JobState, now: float, cluster: ClusterSpec):
        tput = self.profile.isolated(job.spec.model, job.num_gpus, job.strategy)
        return job.remaining_iters() / max(tput, 1e-9)
