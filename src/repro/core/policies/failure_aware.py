"""Failure-aware policy wrapper (MTBF-aware consolidation, sort-key side).

Wraps any base policy and appends a domain-spread term to its sort key:
when the scheduler observes a HOT outage process (empirical per-node MTBF
from the applied ``FailureEvent`` stream below its ``spread_mtbf_h``
threshold — see ``ClusterHealth.hazard_hot``), multi-node gangs are
boosted ahead of their queue peers so they get first pick of the empty
nodes, which the placement stage then spreads breadth-first across racks
(``place_without_packing(spread_domains=True)``).  A single rack outage
then clips one node's worth of a large gang instead of killing the whole
thing's consolidated placement.

When the process is cold (or health tracking is off) the appended term is
a constant, so the wrapped order is IDENTICAL to the inner policy's —
clean traces, and degraded-but-not-failing clusters, see the seed order
bit-for-bit.  The scheduler drives the hot flag each round through
:meth:`set_spread_hot`; the wrapper never reads the clock itself, keeping
the policy pure and replay-deterministic.
"""

from __future__ import annotations

from repro.core.cluster import ClusterSpec
from repro.core.jobs import JobState
from repro.core.policies.base import SchedulingPolicy


class FailureAwarePolicy(SchedulingPolicy):
    """Decorates ``inner`` with the hot-outage gang-spread boost."""

    def __init__(self, inner: SchedulingPolicy):
        super().__init__(inner.profile)
        self.inner = inner
        self.name = inner.name + "-fa"
        self._spread_hot = False

    def set_spread_hot(self, hot: bool) -> None:
        """Scheduler hook: called once per decide() with the current
        empirical-hazard verdict."""
        self._spread_hot = bool(hot)

    def sort_key(self, job: JobState, now: float, cluster: ClusterSpec):
        key = self.inner.sort_key(job, now, cluster)
        if not self._spread_hot:
            # constant append: preserves the inner order exactly
            return (key, 1)
        # hot outage process: multi-node gangs first within the inner
        # ordering tier would break the inner policy's fairness — instead
        # the boost is SUBORDINATE to the inner key (same tuple position),
        # so equal-priority jobs reorder gang-first but queue discipline
        # is untouched.
        is_gang = job.num_gpus > cluster.gpus_per_node
        return (key, 0 if is_gang else 1)
