"""Scheduling-policy interface (Listing 1 line 3: "Sort active_jobs")."""

from __future__ import annotations

import abc
from typing import List, Sequence

from repro.core.cluster import ClusterSpec
from repro.core.jobs import JobState
from repro.core.profiler import ThroughputProfile


class SchedulingPolicy(abc.ABC):
    """Produces the priority ORDER of active jobs; placement is Tesserae's."""

    name = "base"

    def __init__(self, profile: ThroughputProfile | None = None):
        self.profile = profile or ThroughputProfile()

    @abc.abstractmethod
    def sort_key(self, job: JobState, now: float, cluster: ClusterSpec):
        """Smaller key = higher priority."""

    def order(
        self, jobs: Sequence[JobState], now: float, cluster: ClusterSpec
    ) -> List[JobState]:
        # Stable sort; ties broken by arrival then id for determinism.
        return sorted(
            jobs,
            key=lambda j: (
                self.sort_key(j, now, cluster),
                j.spec.arrival_time,
                j.job_id,
            ),
        )
