"""Tesserae core: graph-matching placement policies for DL cluster scheduling.

Public API:

* :class:`repro.core.scheduler.TesseraeScheduler` — the round scheduler
  (Listing 1) composing any :class:`~repro.core.policies.SchedulingPolicy`
  with the graph-based migration (§4.1) and packing (§4.2) policies.
* :class:`repro.core.simulator.Simulator` — round-based cluster simulator.
* :mod:`repro.core.matching` — LAP solvers (numpy Hungarian, scipy, JAX
  auction).
"""

from repro.core.cluster import ClusterSpec, PlacementPlan, count_migrations
from repro.core.jobs import JobSpec, JobState
from repro.core.migration import plan_migration, plan_migration_batched_auction
from repro.core.packing import pack_jobs
from repro.core.placement import place_without_packing
from repro.core.profiler import ThroughputProfile, register_model
from repro.core.scheduler import TesseraeScheduler, tiresias_single_packed_ok
from repro.core.simulator import SimConfig, Simulator

__all__ = [
    "ClusterSpec",
    "PlacementPlan",
    "count_migrations",
    "JobSpec",
    "JobState",
    "plan_migration",
    "plan_migration_batched_auction",
    "pack_jobs",
    "place_without_packing",
    "ThroughputProfile",
    "register_model",
    "TesseraeScheduler",
    "tiresias_single_packed_ok",
    "SimConfig",
    "Simulator",
]
