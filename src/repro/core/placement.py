"""Allocation without packing (Listing 1 lines 5-12, Fig. 5).

Given the priority-sorted active jobs, place as many as possible on empty
GPUs subject to **consolidated placement**:

* a job needing ``g <= gpus_per_node`` GPUs must get all of them on one
  node (best-fit: the node with the fewest free GPUs that still fits, to
  keep large holes open for large jobs);
* a job needing ``g > gpus_per_node`` GPUs must get whole nodes.

Placement can fail (line 8) when no consolidated hole exists even if the
total free GPU count suffices — those jobs go to ``pending_jobs`` and
become packing candidates (Algorithm 4).

On **heterogeneous** clusters the best-fit key additionally carries a
type-affinity term (``type_affinity=True``): sub-node jobs prefer the
SLOWEST GPU type that still fits before tie-breaking on hole size, and
multi-node gangs take the fastest empty nodes.  Without it, a 1-GPU job
arriving first can squat an A100 node while an 8-GPU gang lands on V100s
— the type-blindness bug; the affinity key is the minimal fix (the full
Gavel policy-as-optimization treatment stays future work).  On
homogeneous clusters every speed ties and the order degenerates
bit-identically to the seed best-fit.
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cluster import EMPTY, ClusterSpec, PlacementPlan
from repro.core.jobs import JobState
from repro.core.profiler import GPU_TYPES


def _node_speeds(cluster: ClusterSpec) -> Optional[np.ndarray]:
    """Per-node relative GPU speed, or None when every node ties (the
    homogeneous fast path — no key change at all)."""
    if not cluster.is_heterogeneous:
        return None
    return np.array([GPU_TYPES[t].speed for t in cluster.node_types()])


def place_without_packing(
    cluster: ClusterSpec,
    sorted_jobs: Sequence[JobState],
    type_affinity: bool = True,
    down_nodes: Optional[Iterable[int]] = None,
    spread_domains: bool = False,
) -> Tuple[PlacementPlan, List[JobState], List[JobState]]:
    """Greedy consolidated placement of priority-sorted jobs.

    Returns ``(plan, placed_jobs, pending_jobs)``.  Mirrors Listing 1: we
    keep walking the priority list while any GPU remains free, so a small
    job can fill a hole a larger, higher-priority job could not use.
    ``down_nodes`` are zero capacity: no hole on them is ever considered,
    so a down node's logical rows stay empty in the returned plan.
    ``spread_domains`` (failure-aware policies, racked clusters only)
    reorders each multi-node gang's candidate empty nodes breadth-first
    across racks, so a gang spans the maximum number of failure domains a
    single outage can only clip — instead of the default packing order
    that concentrates it in one rack.  Off (default) = seed behaviour.
    """
    plan = PlacementPlan(cluster)
    placed: List[JobState] = []
    pending: List[JobState] = []
    free_per_node = np.full(cluster.num_nodes, cluster.gpus_per_node, np.int64)
    if down_nodes is not None:
        for n in down_nodes:
            free_per_node[int(n)] = 0
    gpn = cluster.gpus_per_node
    speeds = _node_speeds(cluster) if type_affinity else None

    for job in sorted_jobs:
        g = job.num_gpus
        if free_per_node.sum() <= 0:
            pending.append(job)
            continue
        if g <= gpn:
            candidates = np.nonzero(free_per_node >= g)[0]
            if len(candidates) == 0:
                pending.append(job)
                continue
            if speeds is None:
                # best fit: smallest adequate hole (first index on ties)
                node = int(candidates[np.argmin(free_per_node[candidates])])
            else:
                # type-affinity best fit: the job runs at its node's
                # speed, so break hole-size ties toward the FASTEST type
                # (explicitly — not via the index-order accident) while
                # still filling partial holes before opening empty nodes
                order = np.lexsort(
                    (candidates, -speeds[candidates], free_per_node[candidates])
                )
                node = int(candidates[order[0]])
            gpus = _take_free_gpus(plan, node, g)
        else:
            if g % gpn != 0:
                raise ValueError(
                    f"job {job.job_id}: {g} GPUs not a multiple of node size {gpn}"
                )
            need_nodes = g // gpn
            empty_nodes = np.nonzero(free_per_node == gpn)[0]
            if len(empty_nodes) < need_nodes:
                pending.append(job)
                continue
            if speeds is not None and len(empty_nodes) >= need_nodes:
                # a gang runs at the pace of its SLOWEST node, so a
                # type-mixed gang throttles every fast GPU it holds to
                # the slow type's speed (the squat bug's worst case).
                # Prefer a type-PURE node set — fastest pure type first —
                # and fall back to the maximum-min-speed mixed set only
                # when no single type has enough empty nodes.
                esp = speeds[empty_nodes]
                pure = None
                for sp in sorted(set(esp.tolist()), reverse=True):
                    ns = empty_nodes[esp == sp]
                    if len(ns) >= need_nodes:
                        pure = ns
                        break
                empty_nodes = (
                    pure
                    if pure is not None
                    else empty_nodes[np.lexsort((empty_nodes, -esp))]
                )
            if spread_domains and cluster.has_topology and need_nodes > 1:
                # breadth-first across racks: take each rack's first empty
                # node before any rack's second, preserving the incoming
                # order (type-pure / best-speed) within each rack, so the
                # prefix empty_nodes[:need_nodes] spans max failure domains
                racks = np.array(
                    [cluster.rack_of(int(n)) for n in empty_nodes]
                )
                within = np.zeros(len(empty_nodes), dtype=np.int64)
                seen: Dict[int, int] = {}
                for i, r in enumerate(racks.tolist()):
                    within[i] = seen.get(r, 0)
                    seen[r] = within[i] + 1
                order = np.lexsort((np.arange(len(empty_nodes)), racks, within))
                empty_nodes = empty_nodes[order]
            gpus = []
            for node in empty_nodes[:need_nodes]:
                gpus.extend(_take_free_gpus(plan, int(node), gpn))
        plan.place_job(job.job_id, gpus)
        for gid in gpus:
            free_per_node[cluster.node_of(gid)] -= 1
        placed.append(job)
    return plan, placed, pending


def _take_free_gpus(plan: PlacementPlan, node: int, count: int) -> List[int]:
    cluster = plan.cluster
    out: List[int] = []
    for local in range(cluster.gpus_per_node):
        if (plan.slots[node, local] == EMPTY).all():
            out.append(cluster.gpu_id(node, local))
            if len(out) == count:
                return out
    raise RuntimeError(f"node {node} lacks {count} free GPUs")  # pragma: no cover


def apply_packing(
    plan: PlacementPlan,
    matches: Dict[int, int],
    placed_lookup: Dict[int, JobState],
) -> PlacementPlan:
    """Overlay pending jobs onto their matched placed jobs' GPUs."""
    out = plan.copy()
    for pending_id, placed_id in matches.items():
        gpus = out.gpus_of_job(placed_id)
        out.place_job(pending_id, gpus)
    return out
