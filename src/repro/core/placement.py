"""Allocation without packing (Listing 1 lines 5-12, Fig. 5).

Given the priority-sorted active jobs, place as many as possible on empty
GPUs subject to **consolidated placement**:

* a job needing ``g <= gpus_per_node`` GPUs must get all of them on one
  node (best-fit: the node with the fewest free GPUs that still fits, to
  keep large holes open for large jobs);
* a job needing ``g > gpus_per_node`` GPUs must get whole nodes.

Placement can fail (line 8) when no consolidated hole exists even if the
total free GPU count suffices — those jobs go to ``pending_jobs`` and
become packing candidates (Algorithm 4).
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

from repro.core.cluster import EMPTY, ClusterSpec, PlacementPlan
from repro.core.jobs import JobState


def place_without_packing(
    cluster: ClusterSpec,
    sorted_jobs: Sequence[JobState],
) -> Tuple[PlacementPlan, List[JobState], List[JobState]]:
    """Greedy consolidated placement of priority-sorted jobs.

    Returns ``(plan, placed_jobs, pending_jobs)``.  Mirrors Listing 1: we
    keep walking the priority list while any GPU remains free, so a small
    job can fill a hole a larger, higher-priority job could not use.
    """
    plan = PlacementPlan(cluster)
    placed: List[JobState] = []
    pending: List[JobState] = []
    free_per_node = np.full(cluster.num_nodes, cluster.gpus_per_node, np.int64)
    gpn = cluster.gpus_per_node

    for job in sorted_jobs:
        g = job.num_gpus
        if free_per_node.sum() <= 0:
            pending.append(job)
            continue
        if g <= gpn:
            # best fit: smallest adequate hole
            candidates = np.nonzero(free_per_node >= g)[0]
            if len(candidates) == 0:
                pending.append(job)
                continue
            node = int(candidates[np.argmin(free_per_node[candidates])])
            gpus = _take_free_gpus(plan, node, g)
        else:
            if g % gpn != 0:
                raise ValueError(
                    f"job {job.job_id}: {g} GPUs not a multiple of node size {gpn}"
                )
            need_nodes = g // gpn
            empty_nodes = np.nonzero(free_per_node == gpn)[0]
            if len(empty_nodes) < need_nodes:
                pending.append(job)
                continue
            gpus = []
            for node in empty_nodes[:need_nodes]:
                gpus.extend(_take_free_gpus(plan, int(node), gpn))
        plan.place_job(job.job_id, gpus)
        for gid in gpus:
            free_per_node[cluster.node_of(gid)] -= 1
        placed.append(job)
    return plan, placed, pending


def _take_free_gpus(plan: PlacementPlan, node: int, count: int) -> List[int]:
    cluster = plan.cluster
    out: List[int] = []
    for local in range(cluster.gpus_per_node):
        if (plan.slots[node, local] == EMPTY).all():
            out.append(cluster.gpu_id(node, local))
            if len(out) == count:
                return out
    raise RuntimeError(f"node {node} lacks {count} free GPUs")  # pragma: no cover


def apply_packing(
    plan: PlacementPlan,
    matches: Dict[int, int],
    placed_lookup: Dict[int, JobState],
) -> PlacementPlan:
    """Overlay pending jobs onto their matched placed jobs' GPUs."""
    out = plan.copy()
    for pending_id, placed_id in matches.items():
        gpus = out.gpus_of_job(placed_id)
        out.place_job(pending_id, gpus)
    return out
