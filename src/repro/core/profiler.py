"""Throughput profiles and profiling-cost reducers (§4.2 "Profiling", §4.3).

The paper profiles every model / model-pair / parallelism-strategy offline
on real GPUs.  Without hardware we use an **analytic interference model**
grounded in roofline reasoning (DESIGN.md §3):

* every model has a *compute intensity* ``ci`` in (0, 1] — the fraction of
  its step time bound by the compute units rather than memory bandwidth.
  For the 10 assigned repro architectures the value is derived from the
  dry-run roofline terms (compute_term / (compute_term + memory_term));
  for the paper's Table-1 models we use representative constants.
* packing two jobs on one accelerator makes them contend for whichever
  resource both need: the normalised packed throughput of job *a* is
  ``1 / (1 + interference(a, b))`` with
  ``interference = gamma + (1 - gamma) * overlap`` and
  ``overlap = ci_a * ci_b + (1 - ci_a) * (1 - ci_b)``.
  Two compute-bound jobs each drop to ~0.5 (no packing gain); a
  compute-bound + memory-bound pair keeps ~0.85 each (combined ~1.7 —
  exactly the packing wins of Figs. 7/8).
* a pair is infeasible (OOM -> no edge in Algorithm 4) when the summed
  memory footprints exceed the accelerator HBM — this is what makes
  Tesserae adapt to V100s (less HBM => fewer packing opportunities,
  Fig. 12b) *without any retuning*.

Parallelism strategies (§4.2 "Parallelism Strategy"): LLM jobs carry a
candidate strategy set; each strategy has a throughput factor and a memory
factor (pipeline parallelism trades throughput for activation memory —
choosing it can turn an OOM pair feasible, as in Fig. 8's VGG-19 example).

Profiling-cost reducers (§4.3, Fig. 18): the linear scaling model for DP
jobs, Bayesian optimisation over the strategy space for LLM jobs, and the
matrix-completion baseline (Gavel/Quasar style).
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

# --------------------------------------------------------------------------- #
# Catalog
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ModelProfile:
    name: str
    ci: float            # compute intensity in (0, 1]
    mem_gb: float        # per-GPU training footprint at default strategy
    base_tput: float     # iters/sec on one reference (A100) GPU
    is_llm: bool = False


#: Table 1 models.  ci/mem grounded in public A100 measurements; base_tput
#: in iterations/second at the Table-1 batch sizes.
MODEL_CATALOG: Dict[str, ModelProfile] = {
    m.name: m
    for m in [
        ModelProfile("resnet50", ci=0.82, mem_gb=9.0, base_tput=6.0),
        ModelProfile("vgg19", ci=0.68, mem_gb=15.0, base_tput=3.0),
        ModelProfile("dcgan", ci=0.45, mem_gb=6.0, base_tput=14.0),
        ModelProfile("pointnet", ci=0.25, mem_gb=4.0, base_tput=50.0),
        ModelProfile("gpt3-medium", ci=0.72, mem_gb=17.0, base_tput=1.6, is_llm=True),
        ModelProfile("gpt3-xl", ci=0.78, mem_gb=25.0, base_tput=0.7, is_llm=True),
        ModelProfile("gpt3-3b", ci=0.85, mem_gb=33.0, base_tput=0.33, is_llm=True),
    ]
}


def register_model(
    name: str, ci: float, mem_gb: float, base_tput: float, is_llm: bool = False
) -> None:
    """Register extra models (the 10 assigned repro architectures plug in
    here with roofline-derived ci; see benchmarks/roofline_report.py)."""
    MODEL_CATALOG[name] = ModelProfile(name, ci, mem_gb, base_tput, is_llm)


@dataclasses.dataclass(frozen=True)
class GpuType:
    name: str
    mem_gb: float
    speed: float  # relative to A100


GPU_TYPES: Dict[str, GpuType] = {
    "a100": GpuType("a100", 40.0, 1.0),
    "v100": GpuType("v100", 16.0, 0.45),
    "tpu-v5e": GpuType("tpu-v5e", 16.0, 0.63),  # 197/312 bf16 TFLOP/s
}

#: Megatron-style strategy candidates (LLM jobs).  (tput_factor, mem_factor)
#: relative to pure DP.  "pp-default" is Megatron's uniform split; the
#: "pp-bal-*" entries are rebalanced splits like PP=(3,3,3,4,4,5,5,5) in
#: Fig. 8 — slightly better compute balance, much lower activation memory.
STRATEGIES: Dict[str, Tuple[float, float]] = {
    "dp": (1.00, 1.00),
    "tp": (0.92, 0.62),
    "pp-default": (0.84, 0.52),
    "pp-bal-1": (0.90, 0.50),
    "pp-bal-2": (0.94, 0.47),
    "pp-bal-3": (0.88, 0.44),
    "pp-deep": (0.80, 0.38),
    "tp-pp": (0.86, 0.40),
}
DP_ONLY = ("dp",)
LLM_STRATEGIES = tuple(STRATEGIES.keys())


def _pair_hash_unit(a: str, b: str, salt: str = "") -> float:
    """Deterministic pseudo-random unit float for a model pair."""
    key = "|".join(sorted((a, b))) + "#" + salt
    h = hashlib.sha256(key.encode()).digest()
    return int.from_bytes(h[:8], "little") / 2**64


# --------------------------------------------------------------------------- #
# Ground-truth analytic profile
# --------------------------------------------------------------------------- #
class ThroughputProfile:
    """Analytic stand-in for the paper's offline profiling tables."""

    def __init__(
        self,
        gpu_type: str = "a100",
        gamma: float = 0.12,
        jitter: float = 0.05,
        strategy_jitter: float = 0.08,
    ):
        self.gpu = GPU_TYPES[gpu_type]
        self.gamma = gamma
        self.jitter = jitter
        self.strategy_jitter = strategy_jitter
        #: memo for combined_weight: the packing-graph build queries the
        #: same (model_a, model_b) pair thousands of times per round.
        self._weight_cache: Dict = {}

    def for_gpu_type(self, gpu_type: str) -> "ThroughputProfile":
        """Profile variant keyed to another GPU type (heterogeneous
        clusters: a job placed on a V100 node reads V100 speed and HBM).

        Returns ``self`` when the type already matches — the homogeneous
        path never allocates — and a cached plain
        :class:`ThroughputProfile` otherwise.  Wrapper subclasses
        (:class:`NoisyProfile`, :class:`TabulatedProfile`) intentionally
        degrade to the clean analytic profile for foreign types: their
        noise/tables were observed on the base type only.
        """
        if gpu_type == self.gpu.name:
            return self
        cache = self.__dict__.setdefault("_type_variants", {})
        hit = cache.get(gpu_type)
        if hit is None:
            hit = ThroughputProfile(
                gpu_type=gpu_type,
                gamma=self.gamma,
                jitter=self.jitter,
                strategy_jitter=self.strategy_jitter,
            )
            cache[gpu_type] = hit
        return hit

    # -- catalog helpers ------------------------------------------------- #
    def model(self, name: str) -> ModelProfile:
        try:
            return MODEL_CATALOG[name]
        except KeyError:
            raise KeyError(
                f"model {name!r} not in catalog; call profiler.register_model"
            ) from None

    def strategies(self, name: str) -> Tuple[str, ...]:
        return LLM_STRATEGIES if self.model(name).is_llm else DP_ONLY

    def _strategy_factors(self, name: str, strategy: str) -> Tuple[float, float]:
        tput_f, mem_f = STRATEGIES[strategy]
        # deterministic per-(model, strategy) wiggle so the "best" strategy
        # differs across models (the thing BO has to discover).
        u = _pair_hash_unit(name, strategy, "strat")
        tput_f *= 1.0 + self.strategy_jitter * (2 * u - 1)
        return tput_f, mem_f

    # -- isolated throughput --------------------------------------------- #
    def isolated(self, name: str, num_gpus: int = 1, strategy: str = "dp") -> float:
        """iters/sec.  Linear scaling in num_gpus (§4.3 linear model — the
        simulator's ground truth deliberately matches the paper's modelling
        assumption for DP jobs)."""
        m = self.model(name)
        tput_f, _ = self._strategy_factors(name, strategy)
        return m.base_tput * self.gpu.speed * num_gpus * tput_f

    def mem_gb(self, name: str, strategy: str = "dp") -> float:
        _, mem_f = self._strategy_factors(name, strategy)
        return self.model(name).mem_gb * mem_f

    # -- packed throughput ------------------------------------------------ #
    def packable(self, a: str, b: str, strat_a: str = "dp", strat_b: str = "dp") -> bool:
        return self.mem_gb(a, strat_a) + self.mem_gb(b, strat_b) <= self.gpu.mem_gb

    def normalized_packed(
        self, a: str, b: str, strat_a: str = "dp", strat_b: str = "dp"
    ) -> Tuple[float, float]:
        """(norm tput of a, norm tput of b) when packed on one accelerator.

        Normalised = packed tput / isolated tput at the same GPU count
        (§4.2 "Profiling").  Returns (0, 0) if the pair OOMs.
        """
        if not self.packable(a, b, strat_a, strat_b):
            return 0.0, 0.0
        ma, mb = self.model(a), self.model(b)
        overlap = ma.ci * mb.ci + (1 - ma.ci) * (1 - mb.ci)
        interference = self.gamma + (1 - self.gamma) * overlap
        # memory pressure: the fuller the HBM, the harsher the contention
        # (cache thrash / allocator fragmentation).  This is what makes
        # low-activation-memory parallelism strategies (PP/TP) raise PACKED
        # throughput even though they are slower in isolation (Fig. 8).
        mem_util = (
            self.mem_gb(a, strat_a) + self.mem_gb(b, strat_b)
        ) / self.gpu.mem_gb
        interference *= 0.55 + 0.75 * mem_util
        wiggle = 1.0 + self.jitter * (2 * _pair_hash_unit(a, b) - 1)
        na = wiggle / (1.0 + interference)
        nb = wiggle / (1.0 + interference)
        # packing asymmetry: the more memory-bound job suffers slightly more
        skew = 0.06 * (ma.ci - mb.ci)
        return float(np.clip(na * (1 + skew), 0.05, 1.0)), float(
            np.clip(nb * (1 - skew), 0.05, 1.0)
        )

    def combined_weight(
        self,
        a: str,
        b: str,
        optimize_strategy: bool = True,
        strategies_a: Optional[Sequence[str]] = None,
    ) -> Tuple[float, str]:
        """Edge weight for Algorithm 4: summed normalised packed throughput,
        maximised over the parallelism strategy of the *placed* job a
        (§4.2: "modify the edge weight ... when optimizing the parallelism
        strategy of job u")."""
        cands = tuple(
            strategies_a or (self.strategies(a) if optimize_strategy else ("dp",))
        )
        key = (a, b, cands)
        hit = self._weight_cache.get(key)
        if hit is not None:
            return hit
        best_w, best_s = 0.0, "dp"
        dp_tput = self.isolated(a, 1, "dp")
        for s in cands:
            na, nb = self.normalized_packed(a, b, strat_a=s)
            # job a's contribution is normalised to its DP-isolated rate, so
            # a slower-in-isolation strategy only wins when the packing gain
            # outweighs its throughput factor (Fig. 8's trade-off)
            rel = self.isolated(a, 1, s) / dp_tput
            w = rel * na + nb
            if w > best_w:
                best_w, best_s = w, s
        self._weight_cache[key] = (best_w, best_s)
        return best_w, best_s


# --------------------------------------------------------------------------- #
# Noise wrapper (Fig. 16) and estimators (Fig. 18)
# --------------------------------------------------------------------------- #
class RestrictedStrategyProfile(ThroughputProfile):
    """Limits the parallelism-strategy candidate set (Fig. 15 ablations:
    Tesserae-T (DP) / Tesserae-T (Default PP) / full Tesserae-T)."""

    def __init__(self, base: ThroughputProfile, allowed: Tuple[str, ...]):
        self.__dict__.update(base.__dict__)
        self._weight_cache = {}
        self._allowed = tuple(allowed)

    def strategies(self, name: str) -> Tuple[str, ...]:
        base = super().strategies(name)
        if not self.model(name).is_llm:
            return base
        out = tuple(s for s in base if s in self._allowed)
        return out or ("dp",)


class NoisyProfile(ThroughputProfile):
    """Multiplies packed-throughput lookups by U[1-n, 1+n] (§7.2)."""

    def __init__(self, base: ThroughputProfile, noise: float, seed: int = 0):
        self.__dict__.update(base.__dict__)
        self._weight_cache = {}
        self._noise = noise
        self._seed = seed

    def normalized_packed(self, a, b, strat_a="dp", strat_b="dp"):
        na, nb = super().normalized_packed(a, b, strat_a, strat_b)
        if na == 0.0:
            return na, nb
        u = _pair_hash_unit(a + strat_a, b + strat_b, f"noise{self._seed}")
        factor = 1.0 + self._noise * (2 * u - 1)
        return min(na * factor, 1.0), min(nb * factor, 1.0)


class TabulatedProfile(ThroughputProfile):
    """Profile whose packed table is *predicted* by an estimator.

    The scheduler reads this; the simulator advances jobs with the TRUE
    profile — mispredictions show up as bad packing choices (Fig. 18).
    """

    def __init__(self, base: ThroughputProfile, table: Dict[Tuple[str, str, str], Tuple[float, float]]):
        self.__dict__.update(base.__dict__)
        self._weight_cache = {}
        self._table = table
        self._base = base

    def normalized_packed(self, a, b, strat_a="dp", strat_b="dp"):
        key = (a, b, strat_a)
        if key in self._table:
            return self._table[key]
        rkey = (b, a, strat_b)
        if rkey in self._table:
            nb, na = self._table[rkey]
            return na, nb
        return self._base.normalized_packed(a, b, strat_a, strat_b)


def all_pairs(models: Sequence[str]) -> List[Tuple[str, str]]:
    return [(a, b) for i, a in enumerate(models) for b in models[i:]]


def oracle_table(
    profile: ThroughputProfile, models: Sequence[str]
) -> Dict[Tuple[str, str, str], Tuple[float, float]]:
    table = {}
    for a, b in all_pairs(models):
        for s in profile.strategies(a):
            table[(a, b, s)] = profile.normalized_packed(a, b, strat_a=s)
    return table


def linear_bo_estimate(
    profile: ThroughputProfile,
    models: Sequence[str],
    strategy_budget: int = 3,
    seed: int = 0,
) -> TabulatedProfile:
    """§4.3 profiling-cost reduction: profile each pair once at the default
    strategy ("linear model" observation), then spend ``strategy_budget``
    extra probes per LLM pair chosen by a tiny Bayesian-optimisation loop
    (GP with RBF kernel over a 2-feature strategy embedding, expected-
    improvement acquisition)."""
    rng = np.random.default_rng(seed)
    table: Dict[Tuple[str, str, str], Tuple[float, float]] = {}
    feats = {
        s: np.array([STRATEGIES[s][0], STRATEGIES[s][1]]) for s in STRATEGIES
    }
    for a, b in all_pairs(models):
        # one observation at the default strategy (cheap, always done)
        table[(a, b, "dp")] = profile.normalized_packed(a, b, strat_a="dp")
        if not profile.model(a).is_llm:
            continue
        cands = [s for s in profile.strategies(a) if s != "dp"]
        observed: Dict[str, float] = {"dp": sum(table[(a, b, "dp")])}
        for _ in range(strategy_budget):
            s = _bo_pick(observed, cands, feats, rng)
            if s is None:
                break
            na, nb = profile.normalized_packed(a, b, strat_a=s)
            table[(a, b, s)] = (na, nb)
            observed[s] = na + nb
        # predict un-probed strategies with the GP posterior mean
        mu = _gp_posterior_mean(observed, cands, feats)
        for s, m in mu.items():
            if (a, b, s) not in table:
                half = max(m, 0.0) / 2.0
                table[(a, b, s)] = (half, half)
    return TabulatedProfile(profile, table)


def matrix_completion_estimate(
    profile: ThroughputProfile,
    models: Sequence[str],
    observed_fraction: float = 0.4,
    rank: int = 2,
    seed: int = 0,
    iters: int = 200,
) -> TabulatedProfile:
    """Gavel/Quasar-style baseline: observe a random subset of the pairwise
    combined-throughput matrix and complete it with rank-``rank`` soft
    impute (alternating SVD)."""
    rng = np.random.default_rng(seed)
    n = len(models)
    truth = np.zeros((n, n))
    for i, a in enumerate(models):
        for j, b in enumerate(models):
            na, nb = profile.normalized_packed(a, b)
            truth[i, j] = na + nb
    mask = rng.random((n, n)) < observed_fraction
    mask |= mask.T
    np.fill_diagonal(mask, True)
    x = np.where(mask, truth, truth[mask].mean() if mask.any() else 1.0)
    for _ in range(iters):
        u, s, vt = np.linalg.svd(x, full_matrices=False)
        s[rank:] = 0.0
        x_low = (u * s) @ vt
        x = np.where(mask, truth, x_low)
    table: Dict[Tuple[str, str, str], Tuple[float, float]] = {}
    for i, a in enumerate(models):
        for j, b in enumerate(models):
            if j < i:
                continue
            w = float(np.clip(x[i, j], 0.0, 2.0))
            table[(a, b, "dp")] = (w / 2.0, w / 2.0)
    return TabulatedProfile(profile, table)


# -- tiny GP utilities ------------------------------------------------------ #
def _rbf(x1: np.ndarray, x2: np.ndarray, ls: float = 0.35) -> np.ndarray:
    d2 = ((x1[:, None, :] - x2[None, :, :]) ** 2).sum(-1)
    return np.exp(-0.5 * d2 / ls**2)


def _gp_fit(observed: Dict[str, float], feats: Dict[str, np.ndarray]):
    names = list(observed)
    x = np.stack([feats[s] for s in names])
    y = np.array([observed[s] for s in names])
    y_mean = y.mean()
    k = _rbf(x, x) + 1e-6 * np.eye(len(names))
    alpha = np.linalg.solve(k, y - y_mean)
    return x, alpha, y_mean


def _gp_posterior_mean(observed, cands, feats) -> Dict[str, float]:
    if not observed:
        return {s: 1.0 for s in cands}
    x, alpha, y_mean = _gp_fit(observed, feats)
    out = {}
    for s in cands:
        ks = _rbf(feats[s][None, :], x)[0]
        out[s] = float(y_mean + ks @ alpha)
    return out


def _bo_pick(observed, cands, feats, rng) -> Optional[str]:
    remaining = [s for s in cands if s not in observed]
    if not remaining:
        return None
    x, alpha, y_mean = _gp_fit(observed, feats)
    best = max(observed.values())
    scores = {}
    for s in remaining:
        ks = _rbf(feats[s][None, :], x)[0]
        mu = y_mean + float(ks @ alpha)
        var = max(1.0 - float(ks @ np.linalg.solve(_rbf(x, x) + 1e-6 * np.eye(len(x)), ks)), 1e-9)
        sigma = np.sqrt(var)
        z = (mu - best) / sigma
        # expected improvement
        from math import erf, exp, pi, sqrt

        phi = 0.5 * (1 + erf(z / sqrt(2)))
        pdf = exp(-0.5 * z * z) / sqrt(2 * pi)
        scores[s] = (mu - best) * phi + sigma * pdf
    return max(scores, key=scores.get)
