"""Workload trace generators (§6.1 "Traces" and §7.2 "Sensitivity").

Two families, matching the paper's evaluation:

* **Shockwave-like** (default): job *size class* probabilities
  Small/Medium/Large/XL = 0.72 / 0.20 / 0.05 / 0.03 and GPU-count
  probabilities 1/2/4/8 = 0.60 / 0.30 / 0.09 / 0.01; Poisson arrivals at 80
  jobs/hour.  120 jobs for "physical"-scale runs, 900 for simulation.
* **Gavel-like** (Fig. 17): durations 10^U[1.5,3] minutes w.p. 0.8 else
  10^U[3,4] minutes; GPU counts 1/2/4/8 = 0.70 / 0.10 / 0.15 / 0.05.

Models are drawn from the paper's Table 1; ``extra_models`` lets callers mix
in the 10 assigned repro architectures (used by examples/cluster_sim.py) so
Tesserae schedules the same models the JAX substrate trains.
"""

from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.core.jobs import JobSpec
from repro.core.profiler import MODEL_CATALOG, ThroughputProfile

TABLE1_MODELS = [
    "resnet50",
    "vgg19",
    "dcgan",
    "pointnet",
    "gpt3-medium",
    "gpt3-xl",
    "gpt3-3b",
]

#: duration classes (isolated runtime on ONE reference GPU, seconds)
_SHOCKWAVE_CLASSES = {
    "small": (0.72, (600.0, 3600.0)),
    "medium": (0.20, (3600.0, 3 * 3600.0)),
    "large": (0.05, (3 * 3600.0, 8 * 3600.0)),
    "xl": (0.03, (8 * 3600.0, 16 * 3600.0)),
}
_SHOCKWAVE_GPUS = ([1, 2, 4, 8], [0.60, 0.30, 0.09, 0.01])
_GAVEL_GPUS = ([1, 2, 4, 8], [0.70, 0.10, 0.15, 0.05])


def iters_for_duration(
    model: str, num_gpus: int, duration_s: float, profile: ThroughputProfile
) -> float:
    """Iteration count that runs for ``duration_s`` at the job's own GPU
    count (linear scaling) — the one conversion rule shared by these
    fixture generators and the :mod:`repro.workloads` trace schema, so a
    duration-profiled trace row materialises identically everywhere."""
    return duration_s * profile.isolated(model, num_gpus)


def _mk_job(
    rng: np.random.Generator,
    job_id: int,
    arrival: float,
    duration_s: float,
    num_gpus: int,
    models: Sequence[str],
    profile: ThroughputProfile,
) -> JobSpec:
    model = models[int(rng.integers(len(models)))]
    is_llm = MODEL_CATALOG[model].is_llm
    total_iters = iters_for_duration(model, num_gpus, duration_s, profile)
    batch_pow = int(rng.integers(0, 4))
    return JobSpec(
        job_id=job_id,
        model=model,
        num_gpus=num_gpus,
        total_iters=total_iters,
        arrival_time=arrival,
        batch_size=16 * (2**batch_pow),
        packable=True,
        is_llm=is_llm,
    )


def shockwave_trace(
    num_jobs: int = 900,
    arrival_rate_per_hour: float = 80.0,
    seed: int = 0,
    models: Optional[Sequence[str]] = None,
    extra_models: Sequence[str] = (),
    profile: Optional[ThroughputProfile] = None,
) -> List[JobSpec]:
    rng = np.random.default_rng(seed)
    profile = profile or ThroughputProfile()
    models = list(models or TABLE1_MODELS) + list(extra_models)
    class_names = list(_SHOCKWAVE_CLASSES)
    class_p = np.array([_SHOCKWAVE_CLASSES[c][0] for c in class_names])
    class_p = class_p / class_p.sum()
    gpu_choices, gpu_p = _SHOCKWAVE_GPUS

    jobs: List[JobSpec] = []
    t = 0.0
    for jid in range(num_jobs):
        t += rng.exponential(3600.0 / arrival_rate_per_hour)
        cname = class_names[int(rng.choice(len(class_names), p=class_p))]
        lo, hi = _SHOCKWAVE_CLASSES[cname][1]
        duration = float(rng.uniform(lo, hi))
        g = int(rng.choice(gpu_choices, p=gpu_p))
        jobs.append(_mk_job(rng, jid, t, duration, g, models, profile))
    return jobs


def gavel_trace(
    num_jobs: int = 900,
    arrival_rate_per_hour: float = 80.0,
    seed: int = 0,
    models: Optional[Sequence[str]] = None,
    extra_models: Sequence[str] = (),
    profile: Optional[ThroughputProfile] = None,
) -> List[JobSpec]:
    rng = np.random.default_rng(seed)
    profile = profile or ThroughputProfile()
    models = list(models or TABLE1_MODELS) + list(extra_models)
    gpu_choices, gpu_p = _GAVEL_GPUS

    jobs: List[JobSpec] = []
    t = 0.0
    for jid in range(num_jobs):
        t += rng.exponential(3600.0 / arrival_rate_per_hour)
        if rng.random() < 0.8:
            duration = 60.0 * 10 ** rng.uniform(1.5, 3.0)
        else:
            duration = 60.0 * 10 ** rng.uniform(3.0, 4.0)
        g = int(rng.choice(gpu_choices, p=gpu_p))
        jobs.append(_mk_job(rng, jid, t, float(duration), g, models, profile))
    return jobs


def synthetic_active_jobs(
    num_jobs: int,
    seed: int = 0,
    models: Optional[Sequence[str]] = None,
    gpu_dist=_SHOCKWAVE_GPUS,
    profile: Optional[ThroughputProfile] = None,
):
    """Instant snapshot of `num_jobs` active jobs (for the Fig. 2 / Fig. 14
    decision-time scalability benchmark, which measures one round)."""
    from repro.core.jobs import JobState

    rng = np.random.default_rng(seed)
    profile = profile or ThroughputProfile()
    models = list(models or TABLE1_MODELS)
    gpu_choices, gpu_p = gpu_dist
    out = []
    for jid in range(num_jobs):
        g = int(rng.choice(gpu_choices, p=gpu_p))
        spec = _mk_job(rng, jid, 0.0, float(rng.uniform(600, 3600 * 8)), g, models, profile)
        st = JobState(spec=spec)
        st.attained_service = float(rng.uniform(0, 3600 * 8)) * g
        out.append(st)
    return out
