"""Unified batched matching engine — one entry point for every LAP in Tesserae.

Algorithm 2 solves k_c^2 independent node-pair LAPs per scheduling round,
packing (Algorithm 4) solves one rectangular max-weight matching, and the
final node-level match is one more square LAP.  Before this module each
call site picked its own solver (sequential scipy loops in
``migration.py``, ``hungarian.solve_lap`` in ``packing.py``, a bespoke
auction path in ``plan_migration_batched_auction``).  The engine unifies
them behind a *backend registry*:

==================  =========================================================
``scipy``           per-instance ``scipy.optimize.linear_sum_assignment``
                    (the paper-faithful reference; exact).  Rectangular
                    instances solve natively (no square embedding).
``numpy``           per-instance :mod:`repro.core.matching.hungarian` (exact,
                    no scipy dependency).  Rectangular instances solve
                    natively.
``smallperm``       vectorised brute force over all k! permutations — exact
                    and ~100x faster than looped Hungarian for the k <= 6
                    node-pair instances of Algorithm 2 (k_l is 4-8 on every
                    evaluated cluster).  Square-embedded.
``auction``         batched JAX auction (`auction_lap_batched`): one XLA
                    program for the whole fan-out; totals within the
                    documented ``n * eps`` bound of optimal (exact for
                    integer-valued costs).  Warm-startable (below); n != m
                    instances route to the native rectangular forward
                    auction — bids range only over real columns and no
                    ``max(n, m)^2`` square embedding is allocated.
``auction_kernel``  auction with the bid step lowered to the Pallas
                    ``lap_bid`` kernel (natively batched grid on TPU,
                    interpret mode on CPU).  Same warm-start / rectangular
                    semantics as ``auction``.
``auto``            ``smallperm`` when every instance is k <= 6, else
                    ``scipy`` when available, else ``numpy``
==================  =========================================================

All backends accept **rectangular** instances, **row/col masks** (padding —
so ragged batches solve in one call) and **forbidden edges** (non-finite
cost entries).  Square and ``smallperm`` instances normalise through the
square *benefit* embedding (:func:`~repro.core.matching.auction.
masked_square_benefit`); rectangular instances keep their (n, m) shape
(:func:`~repro.core.matching.auction.masked_rect_benefit`), oriented so
bidders are the short side.  Padded and forbidden cells get a constant
benefit strictly below every real benefit, which guarantees padding never
displaces a real pair in an optimal (or ``n*eps``-optimal) assignment.
Results are post-processed uniformly: pairs landing on padded/forbidden
cells are dropped, and — for the auction backends — instances whose
auction did not converge within the iteration budget (or, on the
rectangular path, whose warm-start price certificate fails, see below) are
transparently re-solved with an exact backend.

**Warm starts** (:class:`MatchContext`): placements change little
round-to-round (the temporal locality Tesserae's migration matching
exploits, Fig. 2/14b), so the scheduler threads an opaque ``MatchContext``
across rounds.  The engine keys cached state by ``(context_key, backend,
orientation, batch/shape)`` and fingerprints every benefit row; on the
next call

* instances whose rows all match resume from last round's **prices** and
  skip the epsilon-scaling schedule (one phase at ``eps_min``); if *every*
  instance matches and a final assignment is cached, the solve is skipped
  outright (a *memo hit* — zero bid iterations);
* **changed rows reset their prices**: a mutated row invalidates the price
  of the column it held last round, and that instance restarts the full
  epsilon schedule (its other columns keep their prices as a head start).

Optimality under warm starts: for square instances the ``S * eps_min``
bound holds for ANY initial prices (both sides of the comparison telescope
over the same full column set).  For rectangular instances it additionally
requires that no unassigned column's final price exceeds an assigned
column's — the engine checks exactly that a posteriori
(:func:`_rect_bound_violation`) and re-solves the rare instance whose
certificate fails, so every returned total carries the documented bound.

Accuracy contract: with ``backend="auction"`` the returned per-instance
total cost is within ``S * eps_min`` of the scipy optimum, where ``S`` is
the solve size (the embedded square for n == m, the short side for
rectangular instances) and ``eps_min`` defaults to ``1 / (S + 1)`` — i.e.
*exact* whenever costs are integers (quantise first when exactness
matters; migration costs are multiples of ``1/(2*num_gpus)`` and are
scaled to integers by the caller).  The exact backends match scipy
identically.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.matching import hungarian
from repro.core.matching.auction import masked_rect_benefit, masked_square_benefit

#: Largest instance size solved by brute-force permutation search (k! <= 720).
SMALLPERM_MAX_K = 6

#: Backends whose totals carry the n*eps approximation bound (float costs).
APPROX_BACKENDS = ("auction", "auction_kernel")

#: Backends that solve rectangular (n != m) instances natively, without the
#: max(n, m)^2 square embedding.
RECT_BACKENDS = ("scipy", "numpy", "auction", "auction_kernel")


# --------------------------------------------------------------------------- #
# Result type
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class BatchedMatchResult:
    """Assignments for a batch of LAP instances.

    ``col_of[b, i]`` is the column assigned to row ``i`` of instance ``b``
    (-1 for unassigned / masked / padded rows).  ``total_cost[b]`` sums the
    ORIGINAL cost entries over assigned pairs.  ``converged[b]`` reports
    whether the primary backend solved the instance itself;
    ``used_fallback[b]`` marks instances re-solved by the exact fallback.
    ``bid_iters[b]`` counts auction bid rounds (0 for exact backends and
    memo hits); ``warm[b]`` marks instances warm-started from a
    :class:`MatchContext`; ``embedding`` records the solve geometry
    (``"square"`` / ``"rect"`` / ``"none"`` for empty batches).
    """

    col_of: np.ndarray      # (B, N) int64
    total_cost: np.ndarray  # (B,) float64
    converged: np.ndarray   # (B,) bool
    used_fallback: np.ndarray  # (B,) bool
    backend: str
    wall_time_s: float = 0.0
    bid_iters: Optional[np.ndarray] = None  # (B,) int64
    warm: Optional[np.ndarray] = None       # (B,) bool
    embedding: str = "square"

    def pairs(self, b: int) -> Tuple[np.ndarray, np.ndarray]:
        """(row_ind, col_ind) of instance ``b`` — scipy-style contract."""
        rows = np.nonzero(self.col_of[b] >= 0)[0]
        return rows, self.col_of[b, rows]


# --------------------------------------------------------------------------- #
# Persistent warm-start state
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class _CtxEntry:
    """Per-(key, shape) cached state from the previous solve."""

    row_fp: np.ndarray          # (B, R) uint64 benefit-row fingerprints
    prices: Optional[np.ndarray]  # (B, C) float32 final auction prices
    col_solve: np.ndarray       # (B, R) int64 solve-space assignment
    final_col_of: np.ndarray    # (B, N) int64 original-space assignment
    converged: np.ndarray       # (B,) bool
    used_fallback: np.ndarray   # (B,) bool


class MatchContext:
    """Opaque warm-start state for :func:`solve_lap_batched`.

    The scheduler creates one and threads it across rounds; each engine
    call site picks a ``context_key`` (e.g. ``"migration_pairs"``,
    ``"packing"``) so different LAP families never collide.  The context
    stores, per (key, backend, shape): benefit-row fingerprints, the final
    auction **prices**, and the final assignment.  See the module
    docstring for the warm-start / invalidation / memoisation semantics.

    Thread-safety: none — one context per scheduler instance.
    """

    def __init__(self):
        self._entries: Dict[tuple, _CtxEntry] = {}
        self.stats: Dict[str, int] = {
            "solves": 0,        # engine calls that consulted this context
            "memo_hits": 0,     # calls skipped entirely (all rows matched)
            "warm_instances": 0,
            "cold_instances": 0,
            "rows_invalidated": 0,
            "cert_violations": 0,  # rect bound certificate failures
        }

    def get(self, key: tuple) -> Optional[_CtxEntry]:
        return self._entries.get(key)

    def store(self, key: tuple, entry: _CtxEntry) -> None:
        """Keep ONE entry per (context_key, backend) family: warm starts
        require an exact shape match anyway, so an older shape's state is
        dead weight — and e.g. the packing family's (|placed|, |pending|)
        shape changes with churn, which would otherwise grow the cache by
        one entry per shape ever seen over a long-running scheduler."""
        family = key[:2]
        for k in [k for k in self._entries if k[:2] == family and k != key]:
            del self._entries[k]
        self._entries[key] = entry

    def reset(self) -> None:
        """Drop all cached state (prices, fingerprints, memoised results)."""
        self._entries.clear()

    def __len__(self) -> int:
        return len(self._entries)


#: fixed odd multipliers for the row fingerprint (stable across processes).
_FP_SEED = 0x5DEECE66D
_FP_WEIGHTS: Dict[int, np.ndarray] = {}


def _fp_weights(c: int) -> np.ndarray:
    """Deterministic per-column multipliers, cached per column count (the
    fingerprint runs on every context-ful engine call — the hot path)."""
    w = _FP_WEIGHTS.get(c)
    if w is None:
        w = (
            np.random.default_rng(_FP_SEED)
            .integers(1, 2**63 - 1, size=c, dtype=np.uint64)
            | np.uint64(1)
        )
        _FP_WEIGHTS[c] = w
    return w


def _row_fingerprints(benefit: np.ndarray) -> np.ndarray:
    """Vectorised 64-bit fingerprint of every benefit row: (B, R, C) ->
    (B, R) uint64.  A changed entry changes its row's fingerprint with
    overwhelming probability; collisions only cost a stale warm start
    (never a wrong answer for exact backends — memoised results are reused
    only when ALL rows match, and the auction path re-verifies through its
    convergence/cardinality/certificate checks)."""
    bits = np.ascontiguousarray(benefit, dtype=np.float64).view(np.uint64)
    c = bits.shape[-1]
    fp = (bits * _fp_weights(c)).sum(axis=-1, dtype=np.uint64)  # wraps mod 2^64
    return fp * np.uint64(0x9E3779B97F4A7C15) + np.uint64(c)


def _assigned_cols(col_solve: np.ndarray, c: int) -> np.ndarray:
    """(B, C) bool mask of columns holding an assignment.  Scatters only
    the real (>= 0) entries — clipping -1 sentinels into index 0 would let
    an unassigned row clobber column 0's flag."""
    b = col_solve.shape[0]
    assigned = np.zeros((b, c), bool)
    bb, rr = np.nonzero(col_solve >= 0)
    assigned[bb, col_solve[bb, rr]] = True
    return assigned


def _rect_bound_violation(prices: np.ndarray, col_solve: np.ndarray) -> np.ndarray:
    """A-posteriori certificate for the rectangular ``n*eps`` bound.

    At termination the auction satisfies eps-complementary slackness wrt
    its FINAL prices, which yields (for any competing assignment S'):

        total(sigma) >= total(S') - R*eps - [sum_{S'\\sigma} p - sum_{sigma\\S'} p]

    The bracket is <= 0 for every S' iff no k largest unassigned-column
    prices sum above the k smallest assigned-column prices (pairwise), so

        D = max_k  sum_{i<k} (U_desc[i] - A_asc[i])  >  0

    is the exact condition under which warm-start prices could have broken
    the bound.  Cold rectangular solves start from all-equal prices, where
    unassigned columns keep the (minimal) initial price and D <= 0 by
    construction; warm starts can leave stale high prices on abandoned
    columns, and those instances are flagged for an exact re-solve.
    Instances with unassigned rows return False — the convergence /
    cardinality checks already flag them.
    """
    b, c = prices.shape
    r = col_solve.shape[1]
    if r >= c or b == 0:
        return np.zeros(b, bool)  # square: bound holds for any prices
    prices = prices.astype(np.float64)
    ok = col_solve >= 0
    assigned = _assigned_cols(col_solve, c)
    complete = ok.all(axis=1)
    a_sorted = np.sort(np.where(assigned, prices, np.inf), axis=1)[:, :r]
    u_sorted = -np.sort(np.where(assigned, np.inf, -prices), axis=1)[:, : c - r]
    k = min(r, c - r)
    diff = u_sorted[:, :k] - a_sorted[:, :k]
    d_worst = np.cumsum(np.where(np.isfinite(diff), diff, 0.0), axis=1).max(axis=1)
    # Tolerance matches the slack the parity gates grant on top of the
    # documented S*eps_min bound (engine docstring / CI perf-smoke gate):
    # a deficit the certificate waves through must be invisible to them.
    # Erring tight is safe — a false positive only costs an exact
    # re-solve; a false negative is a bound violation.  Cold solves have
    # d_worst <= 0 exactly (unassigned columns keep the all-equal initial
    # price), so the tight tolerance never penalises them.
    return complete & (d_worst > 1e-6)


# --------------------------------------------------------------------------- #
# Backend registry
# --------------------------------------------------------------------------- #
#: name -> fn(benefit (B,R,C), eps_min, max_iters) -> (col_of (B,R), converged (B,))
_BACKENDS: Dict[str, Callable] = {}


def register_backend(name: str) -> Callable:
    """Register a batched benefit solver under ``name``.

    The callable receives the benefit batch (maximise convention, padding
    already applied; square-embedded unless the backend is listed in
    ``RECT_BACKENDS``) and returns per-row column assignments plus a
    per-instance convergence flag.  Third-party schedulers can plug in
    e.g. a Sinkhorn or GPU-resident solver without touching any call site
    — backend choice stays one config knob.
    """

    def deco(fn: Callable) -> Callable:
        _BACKENDS[name] = fn
        return fn

    return deco


def available_backends() -> List[str]:
    return sorted(_BACKENDS) + ["auto"]


@register_backend("scipy")
def _solve_scipy(benefit: np.ndarray, eps_min=None, max_iters=None):
    from scipy.optimize import linear_sum_assignment as scipy_lsa

    b, r, _ = benefit.shape
    col_of = np.full((b, r), -1, dtype=np.int64)
    for i in range(b):
        rows, cols = scipy_lsa(benefit[i], maximize=True)
        col_of[i, rows] = cols
    return col_of, np.ones(b, dtype=bool)


@register_backend("numpy")
def _solve_numpy(benefit: np.ndarray, eps_min=None, max_iters=None):
    b, r, _ = benefit.shape
    col_of = np.full((b, r), -1, dtype=np.int64)
    for i in range(b):
        rows, cols = hungarian.linear_sum_assignment(benefit[i], maximize=True)
        col_of[i, rows] = cols
    return col_of, np.ones(b, dtype=bool)


@register_backend("smallperm")
def _solve_smallperm(benefit: np.ndarray, eps_min=None, max_iters=None):
    """Exact batched LAP for k <= 6 by vectorised permutation search.

    Replaces the k_c^2 sequential Hungarian calls in Algorithm 2's
    node-pair fan-out with one numpy pass — the node size k_l is 4-8 in
    every evaluated cluster, where k! brute force beats O(k^3) with Python
    overhead by ~100x (EXPERIMENTS.md §Perf, scheduler iteration 2).
    """
    b, k, _ = benefit.shape
    if k > SMALLPERM_MAX_K:
        raise ValueError(f"smallperm requires k <= {SMALLPERM_MAX_K}, got {k}")
    perms = np.array(list(itertools.permutations(range(k))), dtype=np.int64)
    picked = benefit[:, np.arange(k)[None, :], perms]  # (B, P, k)
    best = np.argmax(picked.sum(axis=-1), axis=-1)  # maximise benefit
    return perms[best], np.ones(b, dtype=bool)


def _solve_auction(benefit: np.ndarray, eps_min, max_iters, use_kernel: bool):
    import jax.numpy as jnp

    from repro.core.matching.auction import auction_lap_batched

    res = auction_lap_batched(
        jnp.asarray(benefit, jnp.float32),
        max_iters=max_iters,
        eps_min=eps_min,
        use_kernel=use_kernel,
    )
    return np.asarray(res.col_of, np.int64), np.asarray(res.converged, bool)


@register_backend("auction")
def _solve_auction_plain(benefit: np.ndarray, eps_min=None, max_iters=20_000):
    return _solve_auction(benefit, eps_min, max_iters, use_kernel=False)


@register_backend("auction_kernel")
def _solve_auction_kernel(benefit: np.ndarray, eps_min=None, max_iters=20_000):
    return _solve_auction(benefit, eps_min, max_iters, use_kernel=True)


def _pick_auto(size: int) -> str:
    if size <= SMALLPERM_MAX_K:
        return "smallperm"
    return _pick_exact()


def _pick_exact() -> str:
    try:
        import scipy.optimize  # noqa: F401

        return "scipy"
    except ImportError:  # pragma: no cover - scipy is installed here
        return "numpy"


def _run_auction(
    benefit: np.ndarray,
    rect: bool,
    eps_min,
    max_iters: int,
    use_kernel: bool,
    init_prices: Optional[np.ndarray],
    warm: Optional[np.ndarray],
):
    """Dispatch a (possibly warm-started) auction solve; returns
    (col_of (B, R), converged (B,), prices (B, C), iters (B,))."""
    import jax.numpy as jnp

    from repro.core.matching.auction import (
        auction_lap_batched,
        auction_lap_rect_batched,
    )

    solver = auction_lap_rect_batched if rect else auction_lap_batched
    res = solver(
        jnp.asarray(benefit, jnp.float32),
        max_iters=max_iters,
        eps_min=eps_min,
        use_kernel=use_kernel,
        init_prices=None if init_prices is None else jnp.asarray(init_prices),
        warm=None if warm is None else jnp.asarray(warm),
    )
    return (
        np.asarray(res.col_of, np.int64),
        np.asarray(res.converged, bool),
        np.asarray(res.prices, np.float32),
        np.asarray(res.iters, np.int64),
    )


# --------------------------------------------------------------------------- #
# Entry points
# --------------------------------------------------------------------------- #
def solve_lap_batched(
    costs: np.ndarray,
    *,
    maximize: bool = False,
    row_mask: Optional[np.ndarray] = None,
    col_mask: Optional[np.ndarray] = None,
    backend: str = "auto",
    eps_min: Optional[float] = None,
    max_iters: int = 20_000,
    context: Optional[MatchContext] = None,
    context_key: str = "default",
) -> BatchedMatchResult:
    """Solve a batch of (rectangular, masked) LAPs with one backend call.

    Args:
      costs: (B, N, M) cost batch (numpy or jax array).  Non-finite entries
        are forbidden edges.  Pass a single (N, M) instance to get B=1.
      maximize: maximise total cost instead of minimising.
      row_mask / col_mask: (B, N) / (B, M) bool, True = real.  Padded rows
        and columns never receive an assignment.
      backend: a registered backend name or ``"auto"``.
      eps_min: auction final epsilon (default ``1/(S+1)``; the auction
        total is within ``S*eps_min`` of optimal — exact for integer costs).
      max_iters: auction bid-round budget; instances that exhaust it fall
        back to an exact solver (tracked per instance via ``used_fallback``).
      context: optional :class:`MatchContext` carrying last round's prices,
        fingerprints and assignments — warm-starts the auction backends and
        memoises identical re-solves for every backend.
      context_key: namespace inside ``context`` (one per LAP family, e.g.
        ``"migration_pairs"`` vs ``"packing"``), so unrelated call sites
        never share price state.
    """
    t0 = time.perf_counter()
    costs = np.asarray(costs, dtype=np.float64)
    if costs.ndim == 2:
        costs = costs[None]
        if row_mask is not None:
            row_mask = np.asarray(row_mask, bool)[None]
        if col_mask is not None:
            col_mask = np.asarray(col_mask, bool)[None]
    if costs.ndim != 3:
        raise ValueError(f"costs must be (B, N, M), got shape {costs.shape}")
    b, n, m = costs.shape
    size = max(n, m)
    if backend == "auto":
        backend = _pick_auto(size)
    if backend not in _BACKENDS:
        raise ValueError(
            f"unknown LAP backend {backend!r}; registered: {available_backends()}"
        )
    if b == 0 or n == 0 or m == 0:
        return BatchedMatchResult(
            np.full((b, n), -1, np.int64),
            np.zeros(b),
            np.ones(b, bool),
            np.zeros(b, bool),
            backend,
            time.perf_counter() - t0,
            np.zeros(b, np.int64),
            np.zeros(b, bool),
            "none",
        )

    approx = backend in APPROX_BACKENDS
    rect = n != m and backend in RECT_BACKENDS
    transposed = rect and n > m
    if rect:
        benefit_nm = masked_rect_benefit(costs, maximize, row_mask, col_mask)
        oriented = (
            np.ascontiguousarray(np.swapaxes(benefit_nm, 1, 2))
            if transposed
            else benefit_nm
        )
    else:
        benefit_nm = oriented = masked_square_benefit(costs, maximize, row_mask, col_mask)
    r, c = oriented.shape[1:]

    # ---- context lookup: memoisation + warm-start prices ---------------- #
    fp = warm = init_prices = None
    entry = None
    key = (context_key, backend, maximize, b, r, c, transposed, eps_min)
    if context is not None:
        context.stats["solves"] += 1
        # Fingerprints follow the CALLER's mutation granularity: original
        # rows.  For transposed rectangular instances an original row is
        # one oriented COLUMN, so a changed row later invalidates exactly
        # that column's price instead of every bidder fingerprint.
        fp = _row_fingerprints(benefit_nm)
        entry = context.get(key)
    if entry is not None:
        unchanged = fp == entry.row_fp  # (B, N) original rows
        warm = unchanged.all(axis=1)
        if warm.all():
            # Every benefit row matches the cached solve: reuse the stored
            # assignment outright.  Totals are recomputed from the (equal,
            # modulo a 2^-64 fingerprint collision) costs for uniformity.
            context.stats["memo_hits"] += 1
            context.stats["warm_instances"] += b
            col_of, total, _ = _extract(costs, entry.final_col_of, row_mask, col_mask)
            return BatchedMatchResult(
                col_of,
                total,
                entry.converged.copy(),
                entry.used_fallback.copy(),
                backend,
                time.perf_counter() - t0,
                np.zeros(b, np.int64),
                warm,
                "rect" if rect else "square",
            )
        if approx and entry.prices is not None:
            # Changed rows reset their prices; everything else carries
            # over as a head start.
            init_prices = entry.prices.copy()
            if transposed:
                # original row i IS oriented column i: reset it directly
                stale = ~unchanged  # (B, C)
                init_prices[stale] = 0.0
            else:
                # a changed row taints the column it held last round
                stale = (~unchanged) & (entry.col_solve >= 0)
                bb, rr = np.nonzero(stale)
                init_prices[bb, entry.col_solve[bb, rr]] = 0.0
            context.stats["rows_invalidated"] += int(stale.sum())
        else:
            # exact backends carry no prices: short of a full memo hit
            # they re-solve from scratch, so nothing is warm-STARTED
            warm = None
        if warm is not None:
            context.stats["warm_instances"] += int(warm.sum())
            context.stats["cold_instances"] += int(b - warm.sum())
        else:
            context.stats["cold_instances"] += b
    elif context is not None:
        context.stats["cold_instances"] += b

    # ---- primary solve -------------------------------------------------- #
    bid_iters = np.zeros(b, np.int64)
    prices = None
    if approx:
        col_solve, converged, prices, bid_iters = _run_auction(
            oriented,
            rect,
            eps_min,
            max_iters,
            use_kernel=(backend == "auction_kernel"),
            init_prices=init_prices,
            warm=warm,
        )
    else:
        col_solve, converged = _BACKENDS[backend](oriented, eps_min, max_iters)

    col_full = _to_orig_cols(col_solve, transposed, n, m)
    col_of, total, complete = _extract(costs, col_full, row_mask, col_mask)
    expect = _expected_cardinality(costs, row_mask, col_mask)
    needs_fallback = (~converged) | (complete < expect)
    if approx and rect:
        viol = _rect_bound_violation(prices, col_solve)
        needs_fallback |= viol
        if context is not None:
            context.stats["cert_violations"] += int(viol.sum())
    used_fallback = np.zeros(b, bool)
    if needs_fallback.any() and approx:
        fb = _pick_exact() if rect else _pick_auto(size)
        idx = np.nonzero(needs_fallback)[0]
        fb_solve, _ = _BACKENDS[fb](oriented[idx], None, None)
        fb_res, fb_total, fb_complete = _extract(
            costs[idx],
            _to_orig_cols(fb_solve, transposed, n, m),
            None if row_mask is None else row_mask[idx],
            None if col_mask is None else col_mask[idx],
        )
        # Adopt the exact re-solve only where it actually improves the
        # result: a structurally infeasible instance (forbidden edges make
        # a complete matching impossible) trips the cardinality check on
        # every call, but if the auction already found an equally large,
        # equally good matching there is nothing to fix — and counting it
        # as a fallback would poison the auction-quality metric the
        # microbench records.
        if maximize:
            improves = fb_total > total[idx]
        else:
            improves = fb_total < total[idx]
        adopt = (fb_complete > complete[idx]) | (
            (fb_complete == complete[idx]) & improves
        )
        sel = idx[adopt]
        col_of[sel] = fb_res[adopt]
        total[sel] = fb_total[adopt]
        used_fallback[sel] = True

    if context is not None:
        if rect and prices is not None:
            # Price repair before caching: a column with no owner is
            # available again next round, so its stale price is reset to
            # the cold-start level.  This keeps the stored prices close to
            # the all-equal-unassigned condition the rectangular bound
            # wants, so the next warm solve rarely trips the certificate
            # (which always runs on the *actual* final prices, above).
            prices = np.where(
                _assigned_cols(col_solve, c), prices, 0.0
            ).astype(np.float32)
        context.store(
            key,
            _CtxEntry(
                row_fp=fp,
                prices=prices,
                col_solve=col_solve,
                final_col_of=col_of.copy(),
                converged=converged.copy(),
                used_fallback=used_fallback.copy(),
            ),
        )

    return BatchedMatchResult(
        col_of,
        total,
        converged,
        used_fallback,
        backend,
        time.perf_counter() - t0,
        bid_iters,
        np.zeros(b, bool) if warm is None else warm,
        "rect" if rect else "square",
    )


def _to_orig_cols(
    col_solve: np.ndarray, transposed: bool, n: int, m: int
) -> np.ndarray:
    """Map solve-space assignments back to original row space.

    ``col_solve`` is (B, R) over the oriented instance.  Untransposed
    solves already index original columns; transposed (n > m rectangular)
    solves assign original *rows* to the m bidding columns and must be
    inverted (vectorised scatter)."""
    if not transposed:
        return col_solve
    b = col_solve.shape[0]
    col_of = np.full((b, n), -1, np.int64)
    bb, jj = np.nonzero((col_solve >= 0) & (col_solve < n))
    col_of[bb, col_solve[bb, jj]] = jj
    return col_of


def _extract(costs, col_of_sq, row_mask, col_mask):
    """Map solver assignments back to the original instances."""
    b, n, m = costs.shape
    cols = col_of_sq[:, :n].astype(np.int64)  # ignore padded rows
    valid = (cols >= 0) & (cols < m)
    safe = np.where(valid, cols, 0)
    picked = np.take_along_axis(costs, safe[:, :, None], axis=2)[:, :, 0]
    valid &= np.isfinite(picked)
    if row_mask is not None:
        valid &= np.asarray(row_mask, bool)
    if col_mask is not None:
        valid &= np.take_along_axis(np.asarray(col_mask, bool), safe, axis=1)
    col_of = np.where(valid, cols, -1)
    total = np.where(valid, picked, 0.0).sum(axis=1)
    return col_of, total, valid.sum(axis=1)


def _expected_cardinality(costs, row_mask, col_mask):
    b, n, m = costs.shape
    nr = np.full(b, n) if row_mask is None else np.asarray(row_mask, bool).sum(1)
    nc = np.full(b, m) if col_mask is None else np.asarray(col_mask, bool).sum(1)
    return np.minimum(nr, nc)


def solve_lap(
    cost: np.ndarray,
    maximize: bool = False,
    backend: str = "auto",
    context: Optional[MatchContext] = None,
    context_key: str = "default",
) -> Tuple[np.ndarray, np.ndarray]:
    """Single-instance LAP with the same backend knob as the batched engine.

    Drop-in superset of ``hungarian.solve_lap``: without a ``context``,
    ``auto``/``numpy``/``scipy`` keep the original exact dispatch (no
    embedding overhead) and the auction backends route through the batched
    engine.  With a ``context``, EVERY backend routes through the engine so
    identical consecutive solves memo-hit and the auction carries prices.
    Returns scipy-style ``(row_ind, col_ind)``.
    """
    if context is None and backend in ("auto", "numpy", "scipy"):
        return hungarian.solve_lap(cost, maximize=maximize, backend=backend)
    res = solve_lap_batched(
        np.asarray(cost)[None],
        maximize=maximize,
        backend=backend,
        context=context,
        context_key=context_key,
    )
    return res.pairs(0)
