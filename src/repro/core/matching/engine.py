"""Unified batched matching engine — one entry point for every LAP in Tesserae.

Algorithm 2 solves k_c^2 independent node-pair LAPs per scheduling round,
packing (Algorithm 4) solves one rectangular max-weight matching, and the
final node-level match is one more square LAP.  Before this module each
call site picked its own solver (sequential scipy loops in
``migration.py``, ``hungarian.solve_lap`` in ``packing.py``, a bespoke
auction path in ``plan_migration_batched_auction``).  The engine unifies
them behind a *backend registry*:

==================  =========================================================
``scipy``           per-instance ``scipy.optimize.linear_sum_assignment``
                    (the paper-faithful reference; exact)
``numpy``           per-instance :mod:`repro.core.matching.hungarian` (exact,
                    no scipy dependency)
``smallperm``       vectorised brute force over all k! permutations — exact
                    and ~100x faster than looped Hungarian for the k <= 6
                    node-pair instances of Algorithm 2 (k_l is 4-8 on every
                    evaluated cluster)
``auction``         batched JAX auction (`auction_lap_batched`): one XLA
                    program for the whole fan-out; totals within the
                    documented ``n * eps`` bound of optimal (exact for
                    integer-valued costs)
``auction_kernel``  auction with the bid step lowered to the Pallas
                    ``lap_bid`` kernel (natively batched grid on TPU,
                    interpret mode on CPU)
``auto``            ``smallperm`` when every instance is k <= 6, else
                    ``scipy`` when available, else ``numpy``
==================  =========================================================

All backends accept **rectangular** instances, **row/col masks** (padding —
so ragged batches solve in one call) and **forbidden edges** (non-finite
cost entries).  Everything is normalised through one square *benefit*
embedding (:func:`repro.core.matching.auction.masked_square_benefit`):
padded and forbidden cells get a constant benefit strictly below every
real benefit, which guarantees padding never displaces a real pair in an
optimal (or ``n*eps``-optimal) assignment.  Results are post-processed
uniformly: pairs landing on padded/forbidden cells are dropped, and —
for the auction backends — instances whose auction did not converge
within the iteration budget are transparently re-solved with scipy
(per-instance convergence comes from the vmapped ``converged`` flag).

Accuracy contract: with ``backend="auction"`` the returned per-instance
total cost is within ``S * eps_min`` of the scipy optimum, where ``S`` is
the embedded square size and ``eps_min`` defaults to ``1 / (S + 1)`` —
i.e. *exact* whenever costs are integers (quantise first when exactness
matters; migration costs are multiples of ``1/(2*num_gpus)`` and are
scaled to integers by the caller).  The exact backends match scipy
identically.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.matching import hungarian
from repro.core.matching.auction import masked_square_benefit

#: Largest instance size solved by brute-force permutation search (k! <= 720).
SMALLPERM_MAX_K = 6

#: Backends whose totals carry the n*eps approximation bound (float costs).
APPROX_BACKENDS = ("auction", "auction_kernel")


# --------------------------------------------------------------------------- #
# Result type
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class BatchedMatchResult:
    """Assignments for a batch of LAP instances.

    ``col_of[b, i]`` is the column assigned to row ``i`` of instance ``b``
    (-1 for unassigned / masked / padded rows).  ``total_cost[b]`` sums the
    ORIGINAL cost entries over assigned pairs.  ``converged[b]`` reports
    whether the primary backend solved the instance itself;
    ``used_fallback[b]`` marks instances re-solved by the scipy fallback.
    """

    col_of: np.ndarray      # (B, N) int64
    total_cost: np.ndarray  # (B,) float64
    converged: np.ndarray   # (B,) bool
    used_fallback: np.ndarray  # (B,) bool
    backend: str
    wall_time_s: float = 0.0

    def pairs(self, b: int) -> Tuple[np.ndarray, np.ndarray]:
        """(row_ind, col_ind) of instance ``b`` — scipy-style contract."""
        rows = np.nonzero(self.col_of[b] >= 0)[0]
        return rows, self.col_of[b, rows]


# --------------------------------------------------------------------------- #
# Backend registry
# --------------------------------------------------------------------------- #
#: name -> fn(benefit_sq (B,S,S), eps_min, max_iters) -> (col_of (B,S), converged (B,))
_BACKENDS: Dict[str, Callable] = {}


def register_backend(name: str) -> Callable:
    """Register a batched square-benefit solver under ``name``.

    The callable receives the square-embedded benefit batch (maximise
    convention, padding already applied) and returns per-row column
    assignments plus a per-instance convergence flag.  Third-party
    schedulers can plug in e.g. a Sinkhorn or GPU-resident solver without
    touching any call site — backend choice stays one config knob.
    """

    def deco(fn: Callable) -> Callable:
        _BACKENDS[name] = fn
        return fn

    return deco


def available_backends() -> List[str]:
    return sorted(_BACKENDS) + ["auto"]


@register_backend("scipy")
def _solve_scipy(benefit: np.ndarray, eps_min=None, max_iters=None):
    from scipy.optimize import linear_sum_assignment as scipy_lsa

    b, s, _ = benefit.shape
    col_of = np.full((b, s), -1, dtype=np.int64)
    for i in range(b):
        rows, cols = scipy_lsa(benefit[i], maximize=True)
        col_of[i, rows] = cols
    return col_of, np.ones(b, dtype=bool)


@register_backend("numpy")
def _solve_numpy(benefit: np.ndarray, eps_min=None, max_iters=None):
    b, s, _ = benefit.shape
    col_of = np.full((b, s), -1, dtype=np.int64)
    for i in range(b):
        rows, cols = hungarian.linear_sum_assignment(benefit[i], maximize=True)
        col_of[i, rows] = cols
    return col_of, np.ones(b, dtype=bool)


@register_backend("smallperm")
def _solve_smallperm(benefit: np.ndarray, eps_min=None, max_iters=None):
    """Exact batched LAP for k <= 6 by vectorised permutation search.

    Replaces the k_c^2 sequential Hungarian calls in Algorithm 2's
    node-pair fan-out with one numpy pass — the node size k_l is 4-8 in
    every evaluated cluster, where k! brute force beats O(k^3) with Python
    overhead by ~100x (EXPERIMENTS.md §Perf, scheduler iteration 2).
    """
    b, k, _ = benefit.shape
    if k > SMALLPERM_MAX_K:
        raise ValueError(f"smallperm requires k <= {SMALLPERM_MAX_K}, got {k}")
    perms = np.array(list(itertools.permutations(range(k))), dtype=np.int64)
    picked = benefit[:, np.arange(k)[None, :], perms]  # (B, P, k)
    best = np.argmax(picked.sum(axis=-1), axis=-1)  # maximise benefit
    return perms[best], np.ones(b, dtype=bool)


def _solve_auction(benefit: np.ndarray, eps_min, max_iters, use_kernel: bool):
    import jax.numpy as jnp

    from repro.core.matching.auction import auction_lap_batched

    res = auction_lap_batched(
        jnp.asarray(benefit, jnp.float32),
        max_iters=max_iters,
        eps_min=eps_min,
        use_kernel=use_kernel,
    )
    return np.asarray(res.col_of, np.int64), np.asarray(res.converged, bool)


@register_backend("auction")
def _solve_auction_plain(benefit: np.ndarray, eps_min=None, max_iters=20_000):
    return _solve_auction(benefit, eps_min, max_iters, use_kernel=False)


@register_backend("auction_kernel")
def _solve_auction_kernel(benefit: np.ndarray, eps_min=None, max_iters=20_000):
    return _solve_auction(benefit, eps_min, max_iters, use_kernel=True)


def _pick_auto(size: int) -> str:
    if size <= SMALLPERM_MAX_K:
        return "smallperm"
    try:
        import scipy.optimize  # noqa: F401

        return "scipy"
    except ImportError:  # pragma: no cover - scipy is installed here
        return "numpy"


# --------------------------------------------------------------------------- #
# Entry points
# --------------------------------------------------------------------------- #
def solve_lap_batched(
    costs: np.ndarray,
    *,
    maximize: bool = False,
    row_mask: Optional[np.ndarray] = None,
    col_mask: Optional[np.ndarray] = None,
    backend: str = "auto",
    eps_min: Optional[float] = None,
    max_iters: int = 20_000,
) -> BatchedMatchResult:
    """Solve a batch of (rectangular, masked) LAPs with one backend call.

    Args:
      costs: (B, N, M) cost batch (numpy or jax array).  Non-finite entries
        are forbidden edges.  Pass a single (N, M) instance to get B=1.
      maximize: maximise total cost instead of minimising.
      row_mask / col_mask: (B, N) / (B, M) bool, True = real.  Padded rows
        and columns never receive an assignment.
      backend: a registered backend name or ``"auto"``.
      eps_min: auction final epsilon (default ``1/(S+1)``; the auction
        total is within ``S*eps_min`` of optimal — exact for integer costs).
      max_iters: auction bid-round budget; instances that exhaust it fall
        back to scipy (tracked per instance via ``used_fallback``).
    """
    t0 = time.perf_counter()
    costs = np.asarray(costs, dtype=np.float64)
    if costs.ndim == 2:
        costs = costs[None]
        if row_mask is not None:
            row_mask = np.asarray(row_mask, bool)[None]
        if col_mask is not None:
            col_mask = np.asarray(col_mask, bool)[None]
    if costs.ndim != 3:
        raise ValueError(f"costs must be (B, N, M), got shape {costs.shape}")
    b, n, m = costs.shape
    size = max(n, m)
    if backend == "auto":
        backend = _pick_auto(size)
    if backend not in _BACKENDS:
        raise ValueError(
            f"unknown LAP backend {backend!r}; registered: {available_backends()}"
        )
    if b == 0 or n == 0 or m == 0:
        return BatchedMatchResult(
            np.full((b, n), -1, np.int64),
            np.zeros(b),
            np.ones(b, bool),
            np.zeros(b, bool),
            backend,
            time.perf_counter() - t0,
        )

    benefit = masked_square_benefit(costs, maximize, row_mask, col_mask)
    col_of_sq, converged = _BACKENDS[backend](benefit, eps_min, max_iters)

    col_of, total, complete = _extract(costs, col_of_sq, row_mask, col_mask)
    expect = _expected_cardinality(costs, row_mask, col_mask)
    needs_fallback = (~converged) | (complete < expect)
    used_fallback = np.zeros(b, bool)
    if needs_fallback.any() and backend in APPROX_BACKENDS:
        fb = _pick_auto(size)
        idx = np.nonzero(needs_fallback)[0]
        fb_col, _ = _BACKENDS[fb](benefit[idx], None, None)
        fb_res, fb_total, fb_complete = _extract(
            costs[idx],
            fb_col,
            None if row_mask is None else row_mask[idx],
            None if col_mask is None else col_mask[idx],
        )
        # Adopt the exact re-solve only where it actually improves the
        # result: a structurally infeasible instance (forbidden edges make
        # a complete matching impossible) trips the cardinality check on
        # every call, but if the auction already found an equally large,
        # equally good matching there is nothing to fix — and counting it
        # as a fallback would poison the auction-quality metric the
        # microbench records.
        if maximize:
            improves = fb_total > total[idx]
        else:
            improves = fb_total < total[idx]
        adopt = (fb_complete > complete[idx]) | (
            (fb_complete == complete[idx]) & improves
        )
        sel = idx[adopt]
        col_of[sel] = fb_res[adopt]
        total[sel] = fb_total[adopt]
        used_fallback[sel] = True

    return BatchedMatchResult(
        col_of, total, converged, used_fallback, backend, time.perf_counter() - t0
    )


def _extract(costs, col_of_sq, row_mask, col_mask):
    """Map square-embedding assignments back to the original instances."""
    b, n, m = costs.shape
    cols = col_of_sq[:, :n].astype(np.int64)  # ignore padded rows
    valid = (cols >= 0) & (cols < m)
    safe = np.where(valid, cols, 0)
    picked = np.take_along_axis(costs, safe[:, :, None], axis=2)[:, :, 0]
    valid &= np.isfinite(picked)
    if row_mask is not None:
        valid &= np.asarray(row_mask, bool)
    if col_mask is not None:
        valid &= np.take_along_axis(np.asarray(col_mask, bool), safe, axis=1)
    col_of = np.where(valid, cols, -1)
    total = np.where(valid, picked, 0.0).sum(axis=1)
    return col_of, total, valid.sum(axis=1)


def _expected_cardinality(costs, row_mask, col_mask):
    b, n, m = costs.shape
    nr = np.full(b, n) if row_mask is None else np.asarray(row_mask, bool).sum(1)
    nc = np.full(b, m) if col_mask is None else np.asarray(col_mask, bool).sum(1)
    return np.minimum(nr, nc)


def solve_lap(
    cost: np.ndarray,
    maximize: bool = False,
    backend: str = "auto",
) -> Tuple[np.ndarray, np.ndarray]:
    """Single-instance LAP with the same backend knob as the batched engine.

    Drop-in superset of ``hungarian.solve_lap``: ``auto``/``numpy``/
    ``scipy`` keep the original exact dispatch (no square-embedding
    overhead); the auction backends route through the batched engine.
    Returns scipy-style ``(row_ind, col_ind)``.
    """
    if backend in ("auto", "numpy", "scipy"):
        return hungarian.solve_lap(cost, maximize=maximize, backend=backend)
    res = solve_lap_batched(
        np.asarray(cost)[None], maximize=maximize, backend=backend
    )
    return res.pairs(0)
