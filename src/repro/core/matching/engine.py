"""Unified batched matching engine — one entry point for every LAP in Tesserae.

Algorithm 2 solves k_c^2 independent node-pair LAPs per scheduling round,
packing (Algorithm 4) solves one rectangular max-weight matching, and the
final node-level match is one more square LAP.  Before this module each
call site picked its own solver (sequential scipy loops in
``migration.py``, ``hungarian.solve_lap`` in ``packing.py``, a bespoke
auction path in ``plan_migration_batched_auction``).  The engine unifies
them behind a *backend registry*:

==================  =========================================================
``scipy``           per-instance ``scipy.optimize.linear_sum_assignment``
                    (the paper-faithful reference; exact).  Rectangular
                    instances solve natively (no square embedding).
``numpy``           per-instance :mod:`repro.core.matching.hungarian` (exact,
                    no scipy dependency).  Rectangular instances solve
                    natively.
``smallperm``       vectorised brute force over all k! permutations — exact
                    and ~100x faster than looped Hungarian for the k <= 6
                    node-pair instances of Algorithm 2 (k_l is 4-8 on every
                    evaluated cluster).  Square-embedded.
``auction``         batched JAX auction (`auction_lap_batched`): one XLA
                    program for the whole fan-out; totals within the
                    documented ``n * eps`` bound of optimal (exact for
                    integer-valued costs).  Warm-startable (below); n != m
                    instances route to the native rectangular forward
                    auction — bids range only over real columns and no
                    ``max(n, m)^2`` square embedding is allocated.
``auction_kernel``  auction with the bid step lowered to the Pallas
                    ``lap_bid`` kernel (natively batched grid on TPU,
                    interpret mode on CPU).  Same warm-start / rectangular
                    semantics as ``auction``.
``auto``            ``smallperm`` when every instance is k <= 6, else
                    ``scipy`` when available, else ``numpy``
==================  =========================================================

All backends accept **rectangular** instances, **row/col masks** (padding —
so ragged batches solve in one call) and **forbidden edges** (non-finite
cost entries).  Square and ``smallperm`` instances normalise through the
square *benefit* embedding (:func:`~repro.core.matching.auction.
masked_square_benefit`); rectangular instances keep their (n, m) shape
(:func:`~repro.core.matching.auction.masked_rect_benefit`), oriented so
bidders are the short side.  Padded and forbidden cells get a constant
benefit strictly below every real benefit, which guarantees padding never
displaces a real pair in an optimal (or ``n*eps``-optimal) assignment.
Results are post-processed uniformly: pairs landing on padded/forbidden
cells are dropped, and — for the auction backends — instances whose
auction did not converge within the iteration budget (or, on the
rectangular path, whose warm-start price certificate fails, see below) are
transparently re-solved with an exact backend.

**Identity-keyed warm starts** (:class:`MatchContext`): placements change
little round-to-round (the temporal locality Tesserae's migration matching
exploits, Fig. 2/14b), so the scheduler threads an opaque ``MatchContext``
across rounds.  Cached state is keyed by *identity*, not by shape:

==================  =========================================================
``instance_ids``    (B,) — who each batch instance *is* (a node pair of the
                    Algorithm-2 fan-out, the packing graph, ...).  Supplied
                    by the caller; defaults to batch position.
``row_ids``         (B, N) or (N,) — identity of each cost row (a physical
                    GPU slot, a placed job id, ...).  Defaults to position.
``col_ids``         (B, M) or (M,) — identity of each cost column (a
                    logical GPU slot, a pending job id, ...).
==================  =========================================================

Reuse rules (per instance, after matching identities across rounds):

* **memo** — same row/col identity sets and bit-identical benefit cells:
  the cached assignment is remapped through the identity maps and reused
  outright (zero bid iterations; assignments are *bit-for-bit* those of a
  fresh solve because the fingerprint comparison is exact, see below).
* **warm** — surviving column identities re-assemble last round's auction
  **prices** (new columns start cold at 0); a content-changed or vanished
  row invalidates the price of the column it held last round.  Instances
  whose only delta is added/removed/permuted identities skip the
  epsilon-scaling schedule (one phase at ``eps_min``); instances with
  content-changed rows restart the full schedule with the surviving
  prices as a head start.
* **invalidation** — anything else (orientation flip, context-key or
  backend change, unseen instance id) is a cold start.
* **departed-identity LRU** — prices of identities that LEAVE a family are
  parked in a bounded per-family LRU; an identity resuming after absent
  rounds (Tiresias demotion-resume) re-enters with its parked prices as a
  head start (single phase at ``eps_min`` — valid for any initial prices)
  but is *not* reported warm: its content was never fingerprint-verified.

**Deterministic tie-breaking** (``tie_break=True``): equally-optimal
assignments are normally solver-dependent (scipy row order vs auction bid
order).  The canonical perturbation (:func:`_tie_break_perturb`) makes the
optimum unique without leaving the original optimal set; for integral
benefits the auction epsilon is tightened below the perturbation quantum,
so EVERY backend returns the identical assignment — the churn-replay
differential compares physical plans bit-for-bit across backends under
this flag.  Default off (seed assignments preserved).

**Partial-batch compaction**: instances that memo-hit never occupy solver
lanes — the changed instances are gathered into a dense sub-batch (padded
to a power-of-two bucket so jit signatures are reused across rounds),
solved, and scattered back next to the memoised results, preserving
per-instance ``converged`` / ``used_fallback`` flags.

**Device residency**: prices and benefit fingerprints live on device as
``jnp`` arrays end-to-end — price re-assembly, the rectangular price
certificate and the save-time price repair are device computations, and
``np.asarray`` happens only at the final assignment readout
(``col_of`` / ``converged`` / ``iters``).  Fingerprints are the exact f64
bit patterns of the benefit cells (two uint32 lanes), so fingerprint
equality is collision-free: a memo hit can never return a stale result.

Optimality under warm starts: for square instances the ``S * eps_min``
bound holds for ANY initial prices (both sides of the comparison telescope
over the same full column set).  For rectangular instances it additionally
requires that no unassigned column's final price exceeds an assigned
column's — the engine checks exactly that a posteriori
(:func:`_rect_bound_violation`) and re-solves the rare instance whose
certificate fails, so every returned total carries the documented bound.

Accuracy contract: with ``backend="auction"`` the returned per-instance
total cost is within ``S * eps_min`` of the scipy optimum, where ``S`` is
the solve size (the embedded square for n == m, the short side for
rectangular instances) and ``eps_min`` defaults to ``1 / (S + 1)`` — i.e.
*exact* whenever costs are integers (quantise first when exactness
matters; migration costs are multiples of ``1/(2*num_gpus)`` and are
scaled to integers by the caller).  The exact backends match scipy
identically, and with a context they memo/compact exactly like the
auction backends (minus price state).
"""

from __future__ import annotations

import dataclasses
import itertools
import json
import time
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.matching import hungarian
from repro.core.matching.auction import masked_rect_benefit, masked_square_benefit

#: Largest instance size solved by brute-force permutation search (k! <= 720).
SMALLPERM_MAX_K = 6

#: Backends whose totals carry the n*eps approximation bound (float costs).
APPROX_BACKENDS = ("auction", "auction_kernel")

#: Backends that solve rectangular (n != m) instances natively, without the
#: max(n, m)^2 square embedding.
RECT_BACKENDS = ("scipy", "numpy", "auction", "auction_kernel")

#: Synthetic identity base for rows/cols the square embedding pads in;
#: caller-supplied identities must stay above this (they are job/node/GPU
#: ids in practice, so any id > -2^40 is safe).
_PAD_ID_BASE = -(1 << 40)

#: Default capacity of the departed-identity price LRU (see MatchContext).
_DEPARTED_LRU_CAPACITY = 4096


def _tb_ranks(ids: Optional[np.ndarray], k: int) -> np.ndarray:
    """1-based tie-break ranks of each row/column identity within its
    instance: the rank of ``ids[b, i]`` among instance ``b``'s REAL ids
    (ascending), with synthetic embedding pads (<= ``_PAD_ID_BASE``)
    ranked after every real id in POSITION order.  ``ids=None`` degenerates
    to positions — bit-identical to the historical position-canonical
    ramp, and identical to materialised default ids (``arange`` + pads).
    Ranks depend only on the identity SET, so a surviving identity keeps
    its perturbation when the batch or its rows/columns permute."""
    if ids is None:
        return np.arange(1.0, k + 1.0)[None, :]
    pos = np.arange(k, dtype=np.int64)
    key = np.where(ids > _PAD_ID_BASE, ids, (1 << 62) + pos)
    order = np.argsort(key, axis=1, kind="stable")
    rank = np.empty(ids.shape, np.float64)
    np.put_along_axis(
        rank, order, np.broadcast_to(np.arange(k, dtype=np.float64), ids.shape), axis=1
    )
    return rank + 1.0


def _tie_break_perturb(
    benefit: np.ndarray,
    row_ids: Optional[np.ndarray] = None,
    col_ids: Optional[np.ndarray] = None,
) -> Tuple[np.ndarray, Optional[float]]:
    """Canonical tie-break perturbation (``tie_break=True``).

    Adds ``scale * r_i^2 * c_j`` to every cell of the embedded benefit,
    where ``r_i`` / ``c_j`` are the 1-based :func:`_tb_ranks` of the row /
    column IDENTITY within its instance (positions when no identities are
    supplied) — a canonical ramp under which two assignments that differ
    by swapping tied rows/columns (the dominant tie pattern: same-model
    pending jobs, interchangeable empty nodes) ALWAYS get distinct totals
    (the pairwise-swap delta is ``(r2^2-r1^2)(c2-c1) != 0``; some
    higher-order rotations can still collide — documented best effort).
    ``scale`` is a power of two small enough that any assignment's total
    perturbation stays below half the benefit quantum, so the perturbed
    optimum is always one of the ORIGINAL optima:

    * integral benefits (quantised migration costs): quantum 1.  Returns
      the scale so the caller can tighten the auction epsilon below it —
      the perturbed problem then has a unique optimum that EVERY backend
      (exact f64 or f32 auction) finds, making equally-optimal
      assignments solver-independent.
    * float benefits (packing throughputs): quantum ``span * 2^-20`` — a
      relative-precision heuristic, NOT a lower bound on real gaps, so
      for floats the optimal-set preservation is best-effort: two
      assignments whose true totals differ by less than ~``span * 2^-21``
      may be reordered (a relative error below 5e-7 — far inside the
      profile-noise floor these weights carry anyway).  The perturbation
      canonicalises the exact f64 backends; it is below f32 resolution,
      so the auction keeps its documented ``S*eps`` bound unchanged
      (returns ``None``: no epsilon tightening).

    Identity-keyed rather than position-canonical: a surviving (row_id,
    col_id) cell keeps its perturbed value when the batch or the rows /
    columns inside an instance permute, so identity-keyed memo/warm hits
    survive packing-graph permutations with tie-breaking on.  Ranks are a
    pure function of the per-instance identity set, so every backend
    still sees the identical perturbed instance — cross-solver parity is
    unconditional.
    """
    b, n, m = benefit.shape
    integral = bool(np.all(benefit == np.rint(benefit)))
    if integral:
        quantum = 1.0
    else:
        span = float(np.abs(benefit).max())
        quantum = max(span, 1.0) * 2.0**-20
    rr = _tb_ranks(row_ids, n)  # (B or 1, n)
    cc = _tb_ranks(col_ids, m)  # (B or 1, m)
    w = (rr**2)[:, :, None] * cc[:, None, :]
    # any assignment picks min(n, m) cells, each below n^2 * m
    bound = 2.0 * min(n, m) * float(n) * float(n) * float(m)
    scale = 2.0 ** np.floor(np.log2(quantum / bound))
    return benefit + scale * w, (float(scale) if integral else None)


def _benefit_total(benefit_nm: np.ndarray, col_of: np.ndarray) -> np.ndarray:
    """Per-instance total of ``benefit_nm`` cells selected by ``col_of``
    (original row space; -1 = unassigned).  Used to rank a primary solve
    against its exact fallback in PERTURBED space when tie-breaking."""
    b, n, m = benefit_nm.shape
    cols = col_of[:, :n]
    valid = (cols >= 0) & (cols < m)
    safe = np.where(valid, cols, 0)
    picked = np.take_along_axis(benefit_nm, safe[:, :, None], axis=2)[:, :, 0]
    return np.where(valid, picked, 0.0).sum(axis=1)


# --------------------------------------------------------------------------- #
# Result type
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class BatchedMatchResult:
    """Assignments for a batch of LAP instances.

    ``col_of[b, i]`` is the column assigned to row ``i`` of instance ``b``
    (-1 for unassigned / masked / padded rows).  ``total_cost[b]`` sums the
    ORIGINAL cost entries over assigned pairs.  ``converged[b]`` reports
    whether the primary backend solved the instance itself;
    ``used_fallback[b]`` marks instances re-solved by the exact fallback.
    ``bid_iters[b]`` counts auction bid rounds (0 for exact backends and
    memo hits); ``warm[b]`` marks instances served from a
    :class:`MatchContext` (memo hits and price-warm solves); ``embedding``
    records the solve geometry (``"square"`` / ``"rect"`` / ``"none"`` for
    empty batches).
    """

    col_of: np.ndarray      # (B, N) int64
    total_cost: np.ndarray  # (B,) float64
    converged: np.ndarray   # (B,) bool
    used_fallback: np.ndarray  # (B,) bool
    backend: str
    wall_time_s: float = 0.0
    bid_iters: Optional[np.ndarray] = None  # (B,) int64
    warm: Optional[np.ndarray] = None       # (B,) bool
    embedding: str = "square"

    def pairs(self, b: int) -> Tuple[np.ndarray, np.ndarray]:
        """(row_ind, col_ind) of instance ``b`` — scipy-style contract."""
        rows = np.nonzero(self.col_of[b] >= 0)[0]
        return rows, self.col_of[b, rows]


# --------------------------------------------------------------------------- #
# Persistent warm-start state
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class _CtxEntry:
    """Identity-keyed state cached from the previous solve of one family.

    ``fp_bits`` and ``prices`` are DEVICE arrays (jnp); everything needed
    for host control flow (identities, assignments, flags) stays numpy.
    """

    instance_ids: np.ndarray    # (B,) int64
    row_ids: np.ndarray         # (B, Ne) int64, original orientation (incl. pad ids)
    col_ids: np.ndarray         # (B, Me) int64
    transposed: bool
    rect: bool
    real_shape: Tuple[int, int]  # (n, m) before any square embedding
    fp_bits: "object"           # (B, Ne, Me, 2) uint32 jnp — exact f64 bit pattern
    prices: Optional["object"]  # (B, C) float32 jnp — oriented column prices
    owner: Optional[np.ndarray]  # (B, C) int64 — oriented col -> owning oriented row
    col_solve: np.ndarray       # (B, R) int64 oriented solve-space assignment
    final_col_of: np.ndarray    # (B, N) int64 original-space assignment
    converged: np.ndarray       # (B,) bool
    used_fallback: np.ndarray   # (B,) bool
    #: bucket-padded int32 device copies of (instance_ids, row_ids, col_ids)
    #: for the fused prologue; None when the ids don't fit the i32 bands
    ids_dev: Optional[tuple] = None


class MatchContext:
    """Opaque identity-keyed warm-start state for :func:`solve_lap_batched`.

    The scheduler creates one and threads it across rounds; each engine
    call site picks a ``context_key`` (e.g. ``"migration_pairs"``,
    ``"packing"``) so different LAP families never collide.  Per family
    the context stores, keyed by the caller-supplied instance/row/column
    *identities*: exact benefit fingerprints, the final auction **prices**
    (device-resident), and the final assignment.  See the module docstring
    for the memo / warm / invalidation semantics.

    A bounded **departed-identity LRU** rides along: when an instance or
    column identity leaves a family (a job finishes or is demoted, a node
    pair drops out of the fan-out), its final auction price is parked in a
    per-family LRU instead of being forgotten.  An identity that RETURNS
    after one or more absent rounds (the Tiresias demotion-resume pattern
    — the dominant Philly-trace event after plain arrivals) re-enters with
    its parked price as a head start instead of bidding up from zero.
    Correctness is unaffected: any initial price vector is valid (module
    docstring), and restored instances still run the full epsilon schedule
    (plus the rectangular certificate), so every bound survives.

    Thread-safety: none — one context per scheduler instance.
    """

    def __init__(self, departed_lru_capacity: int = _DEPARTED_LRU_CAPACITY):
        self._entries: Dict[tuple, _CtxEntry] = {}
        #: (context_key, backend) -> OrderedDict[(instance_id, col_id) -> price]
        self._departed: Dict[tuple, "OrderedDict[Tuple[int, int], float]"] = {}
        self.departed_lru_capacity = departed_lru_capacity
        #: opt-in observability bundle (repro.obs.Observability) — when set
        #: (TesseraeScheduler.set_observability), solve_lap_batched emits a
        #: span per engine call with this context's stat deltas.  Never
        #: serialised with the context payload; the owner re-attaches it.
        self.obs = None
        self.stats: Dict[str, int] = {
            "solves": 0,          # engine calls that consulted this context
            "memo_hits": 0,       # calls where EVERY instance memo-hit
            "memo_instances": 0,  # instances served from cache (0 bid iters)
            "warm_instances": 0,  # memo + price-warm instances
            "cold_instances": 0,
            "rows_invalidated": 0,  # price resets from changed/vanished rows
            "cert_violations": 0,   # rect bound certificate failures
            "compacted_solves": 0,  # calls that solved a proper sub-batch
            "bid_iters": 0,         # total auction bid rounds through this context
            "lru_parked_cols": 0,   # departed column prices parked in the LRU
            "lru_restored_cols": 0,  # cold columns re-seeded from the LRU
            "lru_dropped_cols": 0,   # parked prices dropped on shrink-return
            "host_syncs": 0,         # device->host readouts through this ctx
            "instances_invalidated": 0,  # targeted invalidations (node faults)
        }

    def get(self, key: tuple) -> Optional[_CtxEntry]:
        return self._entries.get(key)

    def store(self, key: tuple, entry: _CtxEntry) -> None:
        """Keep ONE entry per (context_key, backend) family: identities are
        matched against the *latest* round only, so an older round's state
        is dead weight — and without eviction a long-running scheduler
        would grow the cache by one entry per (maximize, eps) variant ever
        seen.  Prices of identities the new entry no longer carries are
        parked in the departed-identity LRU on the way out."""
        family = key[:2]
        old = self._entries.get(key)
        if (
            old is not None
            and old.prices is not None
            and self.departed_lru_capacity > 0
        ):
            # the LRU family carries the ORIENTATION: a transposed solve's
            # price columns are original rows, and parking them under the
            # same family as untransposed column prices would let a price
            # cross identity spaces on restore
            self._park_departed(family + (old.transposed,), old, entry)
        for k in [k for k in self._entries if k[:2] == family and k != key]:
            del self._entries[k]
        self._entries[key] = entry

    # -- departed-identity LRU ------------------------------------------- #
    @staticmethod
    def _oriented_col_ids(entry: _CtxEntry) -> np.ndarray:
        """Identity of each ORIENTED price column: original columns, or —
        for transposed rectangular solves, where the original rows bid as
        columns — the original row ids."""
        return entry.row_ids if entry.transposed else entry.col_ids

    def _park_departed(self, family: tuple, old: _CtxEntry, new: _CtxEntry) -> None:
        oc_old = self._oriented_col_ids(old)
        oc_new = self._oriented_col_ids(new)
        if (
            old.transposed == new.transposed
            and old.instance_ids.shape == new.instance_ids.shape
            and oc_old.shape == oc_new.shape
            and np.array_equal(old.instance_ids, new.instance_ids)
            and np.array_equal(oc_old, oc_new)
        ):
            return  # steady state: nothing departed
        pos = _positions_in(old.instance_ids[None, :], new.instance_ids[None, :])[0]
        safe = np.clip(pos, 0, new.instance_ids.shape[0] - 1)
        col_pos = _positions_in(oc_old, oc_new[safe])
        departed = ((col_pos < 0) | (pos < 0)[:, None]) & (oc_old > _PAD_ID_BASE)
        bb, cc = np.nonzero(departed)
        if bb.size == 0:
            return
        # one small device->host transfer of ONLY the departed prices
        vals = np.asarray(  # tessalint: sync-ok(documented LRU-park readout of just the departed rows; counted in stats[host_syncs])
            jnp.asarray(old.prices)[jnp.asarray(bb), jnp.asarray(cc)], np.float32
        )
        self.stats["host_syncs"] += 1
        lru = self._departed.setdefault(family, OrderedDict())
        parked = 0
        for b, c, v in zip(bb, cc, vals):
            if v == 0.0:
                continue  # a cold price is not worth a slot
            k = (int(old.instance_ids[b]), int(oc_old[b, c]))
            lru.pop(k, None)
            lru[k] = float(v)
            parked += 1
        self.stats["lru_parked_cols"] += parked
        while len(lru) > self.departed_lru_capacity:
            lru.popitem(last=False)

    def restore_departed(
        self,
        family: tuple,
        instance_ids: np.ndarray,
        oriented_col_ids: np.ndarray,
        cold_mask: np.ndarray,
    ) -> Optional[np.ndarray]:
        """Prices for cold (b, c) slots whose identity is parked in the
        LRU, or ``None`` when nothing matches.  Hits are popped — the
        price returns to the live entry at the next ``store``.

        A RETURNING instance consumes every parked entry it owns, whether
        or not the parked column identity is still present: an identity
        that departs and returns with a *changed* column set (the
        shrink-then-return pattern) must get its surviving columns
        restored and its no-longer-present columns DROPPED — a stale
        parked price that lingered past the return could otherwise be
        restored into a later, unrelated incarnation of the column id,
        whose equilibrium it no longer approximates.  (Restores are keyed
        by column identity, never zipped positionally, so a changed
        column ORDER is always safe.)

        Iterates the BOUNDED LRU (not the cold cells): a large fan-out
        with a few percent churn has far more cold slots than parked
        prices, and the per-instance column lookup is built lazily only
        for instances the LRU actually mentions."""
        lru = self._departed.get(family)
        if not lru:
            return None
        inst_pos: Dict[int, int] = {}
        for b, v in enumerate(instance_ids):
            inst_pos.setdefault(int(v), b)
        out = None
        restored = 0
        dropped = 0
        col_lut: Dict[int, Dict[int, int]] = {}
        for (iid, cid), price in list(lru.items()):
            b = inst_pos.get(iid)
            if b is None:
                continue  # instance still absent: keep its prices parked
            lut = col_lut.get(b)
            if lut is None:
                lut = col_lut[b] = {
                    int(v): j for j, v in enumerate(oriented_col_ids[b])
                }
            j = lut.get(cid)
            del lru[(iid, cid)]
            if j is None or not cold_mask[b, j]:
                # column gone (shrink-then-return) or already carrying a
                # live price that supersedes the parked one: drop it
                dropped += 1
                continue
            if out is None:
                out = np.zeros(cold_mask.shape, np.float32)
            out[b, j] = price
            restored += 1
        self.stats["lru_restored_cols"] += restored
        self.stats["lru_dropped_cols"] += dropped
        return out

    def invalidate_instances(self, instance_ids, families=None) -> int:
        """TARGETED invalidation of specific instance identities (the
        node-fault path): poison their cached benefit fingerprints and
        zero their warm prices, in every family (or only the
        ``context_key`` names listed in ``families``), and drop their
        parked departed-identity prices.

        The poison pattern is all-ones in both uint32 lanes — the f64 NaN
        bit pattern, which no real (finite) benefit cell can ever carry —
        so the next solve's exact fingerprint compare is GUARANTEED to
        miss: the instance re-solves cold (full epsilon schedule, zero
        prices, always valid) while every other instance's memo/warm
        state survives untouched.  Returns the number of cached instances
        invalidated.
        """
        ids = np.asarray(list(instance_ids), dtype=np.int64).reshape(-1)
        if ids.size == 0:
            return 0
        count = 0
        for key, entry in self._entries.items():
            if families is not None and key[0] not in families:
                continue
            hit = np.nonzero(np.isin(entry.instance_ids, ids))[0]
            if hit.size == 0:
                continue
            idx = jnp.asarray(hit.astype(np.int32))
            entry.fp_bits = jnp.asarray(entry.fp_bits).at[idx].set(
                jnp.uint32(0xFFFFFFFF)
            )
            if entry.prices is not None:
                entry.prices = jnp.asarray(entry.prices).at[idx].set(0.0)
            count += int(hit.size)
        id_set = {int(i) for i in ids}
        for fam, lru in self._departed.items():
            if families is not None and fam[0] not in families:
                continue
            for k in [k for k in lru if k[0] in id_set]:
                del lru[k]
        self.stats["instances_invalidated"] += count
        return count

    # -- snapshot / restore (crash-resume) -------------------------------- #
    STATE_VERSION = "tesserae-matchctx-v1"

    def state_payload(self) -> Tuple[Dict, Dict[str, np.ndarray]]:
        """The context's full state as ``(json-able meta, arrays)`` — the
        building block :meth:`save` writes to disk and the simulator
        embeds (key-prefixed) inside its own round-state snapshot."""
        arrays: Dict[str, np.ndarray] = {}
        meta: Dict = {
            "version": self.STATE_VERSION,
            "lru_capacity": self.departed_lru_capacity,
            "stats": dict(self.stats),
            "entries": [],
            "lru": [],
        }
        for i, (key, e) in enumerate(self._entries.items()):
            meta["entries"].append(
                {
                    "key": list(key),
                    "transposed": bool(e.transposed),
                    "rect": bool(e.rect),
                    "real_shape": list(e.real_shape),
                    "has_prices": e.prices is not None,
                    "has_owner": e.owner is not None,
                }
            )
            p = f"e{i}."
            arrays[p + "instance_ids"] = e.instance_ids
            arrays[p + "row_ids"] = e.row_ids
            arrays[p + "col_ids"] = e.col_ids
            arrays[p + "fp_bits"] = np.asarray(e.fp_bits)
            if e.prices is not None:
                arrays[p + "prices"] = np.asarray(e.prices, np.float32)
            if e.owner is not None:
                arrays[p + "owner"] = e.owner
            arrays[p + "col_solve"] = e.col_solve
            arrays[p + "final_col_of"] = e.final_col_of
            arrays[p + "converged"] = e.converged
            arrays[p + "used_fallback"] = e.used_fallback
        for j, (fam, lru) in enumerate(self._departed.items()):
            meta["lru"].append({"family": list(fam)})
            keys = np.array(list(lru.keys()), np.int64).reshape(-1, 2)
            vals = np.array(list(lru.values()), np.float32)
            arrays[f"lru{j}.keys"] = keys
            arrays[f"lru{j}.vals"] = vals
        return meta, arrays

    def save(self, path: str) -> None:
        """Serialise the full warm-start state to a versioned ``.npz``.

        Everything that affects future solves round-trips: per-family
        entries (identities, exact fingerprints, prices, assignments),
        the departed-identity LRUs (in recency order) and the stats
        counters.  :meth:`load` restores a context whose subsequent
        solves are bit-identical to one that never left memory — the
        crash-resume differential test gates on exactly that.
        """
        meta, arrays = self.state_payload()
        arrays["meta_json"] = np.array(json.dumps(meta))
        # write through a file object so numpy never appends ".npz"
        with open(path, "wb") as f:
            np.savez(f, **arrays)

    @classmethod
    def from_payload(cls, meta: Dict, get: Callable[[str], np.ndarray]) -> "MatchContext":
        """Rebuild a context from a :meth:`state_payload` meta dict and an
        array accessor (``get(name) -> ndarray``).  Device arrays
        (fingerprints, prices, the fused-prologue id buckets) are
        re-materialised on the current default device."""
        if meta.get("version") != cls.STATE_VERSION:
            raise ValueError(
                f"MatchContext state version {meta.get('version')!r} != "
                f"{cls.STATE_VERSION!r}"
            )
        ctx = cls(departed_lru_capacity=int(meta["lru_capacity"]))
        ctx.stats.update(meta["stats"])
        for i, em in enumerate(meta["entries"]):
            p = f"e{i}."
            k = em["key"]
            key = (k[0], k[1], bool(k[2]), k[3], bool(k[4]))
            inst = get(p + "instance_ids")
            rids = get(p + "row_ids")
            cids = get(p + "col_ids")
            ids_dev = None
            if _ids_i32_safe(inst, rids, cids):
                nb = _next_pow2(inst.shape[0])
                nn = _next_pow2(rids.shape[1])
                nm = _next_pow2(cids.shape[1])
                ids_dev = (
                    jnp.asarray(_bucket_vec_i32(inst, nb)),
                    jnp.asarray(_bucket_mat_i32(rids, nb, nn)),
                    jnp.asarray(_bucket_mat_i32(cids, nb, nm)),
                )
            ctx._entries[key] = _CtxEntry(
                instance_ids=inst,
                row_ids=rids,
                col_ids=cids,
                transposed=bool(em["transposed"]),
                rect=bool(em["rect"]),
                real_shape=tuple(em["real_shape"]),
                fp_bits=jnp.asarray(get(p + "fp_bits")),
                prices=(
                    jnp.asarray(get(p + "prices")) if em["has_prices"] else None
                ),
                owner=get(p + "owner") if em["has_owner"] else None,
                col_solve=get(p + "col_solve"),
                final_col_of=get(p + "final_col_of"),
                converged=get(p + "converged"),
                used_fallback=get(p + "used_fallback"),
                ids_dev=ids_dev,
            )
        for j, lm in enumerate(meta["lru"]):
            fam = tuple(
                bool(v) if isinstance(v, bool) else v for v in lm["family"]
            )
            lru: "OrderedDict[Tuple[int, int], float]" = OrderedDict()
            for (iid, cid), v in zip(get(f"lru{j}.keys"), get(f"lru{j}.vals")):
                lru[(int(iid), int(cid))] = float(v)
            ctx._departed[fam] = lru
        return ctx

    @classmethod
    def load(cls, path: str) -> "MatchContext":
        """Rebuild a context from :meth:`save` output."""
        with np.load(path, allow_pickle=False) as z:
            meta = json.loads(str(z["meta_json"][()]))
            return cls.from_payload(meta, lambda name: z[name])

    def reset(self) -> None:
        """Drop all cached state (prices, fingerprints, memoised results,
        parked departed-identity prices)."""
        self._entries.clear()
        self._departed.clear()

    def __len__(self) -> int:
        return len(self._entries)


# --------------------------------------------------------------------------- #
# Identity bookkeeping (host)
# --------------------------------------------------------------------------- #
def _as_instance_ids(ids, b: int) -> np.ndarray:
    if ids is None:
        return np.arange(b, dtype=np.int64)
    out = np.asarray(ids, dtype=np.int64).reshape(-1)
    if out.shape != (b,):
        raise ValueError(f"instance_ids must have shape ({b},), got {out.shape}")
    return out


def _as_id_matrix(ids, b: int, k: int, name: str) -> np.ndarray:
    if ids is None:
        return np.broadcast_to(np.arange(k, dtype=np.int64), (b, k))
    ids = np.asarray(ids, dtype=np.int64)
    if ids.ndim == 1:
        ids = np.broadcast_to(ids, (b, ids.shape[0]))
    if ids.shape != (b, k):
        raise ValueError(f"{name} must have shape ({b}, {k}), got {ids.shape}")
    return ids


def _pad_ids(ids: np.ndarray, size: int) -> np.ndarray:
    """Extend per-instance identities with synthetic ids for the rows/cols
    the square embedding pads in (stable across rounds, so an unchanged
    padded instance still memo-hits)."""
    b, k = ids.shape
    if k == size:
        return ids
    pad = _PAD_ID_BASE - np.arange(size - k, dtype=np.int64)
    return np.concatenate([ids, np.broadcast_to(pad, (b, size - k))], axis=1)


def _positions_in(new_ids: np.ndarray, old_ids: np.ndarray) -> np.ndarray:
    """Per-instance identity lookup: position of each ``new_ids[b, i]`` in
    ``old_ids[b, :]`` (first occurrence), or -1 when absent.  Vectorised
    over the batch via disjoint per-row key ranges + one flat searchsorted.
    """
    b, k0 = old_ids.shape
    if b == 0 or k0 == 0 or new_ids.shape[1] == 0:
        return np.full(new_ids.shape, -1, np.int64)
    if new_ids.shape == old_ids.shape and np.array_equal(new_ids, old_ids):
        return np.broadcast_to(
            np.arange(new_ids.shape[1], dtype=np.int64), new_ids.shape
        ).copy()
    lo = min(int(new_ids.min()), int(old_ids.min()))
    hi = max(int(new_ids.max()), int(old_ids.max()))
    span = hi - lo + 1
    if span * b < (1 << 62):
        order = np.argsort(old_ids, axis=1, kind="stable")
        sorted_old = np.take_along_axis(old_ids, order, axis=1)
        off = np.arange(b, dtype=np.int64)[:, None] * span
        flat_old = (sorted_old - lo + off).ravel()
        flat_new = (new_ids - lo + off).ravel()
        loc = np.minimum(np.searchsorted(flat_old, flat_new), flat_old.size - 1)
        hit = flat_old[loc] == flat_new
        return np.where(hit, order.ravel()[loc], -1).reshape(new_ids.shape)
    # id range too wide for the offset trick: per-row dict fallback
    out = np.full(new_ids.shape, -1, np.int64)
    for i in range(b):  # pragma: no cover - exotic ids only
        lut = {int(v): j for j, v in reversed(list(enumerate(old_ids[i])))}
        for j, v in enumerate(new_ids[i]):
            out[i, j] = lut.get(int(v), -1)
    return out


def _invert_pos(pos: np.ndarray, k_old: int) -> np.ndarray:
    """Invert per-instance position maps: ``pos`` (B, K_new) holds old
    positions (or -1); returns (B, K_old) with ``inv[b, pos[b, j]] = j``."""
    b = pos.shape[0]
    inv = np.full((b, k_old), -1, np.int64)
    bb, jj = np.nonzero(pos >= 0)
    inv[bb, pos[bb, jj]] = jj
    return inv


# --------------------------------------------------------------------------- #
# Device-resident fingerprints + price machinery
# --------------------------------------------------------------------------- #
def _f64_bits(a: np.ndarray) -> np.ndarray:
    """Exact fingerprint of f64 values: the raw bit pattern as two uint32
    lanes, ``(...,) f64 -> (..., 2) uint32``.  Equality of fingerprints is
    equality of bit patterns — collision-free (note -0.0 != +0.0 at the
    bit level; the spurious invalidation is harmless)."""
    a = np.ascontiguousarray(a, dtype=np.float64)
    return a.view(np.uint32).reshape(*a.shape, 2)


@jax.jit
def _rows_unchanged_dev(new_bits, old_bits, old_idx, row_pos, col_pos):
    """Per-row exact change detection on device.

    ``new_bits`` (B, N, M, 2) uint32; ``old_bits`` (B0, N0, M0, 2);
    ``old_idx`` (B,) instance match (-1 = cold); ``row_pos`` (B, N) /
    ``col_pos`` (B, M) identity positions in the old instance (-1 = new).
    A row is unchanged iff it existed last round and every SURVIVING
    column's cell is bit-identical (new columns don't count against it).
    """
    ob = jnp.clip(old_idx, 0, None)
    rp = jnp.clip(row_pos, 0, None)
    cp = jnp.clip(col_pos, 0, None)
    gathered = old_bits[ob[:, None, None], rp[:, :, None], cp[:, None, :]]
    eq = jnp.all(gathered == new_bits, axis=-1)
    eq = jnp.where((col_pos >= 0)[:, None, :], eq, True)
    return (row_pos >= 0) & (old_idx >= 0)[:, None] & jnp.all(eq, axis=-1)


def _assigned_cols(col_solve: np.ndarray, c: int) -> np.ndarray:
    """(B, C) bool mask of columns holding an assignment.  Scatters only
    the real (>= 0) entries — clipping -1 sentinels into index 0 would let
    an unassigned row clobber column 0's flag."""
    b = col_solve.shape[0]
    assigned = np.zeros((b, c), bool)
    bb, rr = np.nonzero(col_solve >= 0)
    assigned[bb, col_solve[bb, rr]] = True
    return assigned


def _rect_bound_violation(prices, col_solve) -> np.ndarray:
    """A-posteriori certificate for the rectangular ``n*eps`` bound.

    At termination the auction satisfies eps-complementary slackness wrt
    its FINAL prices, which yields (for any competing assignment S'):

        total(sigma) >= total(S') - R*eps - [sum_{S'\\sigma} p - sum_{sigma\\S'} p]

    The bracket is <= 0 for every S' iff no k largest unassigned-column
    prices sum above the k smallest assigned-column prices (pairwise), so

        D = max_k  sum_{i<k} (U_desc[i] - A_asc[i])  >  0

    is the exact condition under which warm-start prices could have broken
    the bound.  Cold rectangular solves start from all-equal prices, where
    unassigned columns keep the (minimal) initial price and D <= 0 by
    construction; warm starts can leave stale high prices on abandoned
    columns, and those instances are flagged for an exact re-solve.
    Instances with unassigned rows return False — the convergence /
    cardinality checks already flag them.

    ``prices`` may be a device (jnp) array — the check runs on device and
    only the (B,) verdict is synced to host.
    """
    b, c = prices.shape
    r = col_solve.shape[1]
    if r >= c or b == 0:
        return np.zeros(b, bool)  # square: bound holds for any prices
    verdict = _rect_violation_dev(
        jnp.asarray(prices, jnp.float32), jnp.asarray(np.asarray(col_solve))
    )
    return np.asarray(verdict)  # tessalint: sync-ok(syncs only the (B,) verdict per the docstring contract; the check itself runs on device)


@jax.jit
def _rect_violation_dev(prices, col_solve):
    b, c = prices.shape
    r = col_solve.shape[1]
    ok = col_solve >= 0
    safe = jnp.where(ok, col_solve, c)
    assigned = (
        jnp.zeros((b, c + 1), bool)
        .at[jnp.arange(b)[:, None], safe]
        .set(True)[:, :c]
    )
    complete = ok.all(axis=1)
    a_sorted = jnp.sort(jnp.where(assigned, prices, jnp.inf), axis=1)[:, :r]
    u_sorted = -jnp.sort(jnp.where(assigned, jnp.inf, -prices), axis=1)[:, : c - r]
    k = min(r, c - r)
    diff = u_sorted[:, :k] - a_sorted[:, :k]
    d_worst = jnp.cumsum(jnp.where(jnp.isfinite(diff), diff, 0.0), axis=1).max(axis=1)
    # Tolerance matches the slack the parity gates grant on top of the
    # documented S*eps_min bound (engine docstring / CI perf-smoke gate):
    # a deficit the certificate waves through must be invisible to them.
    # Erring tight is safe — a false positive only costs an exact
    # re-solve; a false negative is a bound violation.  Cold solves have
    # d_worst <= 0 exactly (unassigned columns keep the all-equal initial
    # price), so the tight tolerance never penalises them.
    return complete & (d_worst > 1e-6)


def _next_pow2(x: int) -> int:
    p = 1
    while p < x:
        p <<= 1
    return p


def _bucket_size(n_solve: int, b: int) -> int:
    """Pad a compacted sub-batch up to a power-of-two bucket (capped at the
    full batch) so the solver jit signature is shared across rounds with
    different churn counts instead of recompiling per count."""
    if n_solve in (0, b):
        return n_solve
    return min(_next_pow2(n_solve), b)


def _bucketed_bits(bits):
    """Zero-pad a (B, N, M, 2) fingerprint tensor to power-of-two B/N/M so
    the change-detection jit signature recurs across churn rounds instead
    of recompiling per (batch, shape) pair.  Padded cells are never
    consulted: padded batch entries carry ``old_idx == -1``, padded rows
    ``row_pos == -1`` and padded columns ``col_pos == -1``."""
    b, n, m, _ = bits.shape
    nb, nn, nm = _next_pow2(b), _next_pow2(n), _next_pow2(m)
    if (nb, nn, nm) == (b, n, m):
        return bits
    return jnp.pad(bits, ((0, nb - b), (0, nn - n), (0, nm - m), (0, 0)))


# --------------------------------------------------------------------------- #
# Device-side identity matching (fused prologue)
# --------------------------------------------------------------------------- #
# x64 is disabled, so device integers are int32 while host identities are
# int64 (with synthetic embedding pads below _PAD_ID_BASE = -2^40).  The
# prologue therefore runs on an order- and identity-preserving int32
# re-encoding with three disjoint bands:
#
#   real ids            (-2^30, 2^31)            pass through unchanged
#   embedding pads      (-2^30 - 2^20, -2^30]    shifted by _I32_PAD_OFFSET
#   bucket sentinels    below -2^30 - 2^21       power-of-two shape padding
#
# Band disjointness means a real id can never collide with a pad or a
# sentinel after encoding, so device matches are exactly the host matches.
# Callers whose ids fall outside the real band (or whose embedding exceeds
# 2^20) keep the host-numpy path (:func:`_positions_in`).
_I32_PAD_OFFSET = _PAD_ID_BASE + (1 << 30)
_I32_BUCKET_PAD = -(1 << 30) - (1 << 21)


def _ids_i32_safe(*id_arrays: np.ndarray) -> bool:
    """True when every identity fits its int32 device encoding band: real
    ids inside (-2^30, 2^31), embedding pads shallow enough (< 2^21 pad
    rows/cols, i.e. any practical embedding) to stay above the bucket
    sentinels."""
    for ids in id_arrays:
        if ids.size == 0:
            continue
        lo, hi = int(ids.min()), int(ids.max())
        if hi >= (1 << 31):
            return False
        if lo <= _PAD_ID_BASE:  # embedding pads present
            if lo <= _PAD_ID_BASE - (1 << 21) + 1:
                return False
            real = ids[ids > _PAD_ID_BASE]
            if real.size and int(real.min()) <= -(1 << 30):
                return False
        elif lo <= -(1 << 30):
            return False
    return True


def _encode_ids_i32(ids: np.ndarray) -> np.ndarray:
    return np.where(ids > _PAD_ID_BASE, ids, ids - _I32_PAD_OFFSET).astype(np.int32)


def _bucket_vec_i32(ids: np.ndarray, nb: int) -> np.ndarray:
    """Encode a (B,) instance-id vector into its (nb,) bucket."""
    out = np.empty(nb, np.int32)
    out[: ids.shape[0]] = _encode_ids_i32(ids)
    out[ids.shape[0]:] = (
        _I32_BUCKET_PAD - np.arange(nb - ids.shape[0], dtype=np.int64)
    ).astype(np.int32)
    return out


def _bucket_mat_i32(ids: np.ndarray, nb: int, nk: int) -> np.ndarray:
    """Encode a (B, K) row/col-id matrix into its (nb, nk) bucket.  Padded
    cells get per-position sentinels: unique within a row (the engine's
    identity-uniqueness contract extends to the padding) and out of every
    real/pad band, so they can only ever match OTHER sentinels — and those
    matches live entirely in the padded region the caller slices off (the
    fingerprint compare sees bit-equal zero cells there either way)."""
    b, k = ids.shape
    out = np.empty((nb, nk), np.int32)
    out[:b, :k] = _encode_ids_i32(ids)
    sent = (_I32_BUCKET_PAD - np.arange(nk, dtype=np.int64)).astype(np.int32)
    out[:b, k:] = sent[k:]
    out[b:, :] = sent[None, :]
    return out


@jax.jit
def _positions_in_dev(new_ids, old_ids):
    """Device counterpart of :func:`_positions_in`: position of each
    ``new_ids[b, i]`` in ``old_ids[b, :]`` (first occurrence, via stable
    argsort + left searchsorted — the same tie rule as the host path), or
    -1 when absent.  int32 ids (see the encoding bands above)."""
    order = jnp.argsort(old_ids, axis=1, stable=True)
    sorted_old = jnp.take_along_axis(old_ids, order, axis=1)
    loc = jax.vmap(lambda so, ni: jnp.searchsorted(so, ni, side="left"))(
        sorted_old, new_ids
    )
    loc = jnp.minimum(loc, old_ids.shape[1] - 1)
    hit = jnp.take_along_axis(sorted_old, loc, axis=1) == new_ids
    return jnp.where(hit, jnp.take_along_axis(order, loc, axis=1), -1)


@jax.jit
def _match_prologue_dev(
    inst, old_inst, rids, old_rids, cids, old_cids, new_bits, old_bits
):
    """The fused context-lookup prologue: instance matching, row/column
    identity matching and the exact fingerprint compare as ONE jitted
    program with a single 4-tuple readout — replacing the three host-numpy
    ``_positions_in`` passes plus the separate change-detection sync the
    host path performs per round."""
    old_idx = _positions_in_dev(inst[None, :], old_inst[None, :])[0]
    safe_b = jnp.clip(old_idx, 0, old_inst.shape[0] - 1)
    matched = old_idx >= 0
    row_pos = jnp.where(
        matched[:, None], _positions_in_dev(rids, old_rids[safe_b]), -1
    )
    col_pos = jnp.where(
        matched[:, None], _positions_in_dev(cids, old_cids[safe_b]), -1
    )
    unchanged = _rows_unchanged_dev(new_bits, old_bits, old_idx, row_pos, col_pos)
    return old_idx, row_pos, col_pos, unchanged


# --------------------------------------------------------------------------- #
# Backend registry
# --------------------------------------------------------------------------- #
#: name -> fn(benefit (B,R,C), eps_min, max_iters) -> (col_of (B,R), converged (B,))
_BACKENDS: Dict[str, Callable] = {}


def register_backend(name: str) -> Callable:
    """Register a batched benefit solver under ``name``.

    The callable receives the benefit batch (maximise convention, padding
    already applied; square-embedded unless the backend is listed in
    ``RECT_BACKENDS``) and returns per-row column assignments plus a
    per-instance convergence flag.  Third-party schedulers can plug in
    e.g. a Sinkhorn or GPU-resident solver without touching any call site
    — backend choice stays one config knob.
    """

    def deco(fn: Callable) -> Callable:
        _BACKENDS[name] = fn
        return fn

    return deco


def available_backends() -> List[str]:
    return sorted(_BACKENDS) + ["auto"]


@register_backend("scipy")
def _solve_scipy(benefit: np.ndarray, eps_min=None, max_iters=None):
    from scipy.optimize import linear_sum_assignment as scipy_lsa

    b, r, _ = benefit.shape
    col_of = np.full((b, r), -1, dtype=np.int64)
    for i in range(b):
        rows, cols = scipy_lsa(benefit[i], maximize=True)
        col_of[i, rows] = cols
    return col_of, np.ones(b, dtype=bool)


@register_backend("numpy")
def _solve_numpy(benefit: np.ndarray, eps_min=None, max_iters=None):
    b, r, _ = benefit.shape
    col_of = np.full((b, r), -1, dtype=np.int64)
    for i in range(b):
        rows, cols = hungarian.linear_sum_assignment(benefit[i], maximize=True)
        col_of[i, rows] = cols
    return col_of, np.ones(b, dtype=bool)


@register_backend("smallperm")
def _solve_smallperm(benefit: np.ndarray, eps_min=None, max_iters=None):
    """Exact batched LAP for k <= 6 by vectorised permutation search.

    Replaces the k_c^2 sequential Hungarian calls in Algorithm 2's
    node-pair fan-out with one numpy pass — the node size k_l is 4-8 in
    every evaluated cluster, where k! brute force beats O(k^3) with Python
    overhead by ~100x (EXPERIMENTS.md §Perf, scheduler iteration 2).
    """
    b, k, _ = benefit.shape
    if k > SMALLPERM_MAX_K:
        raise ValueError(f"smallperm requires k <= {SMALLPERM_MAX_K}, got {k}")
    perms = np.array(list(itertools.permutations(range(k))), dtype=np.int64)
    picked = benefit[:, np.arange(k)[None, :], perms]  # (B, P, k)
    best = np.argmax(picked.sum(axis=-1), axis=-1)  # maximise benefit
    return perms[best], np.ones(b, dtype=bool)


def _solve_auction(benefit: np.ndarray, eps_min, max_iters, use_kernel: bool):
    from repro.core.matching.auction import auction_lap_batched

    res = auction_lap_batched(
        jnp.asarray(benefit, jnp.float32),
        max_iters=max_iters,
        eps_min=eps_min,
        use_kernel=use_kernel,
    )
    # one transfer for both outputs, not one per field
    col_h, conv_h = jax.device_get((res.col_of, res.converged))  # tessalint: sync-ok(single readout of the finished batched solve; backend contract returns host arrays)
    return np.asarray(col_h, np.int64), np.asarray(conv_h, bool)


@register_backend("auction")
def _solve_auction_plain(benefit: np.ndarray, eps_min=None, max_iters=20_000):
    return _solve_auction(benefit, eps_min, max_iters, use_kernel=False)


@register_backend("auction_kernel")
def _solve_auction_kernel(benefit: np.ndarray, eps_min=None, max_iters=20_000):
    return _solve_auction(benefit, eps_min, max_iters, use_kernel=True)


def _pick_auto(size: int) -> str:
    if size <= SMALLPERM_MAX_K:
        return "smallperm"
    return _pick_exact()


def _pick_exact() -> str:
    try:
        import scipy.optimize  # noqa: F401

        return "scipy"
    except ImportError:  # pragma: no cover - scipy is installed here
        return "numpy"


def _run_auction(
    benefit: np.ndarray,
    rect: bool,
    eps_min,
    max_iters: int,
    use_kernel: bool,
    init_prices,
    warm: Optional[np.ndarray],
):
    """Dispatch a (possibly warm-started) auction solve.  Returns
    (col_of (B, R), converged (B,), prices (B, C) DEVICE array, iters
    (B,)) — only the assignment readout crosses back to host; prices stay
    jnp so a context can cache them without a device round-trip."""
    from repro.core.matching.auction import (
        auction_lap_batched,
        auction_lap_rect_batched,
    )

    solver = auction_lap_rect_batched if rect else auction_lap_batched
    res = solver(
        jnp.asarray(benefit, jnp.float32),
        max_iters=max_iters,
        eps_min=eps_min,
        use_kernel=use_kernel,
        init_prices=None if init_prices is None else jnp.asarray(init_prices),
        warm=None if warm is None else jnp.asarray(warm),
    )
    # one transfer for the three host-bound fields; prices stay on device
    col_h, conv_h, iters_h = jax.device_get((res.col_of, res.converged, res.iters))  # tessalint: sync-ok(the assignment readout documented above; consolidated so the solve costs one transfer)
    return (
        np.asarray(col_h, np.int64),
        np.asarray(conv_h, bool),
        res.prices,
        np.asarray(iters_h, np.int64),
    )


# --------------------------------------------------------------------------- #
# Entry points
# --------------------------------------------------------------------------- #
def solve_lap_batched(
    costs: np.ndarray,
    *,
    maximize: bool = False,
    row_mask: Optional[np.ndarray] = None,
    col_mask: Optional[np.ndarray] = None,
    backend: str = "auto",
    eps_min: Optional[float] = None,
    max_iters: int = 20_000,
    context: Optional[MatchContext] = None,
    context_key: str = "default",
    instance_ids: Optional[np.ndarray] = None,
    row_ids: Optional[np.ndarray] = None,
    col_ids: Optional[np.ndarray] = None,
    tie_break: bool = False,
) -> BatchedMatchResult:
    """Solve a batch of (rectangular, masked) LAPs with one backend call.

    When the ``context`` carries an observability bundle (``context.obs``,
    attached by ``TesseraeScheduler.set_observability``), each call emits a
    ``lap.solve`` span annotated with the per-family context-stat deltas
    (memo/warm/cold instances, bid iters, host syncs) and the solve
    outcome — pure host-side bookkeeping over numbers the solve already
    read back; no extra device work.

    Args:
      costs: (B, N, M) cost batch (numpy or jax array).  ``+inf`` under
        minimisation (``-inf`` under maximisation) marks a forbidden edge.
        NaN, and infinities of the OPPOSITE sign (an "infinitely
        attractive" edge), are rejected with a ``ValueError`` naming the
        offending instance — they would otherwise flow into the auction as
        silently-forbidden edges and can surface as non-convergence.
        Pass a single (N, M) instance to get B=1.
      maximize: maximise total cost instead of minimising.
      row_mask / col_mask: (B, N) / (B, M) bool, True = real.  Padded rows
        and columns never receive an assignment.
      backend: a registered backend name or ``"auto"``.
      eps_min: auction final epsilon (default ``1/(S+1)``; the auction
        total is within ``S*eps_min`` of optimal — exact for integer costs).
      max_iters: auction bid-round budget; instances that exhaust it fall
        back to an exact solver (tracked per instance via ``used_fallback``).
      context: optional :class:`MatchContext` carrying last round's prices,
        fingerprints and assignments — memoises unchanged instances and
        warm-starts the changed ones (see the module docstring).
      context_key: namespace inside ``context`` (one per LAP family, e.g.
        ``"migration_pairs"`` vs ``"packing"``), so unrelated call sites
        never share price state.
      instance_ids / row_ids / col_ids: identities the context keys its
        state by (see the module docstring table).  Defaults to positions,
        which preserves positional warm starts for fixed-shape callers;
        callers with churning batches (jobs arriving/finishing) must pass
        stable identities to keep surviving state warm across shape
        changes.  Identities must be unique within an instance and greater
        than ``-2^40`` (smaller values are reserved for embedding pads).
      tie_break: apply the canonical tie-break perturbation
        (:func:`_tie_break_perturb`) so equally-optimal assignments are
        solver-independent — for integral benefits the auction epsilon is
        tightened below the perturbation quantum, making the returned
        assignment bit-for-bit the one every exact backend returns.
        Default off: the unperturbed (seed) assignments are preserved.
    """
    obs = getattr(context, "obs", None) if context is not None else None
    kwargs = dict(
        maximize=maximize,
        row_mask=row_mask,
        col_mask=col_mask,
        backend=backend,
        eps_min=eps_min,
        max_iters=max_iters,
        context=context,
        context_key=context_key,
        instance_ids=instance_ids,
        row_ids=row_ids,
        col_ids=col_ids,
        tie_break=tie_break,
    )
    if obs is None:
        return _solve_lap_batched_impl(costs, **kwargs)
    batch = int(costs.shape[0]) if getattr(costs, "ndim", 2) == 3 else 1
    before = dict(context.stats)
    with obs.tracer.span("lap.solve", family=context_key, batch=batch) as sp:
        res = _solve_lap_batched_impl(costs, **kwargs)
        # host-side annotation only: converged/used_fallback are numpy
        # results the solve already transferred
        sp.annotate(
            backend=res.backend,
            embedding=res.embedding,
            converged=int(np.count_nonzero(res.converged)),
            fallbacks=int(np.count_nonzero(res.used_fallback)),
            **{
                k: int(v - before.get(k, 0))
                for k, v in context.stats.items()
                if v != before.get(k, 0)
            },
        )
    return res


def _solve_lap_batched_impl(
    costs: np.ndarray,
    *,
    maximize: bool = False,
    row_mask: Optional[np.ndarray] = None,
    col_mask: Optional[np.ndarray] = None,
    backend: str = "auto",
    eps_min: Optional[float] = None,
    max_iters: int = 20_000,
    context: Optional[MatchContext] = None,
    context_key: str = "default",
    instance_ids: Optional[np.ndarray] = None,
    row_ids: Optional[np.ndarray] = None,
    col_ids: Optional[np.ndarray] = None,
    tie_break: bool = False,
) -> BatchedMatchResult:
    """The batched-LAP engine body — see :func:`solve_lap_batched` for the
    full contract (the public name is a thin tracing wrapper)."""
    t0 = time.perf_counter()
    costs = np.asarray(costs, dtype=np.float64)
    if costs.ndim == 2:
        costs = costs[None]
        if row_mask is not None:
            row_mask = np.asarray(row_mask, bool)[None]
        if col_mask is not None:
            col_mask = np.asarray(col_mask, bool)[None]
    if costs.ndim != 3:
        raise ValueError(f"costs must be (B, N, M), got shape {costs.shape}")
    b, n, m = costs.shape
    # input validation: NaN never means anything, and an infinity of the
    # attractive sign (-inf minimize / +inf maximize) is not the documented
    # forbidden-edge encoding — both would be silently treated as forbidden
    # by the benefit masking and can surface rounds later as an unexplained
    # non-convergence.  Fail loudly, naming the instance.
    invalid = np.isnan(costs) | (np.isinf(costs) & ((costs > 0) == bool(maximize)))
    if invalid.any():
        bb, rr, cc = np.nonzero(invalid)
        ids = _as_instance_ids(instance_ids, b)
        val = costs[bb[0], rr[0], cc[0]]
        raise ValueError(
            f"solve_lap_batched: invalid cost entry {val!r} at "
            f"(row {rr[0]}, col {cc[0]}) of instance id {ids[bb[0]]} "
            f"(batch index {bb[0]}, context_key={context_key!r}, "
            f"maximize={maximize}); {int(invalid.sum())} invalid entr"
            f"{'y' if invalid.sum() == 1 else 'ies'} total.  Forbidden "
            f"edges must be {'-inf' if maximize else '+inf'}."
        )
    size = max(n, m)
    if backend == "auto":
        backend = _pick_auto(size)
    if backend not in _BACKENDS:
        raise ValueError(
            f"unknown LAP backend {backend!r}; registered: {available_backends()}"
        )
    if b == 0 or n == 0 or m == 0:
        return BatchedMatchResult(
            np.full((b, n), -1, np.int64),
            np.zeros(b),
            np.ones(b, bool),
            np.zeros(b, bool),
            backend,
            time.perf_counter() - t0,
            np.zeros(b, np.int64),
            np.zeros(b, bool),
            "none",
        )

    approx = backend in APPROX_BACKENDS
    rect = n != m and backend in RECT_BACKENDS
    transposed = rect and n > m
    if rect:
        benefit_nm = masked_rect_benefit(costs, maximize, row_mask, col_mask)
        oriented = (
            np.ascontiguousarray(np.swapaxes(benefit_nm, 1, 2))
            if transposed
            else benefit_nm
        )
    else:
        benefit_nm = oriented = masked_square_benefit(costs, maximize, row_mask, col_mask)
    ne, me = benefit_nm.shape[1:]
    rids = cids = None
    if tie_break:
        # identity-keyed perturbation: rank identities (not batch
        # positions) so a surviving (row, col) pair keeps its perturbed
        # cell when the batch or the identities inside it permute — the
        # fingerprint memo then still hits under tie-breaking.  Without
        # caller identities this degenerates bit-identically to the
        # positional ramp.
        if row_ids is not None or col_ids is not None:
            rids = _pad_ids(_as_id_matrix(row_ids, b, n, "row_ids"), ne)
            cids = _pad_ids(_as_id_matrix(col_ids, b, m, "col_ids"), me)
        benefit_nm, tb_scale = _tie_break_perturb(benefit_nm, rids, cids)
        oriented = (
            np.ascontiguousarray(np.swapaxes(benefit_nm, 1, 2))
            if transposed
            else benefit_nm
        )
        if tb_scale is not None and approx and eps_min is None:
            # resolve the perturbation: S * eps below the smallest gap
            # between distinct perturbed totals (>= tb_scale on the
            # integral quantum).  Deterministic in the shape alone, so
            # the context key stays stable across rounds.
            eps_min = tb_scale / (size + 1)
    r, c = oriented.shape[1:]

    # ---- context lookup: identity matching + memo + warm prices --------- #
    key = (context_key, backend, maximize, eps_min, tie_break)
    entry = None
    bits = None
    inst = None
    if context is not None:
        context.stats["solves"] += 1
        inst = _as_instance_ids(instance_ids, b)
        if rids is None:
            rids = _pad_ids(_as_id_matrix(row_ids, b, n, "row_ids"), ne)
            cids = _pad_ids(_as_id_matrix(col_ids, b, m, "col_ids"), me)
        bits = jnp.asarray(_f64_bits(benefit_nm))
        cand = context.get(key)
        if cand is not None and cand.transposed == transposed and cand.rect == rect:
            entry = cand

    memo_b = np.zeros(b, bool)
    warm_result = np.zeros(b, bool)
    warm_solver = np.zeros(b, bool)
    lru_warm = np.zeros(b, bool)  # instances re-seeded from the departed LRU
    init_prices_full = None  # (B, C) device, assembled by column identity
    col_of_memo = None
    stale = None
    old_idx = row_pos_or = col_pos_or = None
    if entry is not None:
        b0 = entry.instance_ids.shape[0]
        nb, nn, nm = _next_pow2(b), _next_pow2(ne), _next_pow2(me)
        if entry.ids_dev is not None and _ids_i32_safe(inst, rids, cids):
            # Device-resident identity matching: instance match, row/col
            # identity match and the exact fingerprint compare run as ONE
            # jitted program against the cached device copies of last
            # round's identities — a single 4-tuple readout instead of
            # three host-numpy passes plus a separate change-detection
            # sync.  Bucket-padded inputs keep the jit signature shared
            # across churn rounds.
            oi_d, rp_d, cp_d, ru_d = _match_prologue_dev(
                jnp.asarray(_bucket_vec_i32(inst, nb)),
                entry.ids_dev[0],
                jnp.asarray(_bucket_mat_i32(rids, nb, nn)),
                entry.ids_dev[1],
                jnp.asarray(_bucket_mat_i32(cids, nb, nm)),
                entry.ids_dev[2],
                _bucketed_bits(bits),
                entry.fp_bits,
            )
            oi_h, rp_h, cp_h, ru_h = jax.device_get((oi_d, rp_d, cp_d, ru_d))  # tessalint: sync-ok(the match prologue's single documented readout; counted in stats[host_syncs])
            context.stats["host_syncs"] += 1
            old_idx = np.asarray(oi_h, np.int64)[:b]
            row_pos = np.asarray(rp_h, np.int64)[:b, :ne]
            col_pos = np.asarray(cp_h, np.int64)[:b, :me]
            row_unchanged = np.asarray(ru_h)[:b, :ne]
            matched = old_idx >= 0
        else:
            # host fallback: ids outside the int32 encoding bands
            old_idx = _positions_in(inst[None, :], entry.instance_ids[None, :])[0]
            safe_h = np.clip(old_idx, 0, b0 - 1)
            row_pos = _positions_in(rids, entry.row_ids[safe_h])
            col_pos = _positions_in(cids, entry.col_ids[safe_h])
            matched = old_idx >= 0
            row_pos[~matched] = -1
            col_pos[~matched] = -1
            # bucket-pad the compare inputs (stored fingerprints are padded
            # at store time) so the jit signature recurs across churn rounds
            oi_p = np.full(nb, -1, np.int64)
            oi_p[:b] = old_idx
            rp_p = np.full((nb, nn), -1, np.int64)
            rp_p[:b, :ne] = row_pos
            cp_p = np.full((nb, nm), -1, np.int64)
            cp_p[:b, :me] = col_pos
            row_unchanged = np.asarray(  # tessalint: sync-ok(host-fallback path for ids outside the int32 bands; one readout of the row-unchanged verdict)
                _rows_unchanged_dev(
                    _bucketed_bits(bits),
                    entry.fp_bits,
                    jnp.asarray(oi_p),
                    jnp.asarray(rp_p),
                    jnp.asarray(cp_p),
                )
            )[:b, :ne]
            context.stats["host_syncs"] += 1
        safe_b = np.clip(old_idx, 0, b0 - 1)
        ne0, me0 = entry.row_ids.shape[1], entry.col_ids.shape[1]
        rows_bij = matched & (ne == ne0) & (row_pos >= 0).all(axis=1)
        cols_bij = matched & (me == me0) & (col_pos >= 0).all(axis=1)
        memo_b = rows_bij & cols_bij & row_unchanged.all(axis=1)
        changed_any = ((row_pos >= 0) & ~row_unchanged).any(axis=1)
        warm_solver = matched & ~changed_any
        if not (approx and entry.prices is not None):
            # exact backends carry no prices: short of a memo hit nothing
            # is warm-STARTED, so neither the result flag nor the stats
            # may claim it (PR-2 semantics; keeps warm-rate gates honest)
            warm_solver = np.zeros(b, bool)
        warm_result = memo_b | warm_solver

        if (
            memo_b.all()
            and np.array_equal(inst, entry.instance_ids)
            and np.array_equal(rids, entry.row_ids)
            and np.array_equal(cids, entry.col_ids)
        ):
            # Full-memo fast path: identical identities in identical
            # positions (the steady-state fan-out).  No remap, no price
            # re-assembly, and the stored entry (fingerprints, prices,
            # assignments) is still exactly right — nothing is re-stored.
            # This keeps the per-round cost of an unchanged 2048-GPU
            # fan-out at fingerprint-compare + readout.
            context.stats["memo_instances"] += b
            context.stats["warm_instances"] += b
            context.stats["memo_hits"] += 1
            col_of, total, _ = _extract(costs, entry.final_col_of, row_mask, col_mask)
            return BatchedMatchResult(
                col_of,
                total,
                entry.converged.copy(),
                entry.used_fallback.copy(),
                backend,
                time.perf_counter() - t0,
                np.zeros(b, np.int64),
                warm_result,
                "rect" if rect else "square",
            )

        # oriented views of the identity maps (bidders are the short side)
        row_pos_or = col_pos if transposed else row_pos
        col_pos_or = row_pos if transposed else col_pos
        r0 = me0 if transposed else ne0
        c0 = ne0 if transposed else me0

        if memo_b.any():
            mb = np.nonzero(memo_b)[0]
            ob = old_idx[mb]
            # original-space remap: old assignment re-expressed in the new
            # row/col positions of the surviving identities
            rp_n = row_pos[mb][:, :n]
            oc_n = np.take_along_axis(entry.final_col_of[ob], rp_n, axis=1)
            inv_n = _invert_pos(col_pos[mb][:, :m], entry.real_shape[1])
            col_of_memo = np.where(
                oc_n >= 0,
                np.take_along_axis(inv_n, np.clip(oc_n, 0, None), axis=1),
                -1,
            )
        if approx and entry.prices is not None:
            # Price re-assembly by column identity: surviving columns carry
            # last round's price, new columns start cold.  A column whose
            # last-round owner row changed content or vanished is reset —
            # its price reflects competition that may no longer exist.
            if transposed:
                # original row i IS oriented column i: reset it directly
                stale = (col_pos_or >= 0) & ~row_unchanged
            else:
                survived = np.zeros((b, r0), bool)
                bb, rr = np.nonzero(row_pos_or >= 0)
                survived[bb, row_pos_or[bb, rr]] = row_unchanged[bb, rr]
                own = np.where(
                    col_pos_or >= 0,
                    np.take_along_axis(
                        entry.owner[safe_b], np.clip(col_pos_or, 0, None), axis=1
                    ),
                    -1,
                )
                stale = (own >= 0) & ~np.take_along_axis(
                    survived, np.clip(own, 0, None), axis=1
                )
            keep_host = matched[:, None] & (col_pos_or >= 0) & ~stale
            gathered = jnp.asarray(entry.prices)[
                jnp.asarray(safe_b)[:, None],
                jnp.asarray(np.clip(col_pos_or, 0, c0 - 1)),
            ]
            # columns NOT carried over from last round may still have a
            # parked price from an earlier departure (demotion-resume):
            # seed them from the departed-identity LRU instead of zero.
            cold_seed = context.restore_departed(
                key[:2] + (transposed,), inst, rids if transposed else cids, ~keep_host
            )
            if cold_seed is not None:
                # a resumed instance restarts near its parked equilibrium:
                # skip the epsilon-scaling schedule (valid for ANY initial
                # prices — module docstring) but do NOT report it warm,
                # its content was never fingerprint-verified.
                lru_warm = (cold_seed != 0.0).any(axis=1)
            keep = jnp.asarray(keep_host)
            init_prices_full = jnp.where(
                keep,
                gathered,
                0.0 if cold_seed is None else jnp.asarray(cold_seed),
            )
        context.stats["memo_instances"] += int(memo_b.sum())
        context.stats["warm_instances"] += int(warm_result.sum())
        context.stats["cold_instances"] += int(b - warm_result.sum())
        if memo_b.all():
            context.stats["memo_hits"] += 1
    elif context is not None:
        context.stats["cold_instances"] += b

    # ---- partial-batch compaction + primary solve ----------------------- #
    sidx = np.nonzero(~memo_b)[0]
    col_solve_full = np.full((b, r), -1, np.int64)
    converged = np.ones(b, bool)
    used_fallback = np.zeros(b, bool)
    bid_iters = np.zeros(b, np.int64)
    prices_sub = None
    if entry is not None and memo_b.any():
        mb = np.nonzero(memo_b)[0]
        ob = old_idx[mb]
        rp = row_pos_or[mb]
        oc = np.take_along_axis(entry.col_solve[ob], rp, axis=1)
        inv = _invert_pos(col_pos_or[mb], c0)
        col_solve_full[mb] = np.where(
            oc >= 0, np.take_along_axis(inv, np.clip(oc, 0, None), axis=1), -1
        )
        converged[mb] = entry.converged[ob]
        used_fallback[mb] = entry.used_fallback[ob]
        if sidx.size:
            context.stats["compacted_solves"] += 1
    if stale is not None:
        solve_mask = ~memo_b
        context.stats["rows_invalidated"] += int((stale & solve_mask[:, None]).sum())

    if sidx.size:
        sub_ben = oriented[sidx]
        if approx:
            ip_sub = warm_sub = None
            if init_prices_full is not None:
                ip_sub = init_prices_full[jnp.asarray(sidx)]
                warm_sub = (warm_solver | lru_warm)[sidx]
            pb = _bucket_size(sidx.size, b) if context is not None else sidx.size
            if pb > sidx.size:
                pad = pb - sidx.size
                sub_ben = np.concatenate(
                    [sub_ben, np.zeros((pad, r, c), sub_ben.dtype)], axis=0
                )
                if ip_sub is not None:
                    ip_sub = jnp.concatenate(
                        [ip_sub, jnp.zeros((pad, c), ip_sub.dtype)], axis=0
                    )
                    warm_sub = np.concatenate([warm_sub, np.ones(pad, bool)])
            col_solve_sub, conv_sub, prices_pad, iters_sub = _run_auction(
                sub_ben,
                rect,
                eps_min,
                max_iters,
                use_kernel=(backend == "auction_kernel"),
                init_prices=ip_sub,
                warm=warm_sub,
            )
            ns = sidx.size
            col_solve_full[sidx] = col_solve_sub[:ns]
            converged[sidx] = conv_sub[:ns]
            bid_iters[sidx] = iters_sub[:ns]
            prices_sub = prices_pad[:ns]
            if context is not None:
                context.stats["host_syncs"] += 1  # auction assignment readout
        else:
            col_solve_sub, conv_sub = _BACKENDS[backend](sub_ben, eps_min, max_iters)
            col_solve_full[sidx] = col_solve_sub
            converged[sidx] = conv_sub

    col_full = _to_orig_cols(col_solve_full, transposed, n, m)
    if col_of_memo is not None:
        # memoised instances reuse the FINAL cached assignment (which may
        # include an exact-fallback fix the raw solve state lacks); only
        # the real rows are written — square-embedded pad rows are sliced
        # off by _extract anyway
        mb = np.nonzero(memo_b)[0]
        col_full[mb[:, None], np.arange(n)[None, :]] = col_of_memo
    col_of, total, complete = _extract(costs, col_full, row_mask, col_mask)
    expect = _expected_cardinality(costs, row_mask, col_mask)
    solve_mask = ~memo_b
    needs_fallback = solve_mask & ((~converged) | (complete < expect))
    if approx and rect and prices_sub is not None:
        viol = np.zeros(b, bool)
        viol[sidx] = _rect_bound_violation(prices_sub, col_solve_full[sidx])
        needs_fallback |= viol
        if context is not None:
            context.stats["cert_violations"] += int(viol.sum())
            context.stats["host_syncs"] += 1  # certificate verdict readout
    if needs_fallback.any() and approx:
        fb = _pick_exact() if rect else _pick_auto(size)
        idx = np.nonzero(needs_fallback)[0]
        fb_solve, _ = _BACKENDS[fb](oriented[idx], None, None)
        fb_res, fb_total, fb_complete = _extract(
            costs[idx],
            _to_orig_cols(fb_solve, transposed, n, m),
            None if row_mask is None else row_mask[idx],
            None if col_mask is None else col_mask[idx],
        )
        # Adopt the exact re-solve only where it actually improves the
        # result: a structurally infeasible instance (forbidden edges make
        # a complete matching impossible) trips the cardinality check on
        # every call, but if the auction already found an equally large,
        # equally good matching there is nothing to fix — and counting it
        # as a fallback would poison the auction-quality metric the
        # microbench records.
        if tie_break:
            # rank in PERTURBED benefit space: two original-optimal
            # assignments tie on original cost, but only the canonical
            # one wins the perturbed comparison — a fallback that found
            # it must displace a non-canonical primary result.
            improves = _benefit_total(benefit_nm[idx], fb_res) > _benefit_total(
                benefit_nm[idx], col_of[idx]
            )
        elif maximize:
            improves = fb_total > total[idx]
        else:
            improves = fb_total < total[idx]
        adopt = (fb_complete > complete[idx]) | (
            (fb_complete == complete[idx]) & improves
        )
        sel = idx[adopt]
        col_of[sel] = fb_res[adopt]
        total[sel] = fb_total[adopt]
        used_fallback[sel] = True

    if context is not None:
        context.stats["bid_iters"] += int(bid_iters.sum())
        prices_full = None
        if approx:
            base = (
                init_prices_full
                if init_prices_full is not None
                else jnp.zeros((b, c), jnp.float32)
            )
            if prices_sub is not None:
                base = base.at[jnp.asarray(sidx)].set(prices_sub)
            prices_full = base
            if rect:
                # Price repair before caching: a column with no owner is
                # available again next round, so its stale price is reset
                # to the cold-start level.  This keeps the stored prices
                # close to the all-equal-unassigned condition the
                # rectangular bound wants, so the next warm solve rarely
                # trips the certificate (which always runs on the *actual*
                # final prices, above).
                prices_full = jnp.where(
                    jnp.asarray(_assigned_cols(col_solve_full, c)), prices_full, 0.0
                )
        owner = np.full((b, c), -1, np.int64)
        bb, rr = np.nonzero(col_solve_full >= 0)
        owner[bb, col_solve_full[bb, rr]] = rr
        ids_dev = None
        if _ids_i32_safe(inst, rids, cids):
            nb, nn, nm = _next_pow2(b), _next_pow2(ne), _next_pow2(me)
            ids_dev = (
                jnp.asarray(_bucket_vec_i32(inst, nb)),
                jnp.asarray(_bucket_mat_i32(rids, nb, nn)),
                jnp.asarray(_bucket_mat_i32(cids, nb, nm)),
            )
        context.store(
            key,
            _CtxEntry(
                instance_ids=inst,
                row_ids=np.ascontiguousarray(rids),
                col_ids=np.ascontiguousarray(cids),
                transposed=transposed,
                rect=rect,
                real_shape=(n, m),
                fp_bits=_bucketed_bits(bits),
                prices=prices_full,
                owner=owner,
                col_solve=col_solve_full,
                final_col_of=col_of.copy(),
                converged=converged.copy(),
                used_fallback=used_fallback.copy(),
                ids_dev=ids_dev,
            ),
        )

    return BatchedMatchResult(
        col_of,
        total,
        converged,
        used_fallback,
        backend,
        time.perf_counter() - t0,
        bid_iters,
        warm_result,
        "rect" if rect else "square",
    )


def _to_orig_cols(
    col_solve: np.ndarray, transposed: bool, n: int, m: int
) -> np.ndarray:
    """Map solve-space assignments back to original row space.

    ``col_solve`` is (B, R) over the oriented instance.  Untransposed
    solves already index original columns; transposed (n > m rectangular)
    solves assign original *rows* to the m bidding columns and must be
    inverted (vectorised scatter)."""
    if not transposed:
        return col_solve
    b = col_solve.shape[0]
    col_of = np.full((b, n), -1, np.int64)
    bb, jj = np.nonzero((col_solve >= 0) & (col_solve < n))
    col_of[bb, col_solve[bb, jj]] = jj
    return col_of


def _extract(costs, col_of_sq, row_mask, col_mask):
    """Map solver assignments back to the original instances."""
    b, n, m = costs.shape
    cols = col_of_sq[:, :n].astype(np.int64)  # ignore padded rows
    valid = (cols >= 0) & (cols < m)
    safe = np.where(valid, cols, 0)
    picked = np.take_along_axis(costs, safe[:, :, None], axis=2)[:, :, 0]
    valid &= np.isfinite(picked)
    if row_mask is not None:
        valid &= np.asarray(row_mask, bool)
    if col_mask is not None:
        valid &= np.take_along_axis(np.asarray(col_mask, bool), safe, axis=1)
    col_of = np.where(valid, cols, -1)
    total = np.where(valid, picked, 0.0).sum(axis=1)
    return col_of, total, valid.sum(axis=1)


def _expected_cardinality(costs, row_mask, col_mask):
    b, n, m = costs.shape
    nr = np.full(b, n) if row_mask is None else np.asarray(row_mask, bool).sum(1)
    nc = np.full(b, m) if col_mask is None else np.asarray(col_mask, bool).sum(1)
    return np.minimum(nr, nc)


def solve_lap(
    cost: np.ndarray,
    maximize: bool = False,
    backend: str = "auto",
    context: Optional[MatchContext] = None,
    context_key: str = "default",
    row_ids: Optional[np.ndarray] = None,
    col_ids: Optional[np.ndarray] = None,
    tie_break: bool = False,
) -> Tuple[np.ndarray, np.ndarray]:
    """Single-instance LAP with the same backend knob as the batched engine.

    Drop-in superset of ``hungarian.solve_lap``: without a ``context``,
    ``auto``/``numpy``/``scipy`` keep the original exact dispatch (no
    embedding overhead) and the auction backends route through the batched
    engine.  With a ``context``, EVERY backend routes through the engine so
    identical consecutive solves memo-hit and the auction carries prices;
    ``row_ids``/``col_ids`` key that state by identity (e.g. node ids for
    the final migration match).  ``tie_break`` always routes through the
    engine (the canonical perturbation must apply).  Returns scipy-style
    ``(row_ind, col_ind)``.
    """
    if context is None and not tie_break and backend in ("auto", "numpy", "scipy"):
        return hungarian.solve_lap(cost, maximize=maximize, backend=backend)
    res = solve_lap_batched(
        np.asarray(cost)[None],
        maximize=maximize,
        backend=backend,
        context=context,
        context_key=context_key,
        row_ids=row_ids,
        col_ids=col_ids,
        tie_break=tie_break,
    )
    return res.pairs(0)
