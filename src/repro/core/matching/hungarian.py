"""Hungarian / shortest-augmenting-path solver for the assignment problem.

Tesserae reduces both of its placement policies to linear sum assignment:

* migration minimisation (§4.1, Algorithms 2 & 3) — *minimise* cost,
* packing (§4.2, Algorithm 4) — *maximise* weight (we negate).

This module provides a numpy-vectorised O(n^3) implementation of the
Jonker-Volgenant shortest augmenting path algorithm (the same family scipy
implements) plus a thin dispatcher ``solve_lap`` that can route to scipy —
the backend the paper uses — for large instances.

The implementation follows the classic potentials formulation: for each row
we grow an alternating tree using Dijkstra over reduced costs
``cost[i, j] - u[i] - v[j]`` until a free column is reached, then augment.
The inner column scan is vectorised with numpy, giving O(n^2) numpy work per
row (O(n^3) total) with tiny constants — adequate for the k_l x k_l and
k_c x k_c matrices in Algorithms 2/3 and for packing graphs with thousands
of jobs.
"""

from __future__ import annotations

import numpy as np

_INF = np.inf


def linear_sum_assignment(cost: np.ndarray, maximize: bool = False):
    """Solve the (possibly rectangular) linear sum assignment problem.

    Returns ``(row_ind, col_ind)`` with the same contract as
    ``scipy.optimize.linear_sum_assignment``: ``cost[row_ind, col_ind].sum()``
    is minimal (maximal when ``maximize``), rows are sorted, and
    ``len(row_ind) == min(cost.shape)``.

    Entries may be ``np.inf`` to forbid an assignment (a complete finite
    matching must still exist).
    """
    cost = np.asarray(cost, dtype=np.float64)
    if cost.ndim != 2:
        raise ValueError(f"cost must be 2-D, got shape {cost.shape}")
    if maximize:
        finite = np.isfinite(cost)
        flipped = np.where(finite, -cost, _INF)
        return linear_sum_assignment(flipped, maximize=False)

    n, m = cost.shape
    transposed = n > m
    if transposed:
        cost = cost.T
        n, m = m, n

    # col_to_row[j] = row currently assigned to column j (-1 = free).
    col_to_row = np.full(m, -1, dtype=np.int64)
    u = np.zeros(n, dtype=np.float64)  # row potentials
    v = np.zeros(m, dtype=np.float64)  # column potentials

    for cur_row in range(n):
        # Dijkstra from `cur_row` over columns on reduced costs.
        min_to = np.full(m, _INF, dtype=np.float64)  # shortest path to column j
        prev_col = np.full(m, -1, dtype=np.int64)    # previous column on path
        used = np.zeros(m, dtype=bool)

        i = cur_row
        j_cur = -1  # sentinel "virtual column" attached to cur_row
        while True:
            # Relax all unused columns from row i.
            reduced = cost[i] - u[i] - v
            better = ~used & (reduced < min_to)
            min_to = np.where(better, reduced, min_to)
            prev_col[better] = j_cur

            # Pick the closest unused column.
            masked = np.where(used, _INF, min_to)
            j_next = int(np.argmin(masked))
            delta = masked[j_next]
            if not np.isfinite(delta):
                raise ValueError("infeasible assignment problem (inf block)")

            # Update potentials: tree rows/cols move by delta.
            used_cols = used
            tree_rows = col_to_row[used_cols]
            u[cur_row] += delta
            u[tree_rows] += delta
            v[used_cols] -= delta
            min_to = np.where(used_cols, min_to, min_to - delta)

            used[j_next] = True
            j_cur = j_next
            i = col_to_row[j_next]
            if i == -1:
                break

        # Augment along the alternating path ending at free column j_cur.
        while j_cur != -1:
            j_prev = prev_col[j_cur]
            if j_prev == -1:
                col_to_row[j_cur] = cur_row
            else:
                col_to_row[j_cur] = col_to_row[j_prev]
            j_cur = j_prev

    row_ind = np.empty(n, dtype=np.int64)
    col_ind = np.empty(n, dtype=np.int64)
    k = 0
    for j in range(m):
        if col_to_row[j] >= 0:
            row_ind[k] = col_to_row[j]
            col_ind[k] = j
            k += 1
    order = np.argsort(row_ind[:k])
    row_ind, col_ind = row_ind[:k][order], col_ind[:k][order]
    if transposed:
        order = np.argsort(col_ind)
        return col_ind[order], row_ind[order]
    return row_ind, col_ind


def solve_lap(
    cost: np.ndarray,
    maximize: bool = False,
    backend: str = "auto",
):
    """Dispatch the LAP to a backend.

    ``backend``:
      * ``"auto"``  — scipy when available and n >= 64 (paper-faithful fast
        path), else our numpy implementation.
      * ``"numpy"`` — force our implementation.
      * ``"scipy"`` — force scipy (raises if unavailable).
    """
    cost = np.asarray(cost, dtype=np.float64)
    if backend not in ("auto", "numpy", "scipy"):
        raise ValueError(f"unknown LAP backend {backend!r}")

    use_scipy = backend == "scipy"
    if backend == "auto" and min(cost.shape) >= 64:
        use_scipy = True
    if use_scipy:
        try:
            from scipy.optimize import linear_sum_assignment as scipy_lsa
        except ImportError:  # pragma: no cover - scipy is installed here
            if backend == "scipy":
                raise
            use_scipy = False
        else:
            # scipy rejects matrices containing inf rows even when feasible
            # via other columns only in degenerate cases; contract matches ours.
            return scipy_lsa(cost, maximize=maximize)
    return linear_sum_assignment(cost, maximize=maximize)


def assignment_cost(cost: np.ndarray, row_ind, col_ind) -> float:
    """Total cost of an assignment (helper used by tests & Algorithm 2)."""
    cost = np.asarray(cost, dtype=np.float64)
    return float(cost[np.asarray(row_ind), np.asarray(col_ind)].sum())
