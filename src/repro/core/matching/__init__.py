"""Assignment (linear-sum-assignment / LAP) solvers used by Tesserae.

Three interchangeable backends:

* :func:`repro.core.matching.hungarian.linear_sum_assignment` — our own
  numpy-vectorised Jonker-Volgenant-style shortest-augmenting-path solver
  (no scipy dependency), used for small/medium problems and as a second
  oracle in tests.
* ``scipy.optimize.linear_sum_assignment`` — the backend the paper itself
  uses (§5 "We use Scipy to generate the migration plan ... and solve the
  weighted bipartite graph matching problem").  Default for large n.
* :func:`repro.core.matching.auction.auction_lap` — a jit/vmap-able JAX
  auction-algorithm solver (beyond-paper): Algorithm 2 solves k_c**2
  independent node-level LAPs, which we batch with ``jax.vmap``.
"""

from repro.core.matching.hungarian import linear_sum_assignment, solve_lap
from repro.core.matching.auction import auction_lap, auction_lap_batched

__all__ = [
    "linear_sum_assignment",
    "solve_lap",
    "auction_lap",
    "auction_lap_batched",
]
