"""Assignment (linear-sum-assignment / LAP) solvers used by Tesserae.

The public entry points are the **unified batched matching engine**
(:mod:`repro.core.matching.engine`):

* :func:`solve_lap_batched` — one call for a whole batch of (rectangular,
  masked, forbidden-edge) LAP instances, dispatched through a backend
  registry (``scipy`` / ``numpy`` / ``smallperm`` / ``auction`` /
  ``auction_kernel`` / ``auto``) with per-instance convergence tracking
  and a scipy fallback for non-converged auction instances.  Rectangular
  instances solve natively (no square embedding) on the rect-capable
  backends.
* :class:`MatchContext` — opaque **identity-keyed** warm-start state a
  scheduler threads across rounds: callers supply instance/row/column
  identities (job ids, node ids, GPU slots) and the context re-assembles
  last round's device-resident auction prices for the surviving
  identities, memoises bit-identical instances (remapped through the
  identity maps, so batches may grow/shrink/permute), and compacts the
  changed instances into a dense sub-batch before solving.
* :func:`solve_lap` — single-instance wrapper with the same backend knob.
* :func:`register_backend` / :func:`available_backends` — plug-in points.

Underlying solvers (importable directly when needed):

* :mod:`repro.core.matching.hungarian` — numpy-vectorised Jonker-Volgenant
  shortest-augmenting-path solver (no scipy dependency) plus the
  scipy dispatcher the paper itself uses (§5 "We use Scipy to ... solve
  the weighted bipartite graph matching problem").
* :mod:`repro.core.matching.auction` — jit/vmap-able JAX auction solver
  (beyond-paper): Algorithm 2 solves k_c**2 independent node-level LAPs,
  which batch into ONE XLA program, with the bid step optionally lowered
  to the Pallas ``lap_bid`` kernel.
"""

from repro.core.matching.auction import (
    auction_assignment,
    auction_lap,
    auction_lap_batched,
    auction_lap_rect_batched,
    masked_rect_benefit,
    masked_square_benefit,
)
from repro.core.matching.engine import (
    BatchedMatchResult,
    MatchContext,
    available_backends,
    register_backend,
    solve_lap,
    solve_lap_batched,
)
from repro.core.matching.hungarian import assignment_cost, linear_sum_assignment

__all__ = [
    "BatchedMatchResult",
    "MatchContext",
    "assignment_cost",
    "auction_assignment",
    "auction_lap",
    "auction_lap_batched",
    "auction_lap_rect_batched",
    "available_backends",
    "linear_sum_assignment",
    "masked_rect_benefit",
    "masked_square_benefit",
    "register_backend",
    "solve_lap",
    "solve_lap_batched",
]
