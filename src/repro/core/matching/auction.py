"""JAX auction-algorithm solver for the assignment problem (beyond-paper).

The paper solves every matching with scipy's Hungarian on the host CPU.  Two
observations make a JAX solver worthwhile:

1. Algorithm 2 solves **k_c^2 independent node-level LAPs** (one per node
   pair) before the final node-level matching — an embarrassingly batchable
   fan-out that ``jax.vmap`` turns into one XLA program.
2. Bertsekas' auction algorithm is data-parallel *inside* each instance: the
   bid step is a masked row-wise top-2 reduction over the benefit matrix —
   a natural accelerator kernel (see ``repro.kernels.lap_bid`` for the Pallas
   version tiled for VMEM).

We implement the Jacobi (all-unassigned-bid-simultaneously) forward auction
with epsilon scaling.  For integer-valued benefits and a final
``eps < 1/n`` the result is provably optimal; for float benefits the total
benefit is within ``n * eps_min`` of optimal (we quantise throughputs before
solving when exactness matters).

Warm starts (beyond-paper): every solver accepts ``init_prices`` and a
per-instance ``warm`` flag.  Auction correctness never depends on the
initial prices — each bid re-establishes eps-complementary slackness for
the bidder — so carrying last round's equilibrium prices into this round's
solve is always *valid*; when the costs barely changed (the Tesserae
round-to-round locality the paper's Fig. 2/14b exploits) it is also *fast*:
a warm instance skips the epsilon-scaling schedule entirely and runs one
phase at ``eps_min``.  The matching engine's identity-keyed
``MatchContext`` is the canonical producer of ``init_prices``: it
re-assembles last round's prices per *column identity* (jobs/nodes/GPUs),
so prices survive rows and columns arriving, finishing or permuting — any
re-assembly is valid by the argument above, it only has to be *useful*.
For square instances the ``n * eps`` bound holds for ANY initial prices
(both totals telescope over the same full column set); for rectangular
instances the matching engine verifies an a-posteriori price certificate
and re-solves the rare instance that fails it (see
``engine._rect_bound_violation``).  ``AuctionResult.prices`` is returned
as a device array and is cached as one — prices never round-trip through
the host between rounds.

Rectangular instances (n != m) also get a **native forward auction**
(:func:`auction_lap_rect_batched`): bidders are the short side, bids range
only over the real columns, and no ``max(n, m)^2`` square embedding is ever
materialised — the fix for very skew packing graphs (|placed| >> |pending|)
where the square embedding paid quadratic work for a linear-ish problem.

All shapes are static; the solvers are ``jit``- and ``vmap``-compatible.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

_NEG = -1e18

#: Instance size at which the Pallas bid kernel becomes the default bid
#: path on TPU (one (n, n) VMEM-tiled top-2 sweep per round beats the XLA
#: argmax/one-hot lowering).  Off-TPU the kernel only runs in interpret
#: mode, which is strictly slower than jnp — so auto mode never picks it
#: there; tests opt in explicitly with ``use_kernel=True``.
KERNEL_MIN_N = 256


def _auto_use_kernel(n: int) -> bool:
    return n >= KERNEL_MIN_N and jax.default_backend() == "tpu"


class AuctionResult(NamedTuple):
    # col_of[i]  = object assigned to person (row) i
    # row_of[j]  = person assigned to object (column) j
    col_of: jax.Array
    row_of: jax.Array
    prices: jax.Array
    iters: jax.Array
    converged: jax.Array


def _top2(vals: jax.Array):
    """Row-wise (best value, best index, second-best value)."""
    best_j = jnp.argmax(vals, axis=-1)
    n = vals.shape[-1]
    best_v = jnp.take_along_axis(vals, best_j[..., None], axis=-1)[..., 0]
    masked = jnp.where(
        jax.nn.one_hot(best_j, n, dtype=bool), _NEG, vals
    )
    second_v = jnp.max(masked, axis=-1)
    return best_v, best_j, second_v


def _inverse_assignment(assign: jax.Array, out_size: int) -> jax.Array:
    """Invert a partial injective map: ``assign`` (k,) holds values in
    ``[0, out_size)`` or -1; returns (out_size,) with ``inv[assign[i]] = i``
    and -1 elsewhere.  Square helpers are the ``out_size == k`` case."""
    k = assign.shape[0]
    safe = jnp.where(assign >= 0, assign, out_size)
    return (
        jnp.full((out_size + 1,), -1, jnp.int32)
        .at[safe]
        .set(jnp.arange(k, dtype=jnp.int32))[:out_size]
    )


def auction_lap(
    benefit: jax.Array,
    eps_min: float | jax.Array | None = None,
    max_iters: int = 20_000,
    use_kernel: bool | None = None,
    init_prices: jax.Array | None = None,
    warm: bool | jax.Array = False,
) -> AuctionResult:
    """Maximise ``sum_i benefit[i, col_of[i]]`` over permutations.

    Args:
      benefit: (n, n) float matrix.  Use ``-cost`` to minimise.  Forbidden
        edges should be a large negative number (not -inf, to keep bids
        finite) — see :func:`masked_square_benefit` for the embedding that
        handles rectangular / masked instances.
      eps_min: final epsilon of the scaling schedule.  Defaults to
        ``1 / (n + 1)`` — exact for integer benefits (only the STARTING
        epsilon is scaled by the benefit range).
      max_iters: safety cap on total bid rounds.
      use_kernel: route the bid top-2 through the Pallas kernel.  ``None``
        (default) picks the kernel automatically for instances with
        ``n >= KERNEL_MIN_N`` on TPU; off-TPU the kernel runs in interpret
        mode and is only used when explicitly requested.
      init_prices: (n,) warm-start prices (defaults to zeros).  Any values
        are valid; see the module docstring for the optimality argument.
      warm: skip the epsilon-scaling schedule and run a single phase at
        ``eps_min`` — the warm-start fast path when ``init_prices`` are
        near this round's equilibrium.
    """
    if use_kernel is None:
        use_kernel = _auto_use_kernel(int(benefit.shape[-1]))
    return _auction_lap_jit(
        benefit,
        eps_min,
        max_iters=max_iters,
        use_kernel=use_kernel,
        init_prices=init_prices,
        warm=jnp.asarray(warm),
    )


@functools.partial(jax.jit, static_argnames=("max_iters", "use_kernel"))
def _auction_lap_jit(
    benefit: jax.Array,
    eps_min: float | jax.Array | None = None,
    max_iters: int = 20_000,
    use_kernel: bool = False,
    init_prices: jax.Array | None = None,
    warm: jax.Array | None = None,
) -> AuctionResult:
    benefit = jnp.asarray(benefit, dtype=jnp.float32)
    n = benefit.shape[-1]
    if benefit.shape != (n, n):
        raise ValueError(f"benefit must be square, got {benefit.shape}")

    if eps_min is None:
        eps_min = 1.0 / (n + 1)
    eps_min = jnp.asarray(eps_min, dtype=jnp.float32)
    span = jnp.maximum(jnp.max(jnp.abs(benefit)), 1.0)
    eps0 = jnp.maximum(span / 4.0, eps_min)
    if warm is not None:
        # warm instances skip the scaling schedule: one phase at eps_min.
        eps0 = jnp.where(warm, eps_min, eps0)

    bid_round = _make_bid_round(benefit, n, _pick_top2(use_kernel))

    def cond(state):
        prices, col_of, eps, it, _ = state
        all_assigned = jnp.all(col_of >= 0)
        done = all_assigned & (eps <= eps_min * (1 + 1e-6))
        return (~done) & (it < max_iters)

    def body(state):
        prices, col_of, eps, it, _ = state
        all_assigned = jnp.all(col_of >= 0)
        # Phase change: shrink eps and restart the assignment, keep prices.
        def next_phase(_):
            return prices, jnp.full((n,), -1, jnp.int32), jnp.maximum(eps / 5.0, eps_min)

        def same_phase(_):
            p, c = bid_round(prices, col_of, eps)
            return p, c, eps

        prices, col_of, eps = jax.lax.cond(
            all_assigned & (eps > eps_min * (1 + 1e-6)), next_phase, same_phase, None
        )
        return prices, col_of, eps, it + 1, jnp.all(col_of >= 0)

    p0 = (
        jnp.zeros((n,), jnp.float32)
        if init_prices is None
        else jnp.asarray(init_prices, jnp.float32)
    )
    init = (
        p0,
        jnp.full((n,), -1, jnp.int32),
        eps0,
        jnp.asarray(0, jnp.int32),
        jnp.asarray(False),
    )
    prices, col_of, eps, iters, _ = jax.lax.while_loop(cond, body, init)
    # Converged = completed the FULL epsilon schedule with everyone
    # assigned.  All-assigned alone is not enough: an instance cut off by
    # ``max_iters`` mid-scaling can hold a complete but far-from-optimal
    # assignment (eps still large) — the engine must know to re-solve it.
    converged = jnp.all(col_of >= 0) & (eps <= eps_min * (1 + 1e-6))
    row_of = _inverse_assignment(col_of, n)
    return AuctionResult(col_of, row_of, prices, iters, converged)


def _pick_top2(use_kernel: bool):
    """Bid top-2 reduction as ``(benefit, prices) -> (best, arg, second)``.

    The kernel path hands benefit and prices to the Pallas kernel, which
    fuses the ``benefit - prices`` subtraction into its tiled sweep — no
    (n, m) ``vals`` temporary is materialised per bid round (the previous
    code precomputed ``vals`` in XLA and then had the kernel subtract a
    zero price vector from it)."""
    if use_kernel:
        from repro.kernels.ops import lap_bid

        return lap_bid
    return lambda benefit, prices: _top2(benefit - prices[None, :])


def _make_bid_round(benefit: jax.Array, m: int, top2):
    """Jacobi bid round over an (n, m) benefit matrix (square or rect):
    every unassigned person bids for its best object; objects take the
    highest bid.  Returns ``(prices, col_of) -> (prices, col_of)``."""
    n = benefit.shape[0]

    def bid_round(prices, col_of, eps):
        unassigned = col_of < 0
        best_v, best_j, second_v = top2(benefit, prices)
        incr = best_v - second_v + eps
        # Bid value person i offers for its best object.
        offer = prices[best_j] + incr
        # (n_persons, n_objects) matrix of offers; -inf where no bid.
        bids = jnp.where(
            unassigned[:, None] & jax.nn.one_hot(best_j, m, dtype=bool),
            offer[:, None],
            _NEG,
        )
        has_bid = jnp.any(bids > _NEG / 2, axis=0)
        winner = jnp.argmax(bids, axis=0)
        new_price = jnp.max(bids, axis=0)
        prices = jnp.where(has_bid, new_price, prices)
        # Recompute owners: objects with a bid switch to the winner.
        row_of_prev = _inverse_assignment(col_of, m)
        row_of = jnp.where(has_bid, winner, row_of_prev)
        col_of = _inverse_assignment(row_of, n)
        return prices, col_of

    return bid_round


@functools.partial(jax.jit, static_argnames=("max_iters", "use_kernel"))
def _auction_lap_rect_jit(
    benefit: jax.Array,
    eps_min: float | jax.Array | None = None,
    max_iters: int = 20_000,
    use_kernel: bool = False,
    init_prices: jax.Array | None = None,
    warm: jax.Array | None = None,
) -> AuctionResult:
    """Native rectangular forward auction: (n, m) benefit with n <= m.

    The n persons (rows) bid over the m real objects — no square embedding,
    no padded bidders.  Termination: all n persons assigned (always
    feasible: the engine's rect benefit is finite everywhere).

    Unlike the square solver, the rectangular auction runs a SINGLE phase
    at ``eps_min``: the ``n * eps`` optimality bound for asymmetric
    instances requires the final prices of unassigned objects to never
    exceed those the optimum would use — automatic when initial prices are
    all equal, but *broken* by epsilon-scaling phase restarts (a column
    over-priced in an early large-eps phase and then abandoned keeps its
    stale price, and with m > n it is never forced back to equilibrium;
    empirically this loses several spans of benefit, not ``n * eps``).
    Warm starts pass non-equal ``init_prices``; the engine re-establishes
    the bound a posteriori via the price certificate
    (``engine._rect_bound_violation``) and re-solves instances that fail.
    """
    benefit = jnp.asarray(benefit, dtype=jnp.float32)
    n, m = benefit.shape
    if n > m:
        raise ValueError(f"rect auction requires n <= m, got {benefit.shape}")

    if eps_min is None:
        eps_min = 1.0 / (n + 1)
    eps = jnp.asarray(eps_min, dtype=jnp.float32)  # single phase
    del warm  # warmth only changes init_prices on the rect path

    bid_round = _make_bid_round(benefit, m, _pick_top2(use_kernel))

    def cond(state):
        _, col_of, it = state
        return (~jnp.all(col_of >= 0)) & (it < max_iters)

    def body(state):
        prices, col_of, it = state
        prices, col_of = bid_round(prices, col_of, eps)
        return prices, col_of, it + 1

    p0 = (
        jnp.zeros((m,), jnp.float32)
        if init_prices is None
        else jnp.asarray(init_prices, jnp.float32)
    )
    init = (p0, jnp.full((n,), -1, jnp.int32), jnp.asarray(0, jnp.int32))
    prices, col_of, iters = jax.lax.while_loop(cond, body, init)
    converged = jnp.all(col_of >= 0)
    row_of = _inverse_assignment(col_of, m)
    return AuctionResult(col_of, row_of, prices, iters, converged)


def auction_lap_batched(
    benefits: jax.Array,
    max_iters: int = 20_000,
    eps_min: float | jax.Array | None = None,
    use_kernel: bool | None = None,
    init_prices: jax.Array | None = None,
    warm: jax.Array | None = None,
) -> AuctionResult:
    """vmap'd auction over a batch of (n, n) benefit matrices.

    This is the Algorithm-2 fan-out: all k_c^2 node-pair LAPs solve in one
    XLA program instead of k_c^2 sequential scipy calls.  Every result
    field gains a leading batch axis — in particular ``converged`` is
    per-instance, which the matching engine uses to re-solve stragglers
    with scipy.  ``init_prices`` (B, n) and ``warm`` (B,) thread last
    round's price state per instance (see :class:`engine.MatchContext`).
    With ``use_kernel`` the bid top-2 lowers to ONE batched Pallas call per
    round: ``vmap``'s pallas batching rule lifts the 2-D kernel by
    prepending a batch grid axis (equivalent to the explicit
    ``lap_bid_pallas_batched``, which parity tests pin against it).
    """
    if use_kernel is None:
        use_kernel = _auto_use_kernel(int(benefits.shape[-1]))
    return _auction_lap_batched_jit(
        benefits,
        eps_min,
        max_iters=max_iters,
        use_kernel=use_kernel,
        init_prices=init_prices,
        warm=warm,
    )


def _vmap_auction(
    solver, benefits, eps_min, max_iters, use_kernel, init_prices, warm
) -> AuctionResult:
    """Shared vmap dispatch for the square and rectangular batched solvers
    (with / without per-instance warm-start state)."""
    if init_prices is None:
        return jax.vmap(
            lambda b: solver(b, eps_min, max_iters=max_iters, use_kernel=use_kernel)
        )(benefits)
    if warm is None:
        warm = jnp.zeros(benefits.shape[0], bool)
    return jax.vmap(
        lambda b, p, w: solver(
            b,
            eps_min,
            max_iters=max_iters,
            use_kernel=use_kernel,
            init_prices=p,
            warm=w,
        )
    )(benefits, init_prices, warm)


@functools.partial(jax.jit, static_argnames=("max_iters", "use_kernel"))
def _auction_lap_batched_jit(
    benefits: jax.Array,
    eps_min=None,
    max_iters: int = 20_000,
    use_kernel: bool = False,
    init_prices: jax.Array | None = None,
    warm: jax.Array | None = None,
) -> AuctionResult:
    return _vmap_auction(
        _auction_lap_jit, benefits, eps_min, max_iters, use_kernel, init_prices, warm
    )


def auction_lap_rect_batched(
    benefits: jax.Array,
    max_iters: int = 20_000,
    eps_min: float | jax.Array | None = None,
    use_kernel: bool | None = None,
    init_prices: jax.Array | None = None,
    warm: jax.Array | None = None,
) -> AuctionResult:
    """vmap'd **rectangular** forward auction over (B, n, m) benefits,
    n <= m.  Bids range only over the m real columns — the padded-instance
    fix for skew packing graphs.  Same warm-start contract as
    :func:`auction_lap_batched`; ``init_prices`` is (B, m)."""
    if use_kernel is None:
        use_kernel = _auto_use_kernel(int(benefits.shape[-1]))
    return _auction_lap_rect_batched_jit(
        benefits,
        eps_min,
        max_iters=max_iters,
        use_kernel=use_kernel,
        init_prices=init_prices,
        warm=warm,
    )


@functools.partial(jax.jit, static_argnames=("max_iters", "use_kernel"))
def _auction_lap_rect_batched_jit(
    benefits: jax.Array,
    eps_min=None,
    max_iters: int = 20_000,
    use_kernel: bool = False,
    init_prices: jax.Array | None = None,
    warm: jax.Array | None = None,
) -> AuctionResult:
    return _vmap_auction(
        _auction_lap_rect_jit,
        benefits,
        eps_min,
        max_iters,
        use_kernel,
        init_prices,
        warm,
    )


def _pad_value(benefit: np.ndarray, finite: np.ndarray) -> np.ndarray:
    """PER-INSTANCE benefit value for padded / forbidden cells: strictly
    below anything a real edge can contribute through an augmenting cycle.
    Must scale with the instance SIZE, not just the value span: displacing
    a pad edge can rearrange every real edge of the assignment, and each
    rearranged edge can swing the total by up to 2*span (see
    masked_square_benefit).  Returns shape ``benefit.shape[:-2]`` — the
    reduction is over each instance alone, NOT the batch: a batch-global
    span would couple every instance's pad cells to whichever instance
    holds the batch max, so one instance arriving or departing would
    change the pad bit pattern of every survivor and silently defeat the
    engine's identity-keyed fingerprint memoisation for masked /
    forbidden-edge batches."""
    n, m = benefit.shape[-2], benefit.shape[-1]
    size = max(n, m)
    span = np.where(finite, np.abs(benefit), 0.0).max(axis=(-2, -1))
    return -(2.0 * size * span + 1.0)


def masked_square_benefit(
    cost: np.ndarray,
    maximize: bool = False,
    row_mask: np.ndarray | None = None,
    col_mask: np.ndarray | None = None,
) -> np.ndarray:
    """Embed (possibly rectangular / masked / forbidden-edge) cost instances
    into square benefit matrices the auction can solve.

    ``cost``: (..., n, m).  ``row_mask``/``col_mask``: (..., n) / (..., m)
    bool, True = real.  Non-finite entries are forbidden edges.

    Padding / forbidden cells get a constant benefit low enough that no
    optimal assignment ever trades a (real, real) pair for a padded one —
    i.e. *padding never wins*: the square optimum restricted to real rows
    x real cols is the rectangular optimum.  The pad must scale with the
    instance SIZE, not just the value span: displacing a pad edge can
    rearrange every real edge of the assignment (an augmenting cycle), and
    each rearranged edge can swing the total by up to 2*span — a constant
    pad of -(2*span+1) provably fails on mixed-sign costs (e.g. minimise
    [[2, inf], [-2, 2]]: the forbidden cell at -(2*span+1) beats the
    complete finite matching).  Callers drop pairs whose original entry is
    padded or non-finite.
    """
    cost = np.asarray(cost, dtype=np.float64)
    n, m = cost.shape[-2], cost.shape[-1]
    size = max(n, m)
    benefit = cost if maximize else -cost
    finite = np.isfinite(benefit)
    pad = _pad_value(benefit, finite)[..., None, None]  # per instance
    sq = np.broadcast_to(
        pad, (*cost.shape[:-2], size, size)
    ).astype(np.float64, copy=True)
    sq[..., :n, :m] = np.where(finite, benefit, pad)
    if row_mask is not None:
        rm = np.asarray(row_mask, bool)[..., :, None]  # (..., n, 1)
        sq[..., :n, :] = np.where(rm, sq[..., :n, :], pad)
    if col_mask is not None:
        cm = np.asarray(col_mask, bool)[..., None, :]  # (..., 1, m)
        sq[..., :, :m] = np.where(cm, sq[..., :, :m], pad)
    return sq


def masked_rect_benefit(
    cost: np.ndarray,
    maximize: bool = False,
    row_mask: np.ndarray | None = None,
    col_mask: np.ndarray | None = None,
) -> np.ndarray:
    """Rectangular counterpart of :func:`masked_square_benefit`: same pad
    rule (masked rows/cols and forbidden edges become a size-scaled
    constant strictly below every real benefit), but the (..., n, m) shape
    is preserved — no ``max(n, m)^2`` square embedding is ever allocated.
    Callers drop pairs whose original entry is padded or non-finite, and
    orient the instance so bidders are the short side (n <= m)."""
    cost = np.asarray(cost, dtype=np.float64)
    benefit = np.where(np.isfinite(cost), cost if maximize else -cost, 0.0)
    finite = np.isfinite(cost)
    pad = _pad_value(benefit, finite)[..., None, None]  # per instance
    out = np.where(finite, benefit, pad)
    if row_mask is not None:
        out = np.where(np.asarray(row_mask, bool)[..., :, None], out, pad)
    if col_mask is not None:
        out = np.where(np.asarray(col_mask, bool)[..., None, :], out, pad)
    return out


def auction_assignment(
    cost: np.ndarray,
    maximize: bool = False,
    row_mask: np.ndarray | None = None,
    col_mask: np.ndarray | None = None,
    use_kernel: bool | None = None,
):
    """Numpy-friendly wrapper returning (row_ind, col_ind) like scipy.

    Handles rectangular instances, ``row_mask``/``col_mask`` padding, and
    non-finite (forbidden) entries via the square embedding of
    :func:`masked_square_benefit`; pairs landing on padded / forbidden
    cells are dropped from the returned assignment.
    """
    cost = np.asarray(cost, dtype=np.float64)
    n, m = cost.shape
    sq = masked_square_benefit(cost, maximize, row_mask, col_mask)
    res = auction_lap(jnp.asarray(sq), use_kernel=use_kernel)
    col_of = np.asarray(res.col_of)  # tessalint: sync-ok(single readout of the finished assignment; this wrapper's contract is scipy-style host output)
    row_ind = np.arange(sq.shape[0])
    ok = (row_ind < n) & (col_of < m) & (col_of >= 0)
    if row_mask is not None:
        ok &= np.asarray(row_mask, bool)[np.minimum(row_ind, n - 1)]
    if col_mask is not None:
        ok &= np.asarray(col_mask, bool)[np.minimum(col_of, m - 1)]
    row_ind, col_ind = row_ind[ok], col_of[ok]
    real = np.isfinite(cost[row_ind, col_ind])
    return row_ind[real], col_ind[real]
