"""Migration minimisation (§4.1): Algorithms 2, 3 and 5 + Gavel baseline.

Key idea (Fig. 1): two placement plans that *look* different may be
identical up to GPU renaming — so before physically moving any job, find
the GPU/node relabelling of the new plan that minimises the number of true
migrations.  With homogeneous GPUs this is exactly an assignment problem:

* **Algorithm 3** (node-level matching): for one node from round i and one
  node from round i+1, build the k_l x k_l cost matrix
  ``C[u, v] = sum_{j in JS_u symdiff JS_v} 1 / (2 * num_gpus(j))``
  (each move-in or move-out costs 0.5 per job, amortised over the job's
  GPUs) and solve it with the Hungarian algorithm.
* **Algorithm 2** (job migration): drop jobs not present in both rounds,
  run Algorithm 3 for every node pair to get a k_c x k_c node-level cost
  matrix, then a second Hungarian assignment picks which *physical* node
  hosts each node-worth of the new plan.  Matching at node granularity
  preserves consolidated placement (§4.3).
* **Algorithm 5** (appendix B): flat GPU-level matching over the whole
  cluster — cheaper (O(k^3)) but may break consolidation (Example 5).
* **Gavel baseline**: no relabelling at all; a job migrates whenever its
  logical GPU ids differ between rounds.  (The "basic migration algorithm"
  Tesserae improves on by 36%, Fig. 11.)

Semantic note (found by property testing, EXPERIMENTS.md): the Hungarian
objective minimises the paper's FRACTIONAL cost (each moved GPU of a job
costs 1/(2*num_gpus)), which equals the migration count only when jobs
move atomically.  A multi-GPU job moving PARTIALLY scores < 1 but still
counts as one migration under Definition 1, so on adversarial plans the
optimal-cost assignment can have a (slightly) higher integer count than
no-remap.  In end-to-end traces this never dominates: the simulator
measures 60% fewer migrations than the no-remap baseline (Fig. 11 repro).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import numpy as np

from repro.core.cluster import EMPTY, MAX_PACK, PlacementPlan, count_migrations
from repro.core.matching import MatchContext, solve_lap, solve_lap_batched
from repro.core.matching.engine import APPROX_BACKENDS


# --------------------------------------------------------------------------- #
# Cost-matrix construction
# --------------------------------------------------------------------------- #
def _weight_lookup(num_gpus_of: Dict[int, int]) -> np.ndarray:
    """Dense job-id -> 1/(2*num_gpus) lookup; index -1 (EMPTY) maps to 0."""
    max_id = max(num_gpus_of) if num_gpus_of else 0
    w = np.zeros(max_id + 2, dtype=np.float64)
    for j, g in num_gpus_of.items():
        w[j] = 1.0 / (2.0 * g)  # tessalint: mantissa-ok(f64 host reference path per Algorithm 3; the device path scales to the f32 budget in fused._cost_scale)
    # EMPTY == -1 indexes the last element, which stays 0.
    return w


def pairwise_migration_cost(
    slots_u: np.ndarray, slots_v: np.ndarray, weights: np.ndarray
) -> np.ndarray:
    """Cost matrix between two GPU lists (Algorithm 3 lines 2-7).

    ``slots_u``: (..., U, MAX_PACK) job ids, ``slots_v``: (..., V, MAX_PACK).
    Returns (..., U, V) with
    ``C[u, v] = sum_{j in set(u) symdiff set(v)} weights[j]``.

    This is the exact computation the Pallas ``migration_cost`` kernel
    performs on-device; see ``repro/kernels/migration_cost.py``.
    """
    su = slots_u[..., :, None, :, None]  # (..., U, 1, P, 1)
    sv = slots_v[..., None, :, None, :]  # (..., 1, V, 1, P)
    eq = su == sv  # (..., U, V, P, P)
    u_in_v = eq.any(axis=-1)  # (..., U, V, P): job a of u present in v
    v_in_u = eq.any(axis=-2)  # (..., U, V, P): job b of v present in u
    wu = weights[slots_u]  # EMPTY -> 0 via lookup tail
    wv = weights[slots_v]
    cost_out = (wu[..., :, None, :] * ~u_in_v).sum(axis=-1)
    cost_in = (wv[..., None, :, :] * ~v_in_u).sum(axis=-1)
    return cost_out + cost_in


#: Extra node-relabel cost for crossing a rack boundary: checkpoints must
#: transit the aggregation layer, so the relabelling only does it when it
#: saves at least one half-migration.  A multiple of 1/2 keeps the
#: auction's integer quantisation exact (the cost scale is always even).
CROSS_RACK_COST = 0.5

#: Straggler-drain weight: a fully-degraded node (speed 0) charges this many
#: matching-cost units PER NODE GPU for hosting an occupied logical row, so
#: draining a whole node's worth of jobs (~``gpus_per_node`` half-migrations
#: in and out) is worth it whenever the capacity loss exceeds the move.
#: Partial degradation scales linearly and is rounded to multiples of 1/2,
#: keeping the auction's integer quantisation exact (cost scale is even).
STRAGGLER_DRAIN_COST = 1.0


def _relabel_penalties(
    cluster,
    down_nodes: Optional[np.ndarray] = None,
    occupied_logical: Optional[np.ndarray] = None,
    speed_factor: Optional[np.ndarray] = None,
) -> Optional[np.ndarray]:
    """(kc, kc) additive node-relabel penalties for heterogeneous / racked
    / partially-down clusters: ``pen[k, l]`` is added to the cost of
    hosting logical node ``l`` on physical node ``k``.

    * GPU-type mismatch gets a penalty strictly larger than any achievable
      real matching cost (``2 * kl * kc`` bounds the total), making the
      relabelling TYPE-PRESERVING: a plan row laid out for an A100 node is
      never silently renamed onto a V100 node (which would invalidate every
      throughput belief behind the plan).  Always feasible — the identity
      relabelling is type-preserving by construction.
    * Crossing a rack boundary costs :data:`CROSS_RACK_COST`.
    * A DOWN physical node is zero capacity: hosting any *occupied*
      logical row on it costs twice the mismatch bound, strictly
      dominating every real-cost + mismatch + rack combination, so the
      optimum never lands jobs there (the identity relabelling is always
      feasible and cheaper — health-aware placement left down nodes'
      logical rows empty).  Empty logical rows relabel onto down nodes
      freely, which keeps the assignment square and feasible.
    * A DEGRADED physical node (``speed_factor[k] < 1``) charges a
      *finite* drain penalty proportional to its capacity loss
      (:data:`STRAGGLER_DRAIN_COST` units per node GPU at 100%
      degradation) for hosting any occupied logical row.  Unlike the
      down-node term this competes with real matching costs: the optimum
      drains jobs off stragglers exactly when spare healthy capacity
      exists and the move is cheaper than the penalty — a saturated
      cluster keeps running slow rather than thrash.

    Returns ``None`` for healthy homogeneous single-rack clusters — the
    seed path, where the node cost matrix is untouched (bit-for-bit).
    """
    hetero = cluster.is_heterogeneous
    racked = cluster.has_topology
    downs = (
        np.asarray([], dtype=np.int64)
        if down_nodes is None
        else np.asarray(sorted(int(n) for n in down_nodes), dtype=np.int64)
    )
    slow = None
    if speed_factor is not None:
        sf = np.asarray(speed_factor, dtype=np.float64)
        if (sf != 1.0).any():
            slow = sf
    if not hetero and not racked and len(downs) == 0 and slow is None:
        return None
    kc = cluster.num_nodes
    pen = np.zeros((kc, kc), dtype=np.float64)
    base = 2.0 * cluster.gpus_per_node * kc + 1.0
    if hetero:
        types = np.array(cluster.node_types())
        pen += base * (types[:, None] != types[None, :])
    if racked:
        racks = np.array([cluster.rack_of(i) for i in range(kc)])
        pen += CROSS_RACK_COST * (racks[:, None] != racks[None, :])
    if slow is not None:
        occ = (
            np.ones(kc, dtype=bool)
            if occupied_logical is None
            else np.asarray(occupied_logical, dtype=bool)
        )
        # round UP to half-units so every drain penalty stays on the
        # auction's integer grid after scaling (scale is always even)
        loss = np.clip(1.0 - slow, 0.0, 1.0)
        half_units = np.ceil(
            loss * 2.0 * STRAGGLER_DRAIN_COST * cluster.gpus_per_node
        )
        pen += (0.5 * half_units)[:, None] * occ[None, :]
    if len(downs):
        down_mask = np.zeros(kc, dtype=bool)
        down_mask[downs] = True
        occ = (
            np.ones(kc, dtype=bool)
            if occupied_logical is None
            else np.asarray(occupied_logical, dtype=bool)
        )
        pen += (2.0 * base) * (down_mask[:, None] & occ[None, :])
    return pen


def _cost_scale(num_gpus_of: Dict[int, int], backend: str) -> float:
    """Quantisation scale for the approximate (auction) backends.

    Migration costs are multiples of ``1/(2*num_gpus)``; multiplying by the
    lcm of the ``2*g`` values makes every cost an integer, for which the
    auction's final epsilon guarantees exact optimality.  Exact backends
    need no scaling.
    """
    if backend not in APPROX_BACKENDS:
        return 1.0
    gs = sorted(set(num_gpus_of.values())) or [1]
    return float(np.lcm.reduce([2 * g for g in gs]))


def node_level_matching(
    node_slots_i: np.ndarray,
    node_slots_j: np.ndarray,
    num_gpus_of: Dict[int, int],
    backend: str = "auto",
):
    """Algorithm 3 for a single node pair.

    Returns ``(cost_sum, gpu_assignment)`` where ``gpu_assignment[v] = u``:
    logical GPU v of the new plan lands on physical GPU u.
    """
    weights = _weight_lookup(num_gpus_of)
    cost = pairwise_migration_cost(node_slots_i, node_slots_j, weights)
    rows, cols = solve_lap(
        cost * _cost_scale(num_gpus_of, backend), backend=backend
    )
    assign = np.empty(cost.shape[0], dtype=np.int64)
    assign[cols] = rows
    return float(cost[rows, cols].sum()), assign


# --------------------------------------------------------------------------- #
# Full migration planning
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class MigrationResult:
    #: physical realisation of the new round's plan after relabelling.
    physical_plan: PlacementPlan
    #: number of true migrations (Definition 1) prev -> physical_plan.
    num_migrations: int
    #: total Hungarian matching cost (== migration count when jobs move
    #: atomically; fractional when jobs move partially).
    matching_cost: float
    #: node_assignment[l] = physical node hosting logical node l (node
    #: level only).
    node_assignment: Optional[np.ndarray]
    wall_time_s: float
    algorithm: str


def plan_migration(
    prev: PlacementPlan,
    new_logical: PlacementPlan,
    num_gpus_of: Dict[int, int],
    algorithm: str = "node",  # "node" (Alg 2+3) | "flat" (Alg 5) | "none"
    backend: str = "auto",
    context: Optional[MatchContext] = None,
    tie_break: bool = False,
    down_nodes: Optional[np.ndarray] = None,
    speed_factor: Optional[np.ndarray] = None,
) -> MigrationResult:
    """Compute the relabelling that minimises migrations, then apply it to
    the *full* new plan (jobs unique to one round are excluded from the cost
    computation — Algorithm 2 line 2 — but follow their logical GPU).

    ``backend`` is any engine backend (``auto`` / ``numpy`` / ``scipy`` /
    ``auction`` / ``auction_kernel``) — one knob selects the solver for
    both the node-pair fan-out and the final node-level match.
    ``context`` threads the scheduler's :class:`MatchContext` across
    rounds, keyed by IDENTITY: each fan-out instance is a (physical node,
    logical node) pair and its rows/columns are global GPU slots, the
    final match is keyed by node ids, and the flat algorithm by GPU ids.
    Node pairs whose cost rows did not change since the previous round
    memo-hit outright (they never occupy solver lanes — partial-batch
    compaction) and changed pairs warm-start from last round's auction
    prices; identity keying keeps all of that valid if the cluster itself
    is ever resized between rounds.

    On heterogeneous / racked clusters the node-level cost gains the
    :func:`_relabel_penalties` terms (type-preserving relabelling, rack
    locality); ``matching_cost`` then includes those penalties.
    ``tie_break`` threads the engine's canonical tie-break perturbation
    through every LAP so equally-optimal relabellings are
    solver-independent.  ``down_nodes`` marks failed physical nodes: the
    relabelling is penalised off them (see :func:`_relabel_penalties`),
    so no occupied logical row is ever renamed onto a dead node.
    ``speed_factor`` (per-physical-node, from ``ClusterHealth``) adds the
    finite straggler-drain term: degraded nodes are drained through the
    same matching objective whenever healthy spare capacity makes the
    move worthwhile.
    """
    t0 = time.perf_counter()
    cluster = prev.cluster
    occupied_logical = (new_logical.slots != EMPTY).any(axis=(1, 2))
    if algorithm == "none":
        phys = new_logical.copy()
        n_mig = count_migrations(prev, phys)
        return MigrationResult(
            phys, n_mig, float(n_mig), None, time.perf_counter() - t0, algorithm
        )

    common = prev.job_ids() & new_logical.job_ids()
    pi = prev.restricted_to(common)
    pj = new_logical.restricted_to(common)
    weights = _weight_lookup(num_gpus_of)

    if algorithm == "flat":
        flat_i = pi.slots.reshape(-1, MAX_PACK)
        flat_j = pj.slots.reshape(-1, MAX_PACK)
        cost = pairwise_migration_cost(flat_i, flat_j, weights)
        pen = _relabel_penalties(
            cluster, down_nodes, occupied_logical, speed_factor
        )
        if pen is not None:
            # expand node-level penalties to every (physical, logical) GPU
            # pair: each relabelled GPU's state crosses the boundary
            kl = cluster.gpus_per_node
            cost = cost + np.repeat(np.repeat(pen, kl, axis=0), kl, axis=1)
        gpu_ids = np.arange(cluster.num_gpus, dtype=np.int64)
        rows, cols = solve_lap(
            cost * _cost_scale(num_gpus_of, backend),
            backend=backend,
            context=context,
            context_key="migration_flat",
            row_ids=gpu_ids,
            col_ids=gpu_ids,
            tie_break=tie_break,
        )
        gpu_of_logical = np.empty(cluster.num_gpus, dtype=np.int64)
        gpu_of_logical[cols] = rows
        phys_slots = np.full_like(new_logical.slots, EMPTY)
        flat_new = new_logical.slots.reshape(-1, MAX_PACK)
        phys_flat = phys_slots.reshape(-1, MAX_PACK)
        for v in range(cluster.num_gpus):
            phys_flat[gpu_of_logical[v]] = flat_new[v]
        phys = PlacementPlan(cluster, phys_slots)
        n_mig = count_migrations(prev, phys)
        return MigrationResult(
            phys,
            n_mig,
            float(cost[rows, cols].sum()),
            None,
            time.perf_counter() - t0,
            algorithm,
        )

    if algorithm != "node":
        raise ValueError(f"unknown migration algorithm {algorithm!r}")

    # --- Algorithm 2: node-pair costs via vectorised Algorithm 3 --------- #
    # The k_c^2 independent k_l x k_l LAPs solve as ONE batched engine call;
    # the backend knob picks smallperm/scipy ("auto") or the JAX auction
    # ("auction"/"auction_kernel", quantised to integers so the final
    # epsilon guarantees per-instance optimality).
    kc = cluster.num_nodes
    kl = cluster.gpus_per_node
    # (kc, kc, kl, kl): cost matrix for every (node_i, node_j) pair.
    all_costs = pairwise_migration_cost(
        pi.slots[:, None, :, :], pj.slots[None, :, :, :], weights
    )
    scale = _cost_scale(num_gpus_of, backend)
    # identity keying: instance (i, j) is the (physical, logical) node
    # pair; its rows/cols are the GLOBAL GPU slots of those nodes.  Stable
    # across rounds (and across cluster resizes) by construction.
    node_ids = np.arange(kc, dtype=np.int64)
    pair_ids = (node_ids[:, None] * (1 << 20) + node_ids[None, :]).ravel()
    slot_ids = node_ids[:, None] * kl + np.arange(kl, dtype=np.int64)[None, :]
    res = solve_lap_batched(
        all_costs.reshape(kc * kc, kl, kl) * scale,
        backend=backend,
        context=context,
        context_key="migration_pairs",
        instance_ids=pair_ids,
        row_ids=np.repeat(slot_ids, kc, axis=0),
        col_ids=np.tile(slot_ids, (kc, 1)),
        tie_break=tie_break,
    )
    node_cost = (res.total_cost / scale).reshape(kc, kc)
    pen = _relabel_penalties(
        cluster, down_nodes, occupied_logical, speed_factor
    )
    if pen is not None:
        node_cost = node_cost + pen
    # res.col_of[b, u] = v  ->  gpu_assign[.., v] = u
    gpu_assign = np.argsort(res.col_of, axis=-1).reshape(kc, kc, kl)
    n_rows, n_cols = solve_lap(
        node_cost * scale,
        backend=backend,
        context=context,
        context_key="migration_node",
        row_ids=node_ids,
        col_ids=node_ids,
        tie_break=tie_break,
    )
    node_assignment = np.empty(kc, dtype=np.int64)
    node_assignment[n_cols] = n_rows  # logical node l -> physical node k

    phys_slots = np.full_like(new_logical.slots, EMPTY)
    for l in range(kc):
        k = node_assignment[l]
        for v in range(kl):
            u = gpu_assign[k, l, v]
            phys_slots[k, u] = new_logical.slots[l, v]
    phys = PlacementPlan(cluster, phys_slots)
    n_mig = count_migrations(prev, phys)
    return MigrationResult(
        phys,
        n_mig,
        float(node_cost[n_rows, n_cols].sum()),
        node_assignment,
        time.perf_counter() - t0,
        algorithm,
    )


def plan_migration_batched_auction(
    prev: PlacementPlan,
    new_logical: PlacementPlan,
    num_gpus_of: Dict[int, int],
    use_kernel: bool = False,
) -> MigrationResult:
    """Beyond-paper: Algorithm 2 with the k_c^2 node-pair LAPs solved as ONE
    batched JAX auction instead of k_c^2 sequential Hungarian calls.

    Now a thin wrapper over :func:`plan_migration` with the engine's
    ``auction`` backend (``auction_kernel`` routes the bid top-2 through
    the Pallas kernel).  Exactness: costs are multiples of
    ``1/(2*num_gpus)`` and are scaled to integers before solving, so the
    auction's final epsilon guarantees optimality per instance.
    """
    res = plan_migration(
        prev,
        new_logical,
        num_gpus_of,
        algorithm="node",
        backend="auction_kernel" if use_kernel else "auction",
    )
    return dataclasses.replace(res, algorithm="node-auction")
