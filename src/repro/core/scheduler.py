"""The Tesserae round scheduler (Listing 1 + Fig. 4).

One ``decide()`` call per scheduling round:

1. sort active jobs by the composed scheduling policy's priority,
2. place as many as possible WITHOUT packing, consolidated (Fig. 5),
3. if GPU sharing is enabled, pack pending jobs onto placed jobs via the
   max-weight bipartite matching of Algorithm 4,
4. compute the migration plan vs. the previous round's physical placement
   (Algorithms 2+3) and emit the physically-relabelled plan.

The per-stage wall times are recorded — they are the Fig. 14(b) overhead
breakdown and the Fig. 2 decision-time measurements.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.cluster import ClusterHealth, ClusterSpec, PlacementPlan
from repro.core.jobs import JobState
from repro.core.matching import MatchContext
from repro.core.migration import MigrationResult, plan_migration
from repro.core.packing import PackingResult, pack_jobs
from repro.core.placement import apply_packing, place_without_packing
from repro.core.policies.base import SchedulingPolicy
from repro.core.profiler import ThroughputProfile
from repro.obs.tracer import tracer_of


class DegradeReason:
    """Taxonomy of graceful-degradation steps a round can take (surfaced
    per round through :attr:`RoundDecision.degrade_reason` and aggregated
    into ``SimResult.degrade_rounds``).  The ladder, best to worst:

    ``none`` -> fused served the round -> [``fused-budget`` |
    ``fused-nonconverged``]: host planner served a fused round ->
    ``deadline-host``: the decide() watchdog demoted fused to the host
    planner before starting the migrate stage -> ``deadline-greedy``: the
    watchdog skipped relabelling entirely and emitted the greedy-feasible
    logical plan (``algorithm="none"``) — always valid, zero extra LAPs.
    """

    NONE = "none"
    FUSED_BUDGET = "fused-budget"
    FUSED_NONCONVERGED = "fused-nonconverged"
    DEADLINE_HOST = "deadline-host"
    DEADLINE_GREEDY = "deadline-greedy"

    ALL = (NONE, FUSED_BUDGET, FUSED_NONCONVERGED, DEADLINE_HOST, DEADLINE_GREEDY)


@dataclasses.dataclass
class RoundDecision:
    plan: PlacementPlan  # physical plan for the next round
    placed: List[JobState]
    pending: List[JobState]
    packing: PackingResult
    migration: Optional[MigrationResult]
    timings: Dict[str, float]
    #: this round's delta of the scheduler's MatchContext stats (memo /
    #: warm / cold instances, price invalidations, ...) — the per-round
    #: warm-hit telemetry the churn-replay CI gate and the simulator
    #: aggregate.
    match_stats: Dict[str, int] = dataclasses.field(default_factory=dict)
    #: which degradation-ladder step (if any) produced this round's plan.
    degrade_reason: str = DegradeReason.NONE

    @property
    def total_overhead_s(self) -> float:
        return sum(self.timings.values())

    @property
    def warm_hits(self) -> int:
        """Instances this round served from the identity-keyed context
        (memoised or price-warm) across all LAP families."""
        return int(self.match_stats.get("warm_instances", 0))


class TesseraeScheduler:
    """Placement policy engine composed with a pluggable scheduling policy."""

    def __init__(
        self,
        cluster: ClusterSpec,
        policy: SchedulingPolicy,
        profile: ThroughputProfile,
        enable_packing: bool = True,
        optimize_strategy: bool = True,
        migration_algorithm: str = "node",  # node | flat | none
        # matching-engine backend for packing + migration LAPs:
        # auto | numpy | scipy | auction | auction_kernel (one knob,
        # dispatched through repro.core.matching.solve_lap[_batched])
        lap_backend: str = "auto",
        packed_ok: Optional[Callable[[JobState, JobState], bool]] = None,
        match_context: Optional[MatchContext] = None,
        # canonical tie-break perturbation on every LAP, so equally-optimal
        # packings/relabellings are solver-independent (bit-for-bit
        # differential testing across backends); off by default — the seed
        # placements are preserved exactly.
        tie_break: bool = False,
        # heterogeneous clusters: type-affinity placement key (sub-node
        # jobs to the slowest sufficient GPU type, gangs to the fastest
        # empty nodes).  No-op on homogeneous clusters.
        type_affinity: bool = True,
        # route the migrate stage through the fused device-resident
        # planner (repro.core.fused): one jitted program + one readout per
        # round, with the pair fan-out sharded over `fanout_shards`
        # devices.  Only meaningful with migration_algorithm == "node".
        fused_fanout: bool = False,
        fanout_shards: int = 1,
        # graceful-degradation ladder: wall-clock budget for one decide()
        # call.  When the elapsed time at the migrate stage exceeds half
        # the deadline, a fused round is demoted to the host planner; past
        # the full deadline the relabelling is skipped entirely and the
        # greedy-feasible logical plan ships as-is.  None (default)
        # disables the watchdog — the seed behaviour.
        decide_deadline_s: Optional[float] = None,
        # injectable clock for deterministic ladder tests.
        clock: Callable[[], float] = time.perf_counter,
        # failure-aware placement: fold ClusterHealth into the benefit
        # terms — degraded nodes gain the straggler-drain relabel penalty
        # (migration._relabel_penalties, host AND fused paths), and when
        # the observed outage process is hot (empirical per-node MTBF
        # below `spread_mtbf_h` hours) large gangs are spread across
        # failure domains (racks) in placement and prioritised by the
        # policy's spread hook.  Off by default — with the knob off, or
        # with all nodes healthy, decide() is bit-identical to the seed.
        health_aware: bool = False,
        spread_mtbf_h: float = 12.0,
        # opt-in observability bundle (repro.obs.Observability): structured
        # span tracing of the decide() pipeline.  None (default) routes
        # every instrumentation point through no-op singletons — the
        # decision sequence is bit-identical to the uninstrumented path.
        obs=None,
    ):
        self.cluster = cluster
        self.policy = policy
        self.profile = profile
        self.enable_packing = enable_packing
        self.optimize_strategy = optimize_strategy
        self.migration_algorithm = migration_algorithm
        self.lap_backend = lap_backend
        self.packed_ok = packed_ok
        self.tie_break = tie_break
        self.type_affinity = type_affinity
        self.fused_fanout = fused_fanout
        self.fanout_shards = fanout_shards
        self.decide_deadline_s = decide_deadline_s
        self._clock = clock
        self.health_aware = health_aware
        self.spread_mtbf_h = spread_mtbf_h
        self._fused_planner = None  # lazily built FusedMigrationPlanner
        #: identity-keyed warm-start state threaded across rounds: the
        #: packing matching (keyed by job ids), the Algorithm-2 node-pair
        #: fan-out (node-pair / GPU-slot ids) and the final node match
        #: (node ids) all keep their auction prices / memoised assignments
        #: here, so a round whose placements barely moved (the common
        #: case, Fig. 2) re-solves only what actually changed — including
        #: under churn, where jobs arriving/finishing change the packing
        #: graph's SHAPE but not the surviving identities.
        self.match_context = match_context if match_context is not None else MatchContext()
        self.obs = None
        if obs is not None:
            self.set_observability(obs)

    def set_observability(self, obs) -> None:
        """Attach (or detach, with ``None``) an observability bundle to the
        scheduler AND its matching context / fused planner, so LAP-solve
        and fused-round spans nest under this scheduler's decide spans."""
        self.obs = obs
        self.match_context.obs = obs
        if self._fused_planner is not None:
            self._fused_planner.obs = obs

    def decide(
        self,
        active_jobs: Sequence[JobState],
        now: float,
        prev_plan: Optional[PlacementPlan] = None,
        num_gpus_of: Optional[Dict[int, int]] = None,
        health: Optional[ClusterHealth] = None,
    ) -> RoundDecision:
        tracer = tracer_of(self.obs)
        with tracer.span("decide", jobs=len(active_jobs)) as sp:
            decision = self._decide_impl(
                active_jobs, now, prev_plan, num_gpus_of, health, tracer
            )
            sp.annotate(
                placed=len(decision.placed),
                pending=len(decision.pending),
                degrade=decision.degrade_reason,
                warm_instances=decision.warm_hits,
            )
        return decision

    def _decide_impl(
        self,
        active_jobs: Sequence[JobState],
        now: float,
        prev_plan: Optional[PlacementPlan],
        num_gpus_of: Optional[Dict[int, int]],
        health: Optional[ClusterHealth],
        tracer,
    ) -> RoundDecision:
        timings: Dict[str, float] = {}
        stats_before = dict(self.match_context.stats)
        degrade = DegradeReason.NONE
        # down nodes are ZERO capacity everywhere below; None (all up, or
        # no health tracking) keeps every stage on the seed code path
        down: Optional[np.ndarray] = None
        if health is not None and not health.all_up:
            down = health.down_nodes()
        # failure-aware terms (all None/False unless the knob is on AND the
        # health object carries real signal — the seed path is untouched):
        # `speed` feeds the straggler-drain relabel penalty, `spread`
        # switches gang placement to breadth-first across racks, and the
        # policy's spread hook (if it has one) boosts large gangs so the
        # spread actually gets first pick of the empty nodes.
        speed: Optional[np.ndarray] = None
        spread = False
        if self.health_aware and health is not None:
            if health.degraded:
                speed = health.speed_factor
            hot = health.hazard_hot(now, self.spread_mtbf_h * 3600.0)
            spread = hot and self.cluster.has_topology
            if hasattr(self.policy, "set_spread_hot"):
                self.policy.set_spread_hot(hot)

        t_start = self._clock()
        t0 = time.perf_counter()
        with tracer.span("policy_sort", policy=type(self.policy).__name__):
            ordered = self.policy.order(active_jobs, now, self.cluster)
        timings["schedule_s"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        with tracer.span("place", spread=spread) as sp_place:
            plan, placed, pending = place_without_packing(
                self.cluster,
                ordered,
                type_affinity=self.type_affinity,
                down_nodes=down,
                spread_domains=spread,
            )
            sp_place.annotate(placed=len(placed), pending=len(pending))
        timings["place_s"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        with tracer.span("pack", enabled=self.enable_packing) as sp_pack:
            if self.enable_packing:
                placed_types = None
                if self.cluster.node_gpu_types is not None and placed:
                    # heterogeneous cluster: each placed job's packing
                    # weights (incl. HBM feasibility) are profiled on its
                    # node's type
                    gmap_placed = plan.job_gpu_map()
                    placed_types = [
                        self.cluster.gpu_type_of(
                            self.cluster.node_of(min(gmap_placed[j.job_id]))
                        )
                        for j in placed
                    ]
                packing = pack_jobs(
                    placed,
                    pending,
                    self.profile,
                    optimize_strategy=self.optimize_strategy,
                    backend=self.lap_backend,
                    packed_ok=self.packed_ok,
                    context=self.match_context,
                    placed_gpu_types=placed_types,
                    tie_break=self.tie_break,
                )
                if packing.matches:
                    placed_lookup = {j.job_id: j for j in placed}
                    plan = apply_packing(plan, packing.matches, placed_lookup)
            else:
                packing = PackingResult({}, {}, 0.0, 0.0, 0)
            sp_pack.annotate(matches=len(packing.matches))
        timings["pack_s"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        migration: Optional[MigrationResult] = None
        fused_before: Dict[str, int] = {}
        if prev_plan is not None:
            gmap: Dict[int, int] = dict(num_gpus_of or {})
            for j in active_jobs:
                gmap.setdefault(j.job_id, j.num_gpus)
            # --- degradation-ladder watchdog (wall clock, injectable) ---- #
            deadline = self.decide_deadline_s
            elapsed = self._clock() - t_start if deadline is not None else 0.0
            algorithm = self.migration_algorithm
            use_fused = self.fused_fanout and algorithm == "node"
            if deadline is not None and elapsed >= deadline:
                # past the full budget: skip relabelling, ship the
                # greedy-feasible logical plan (already avoids down nodes)
                algorithm = "none"
                use_fused = False
                degrade = DegradeReason.DEADLINE_GREEDY
            elif deadline is not None and elapsed >= 0.5 * deadline and use_fused:
                # half the budget gone: demote fused to the host planner
                use_fused = False
                degrade = DegradeReason.DEADLINE_HOST
            if use_fused:
                if self._fused_planner is None:
                    from repro.core.fused import FusedMigrationPlanner

                    self._fused_planner = FusedMigrationPlanner(
                        shards=self.fanout_shards, obs=self.obs
                    )
                fused_before = dict(self._fused_planner.stats)
                migration = self._fused_planner.plan(
                    prev_plan,
                    plan,
                    gmap,
                    tie_break=self.tie_break,
                    down_nodes=down,
                    speed_factor=speed,
                )
                if self._fused_planner.last_fallback_reason is not None:
                    degrade = self._fused_planner.last_fallback_reason
            else:
                with tracer.span("migrate.host", algorithm=algorithm) as sp_mig:
                    migration = plan_migration(
                        prev_plan,
                        plan,
                        gmap,
                        algorithm=algorithm,
                        backend=self.lap_backend,
                        context=self.match_context,
                        tie_break=self.tie_break,
                        down_nodes=down,
                        speed_factor=speed,
                    )
                    sp_mig.annotate(migrations=migration.num_migrations)
            plan = migration.physical_plan
        timings["migrate_s"] = time.perf_counter() - t0

        match_stats = {
            k: v - stats_before.get(k, 0)
            for k, v in self.match_context.stats.items()
            if v != stats_before.get(k, 0)
        }
        if self._fused_planner is not None:
            # the fused planner's per-round telemetry rides the same dict
            # the simulator already aggregates (its readout count is the
            # migrate stage's entire host-sync budget for the round)
            for k, v in self._fused_planner.stats.items():
                d = v - fused_before.get(k, 0)
                if d:
                    match_stats[k] = match_stats.get(k, 0) + d
        return RoundDecision(
            plan,
            placed,
            pending,
            packing,
            migration,
            timings,
            match_stats,
            degrade_reason=degrade,
        )

    def invalidate_node(self, node: int) -> int:
        """TARGETED warm-state invalidation for one physical node (called
        by the simulator on node-down AND node-up events): every cached
        matching identity involving the node is poisoned — the Algorithm-2
        fan-out pairs touching it, the single-instance node match and flat
        families, and the fused planner's device-resident occupancy rows —
        while all other nodes' memo/warm state survives (the paper's
        temporal locality is exactly why a full reset would be wasteful).
        Returns the number of cached LAP instances invalidated.
        """
        kc = self.cluster.num_nodes
        ids = np.arange(kc, dtype=np.int64)
        # fan-out instance ids are i * 2^20 + j (migration.plan_migration)
        pair_ids = np.concatenate([node * (1 << 20) + ids, ids * (1 << 20) + node])
        count = self.match_context.invalidate_instances(
            np.unique(pair_ids), families=("migration_pairs",)
        )
        # the node match and the flat relabelling are single-instance
        # families (default instance id 0) — any node fault perturbs them
        count += self.match_context.invalidate_instances(
            [0], families=("migration_node", "migration_flat")
        )
        if self._fused_planner is not None:
            self._fused_planner.invalidate_nodes([node])
        return count

    def prewarm(
        self,
        active_jobs: Sequence[JobState],
        now: float,
        prev_plan: Optional[PlacementPlan] = None,
        num_gpus_of: Optional[Dict[int, int]] = None,
    ) -> None:
        """Speculatively run next round's decision pipeline to warm
        :attr:`match_context`.

        The result is discarded — only the side effect matters: the
        expected node-pair fan-out, final node match and packing LAPs are
        solved through the context NOW (in a real deployment, during the
        scheduler's idle time between rounds), so when ``decide`` runs for
        real with (mostly) the same inputs it memo-hits or warm-starts and
        its critical-path wall time collapses.  Speculation is always
        safe: a wrong guess only leaves non-matching fingerprints behind.
        """
        self.decide(active_jobs, now, prev_plan, num_gpus_of)


def tiresias_single_packed_ok(u: JobState, v: JobState) -> bool:
    """Tiresias (Single) baseline: only pack 1-GPU jobs (Lucid/Pollux rule —
    'at most one distributed job per node', so distributed jobs never
    share)."""
    return u.num_gpus == 1 and v.num_gpus == 1


# vectorised fast path used by build_packing_graph on large rounds
tiresias_single_packed_ok.vectorized_on_gpus = True
tiresias_single_packed_ok.gpu_mask = lambda gi, gj: (gi[:, None] == 1) & (
    gj[None, :] == 1
)
