"""One fused, sharded migration fan-out (the decide() hot path, on-device).

The host planner (:func:`repro.core.migration.plan_migration`, algorithm
``node``) runs Algorithm 2 as four host-orchestrated steps — cost
assembly, the k_c^2 pair-LAP fan-out, the node match, the scatter — with
a device readout between each.  This module compiles the whole migration
stage into ONE jitted XLA program with a SINGLE device→host readout per
round:

* **device-resident invalidation** — the planner caches last round's
  restricted slot matrices on device and diffs node occupancy there:
  one arrival/departure dirties only the pairs touching a changed
  physical or logical node (``dirty[i, j] = dirty_phys[i] |
  dirty_log[j]``).  Clean pairs re-enter the auction with their cached
  assignment and prices at ``eps_min`` — the ``lax.while_loop`` condition
  is immediately satisfied, so they cost ZERO bid rounds and never leave
  the device.
* **in-program benefit assembly** — pair costs are assembled from the
  slot matrices and the scaled ``1/(2g)`` weight table inside the same
  program (exact integers in f32 after the lcm scaling of
  ``migration._cost_scale``); with ``tie_break`` the positional
  perturbation ramp of ``engine._tie_break_perturb`` is added in-program
  (slot/node ids increase with position, so identity ranks equal
  positions — bit-identical to the host engine's identity-keyed ramp).
  With ``use_kernel`` the per-round bid top-2 routes through the fused
  Pallas kernel (:func:`repro.kernels.lap_bid.lap_bid_fused_pallas`),
  which assembles ``-cost + ramp - price`` inside its tiled VMEM sweep —
  the perturbed benefit never exists in HBM at all.
* **shard_map fan-out** — the pair axis is sharded across a device mesh
  (``fanout_shards``), each shard running its slice of the vmapped
  ``lax.while_loop`` auctions; the node match and the physical scatter
  run on the gathered results inside the same program.  Validated on CPU
  via ``--xla_force_host_platform_device_count`` (tests force 8).
* **auction via lax.while_loop** — both the pair fan-out and the node
  match reuse the Jacobi bid round of :mod:`repro.core.matching.auction`;
  warm rounds run a single phase at ``eps_min`` (valid for any initial
  prices on square instances), cold rounds the full epsilon schedule.

Exactness / parity contract: scaled costs are integers and the tie-break
scale a power of two, so while ``k_l * scale / tb_scale < 2^24`` every
assembled f32 value is exact and the fused plan is **bit-identical** to
the host path's (with ``tie_break`` the perturbed optimum is unique, so
every exact solver — scipy shadow, warm host auction, this program —
returns the same assignment).  Instances outside that budget, and rounds
whose auctions fail to converge, fall back to the host planner (counted
in :attr:`FusedMigrationPlanner.stats`).
"""

from __future__ import annotations

import functools
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.cluster import EMPTY, PlacementPlan, count_migrations
from repro.core.matching.auction import _inverse_assignment, _make_bid_round, _top2
from repro.core.migration import (
    MigrationResult,
    _cost_scale,
    _relabel_penalties,
    plan_migration,
)
from repro.obs.tracer import tracer_of

#: f32 mantissa budget: the largest scaled cost plus the finest tie-break
#: quantum must span fewer than 24 bits for the in-program f32 assembly to
#: be exact (see module docstring).
_F32_MANTISSA = float(1 << 24)


def _tb_scale(n: int, m: int) -> float:
    """Positional tie-break scale for an (n, m) integer-cost instance —
    the ``quantum = 1`` branch of ``engine._tie_break_perturb``."""
    bound = 2.0 * min(n, m) * float(n) * float(n) * float(m)
    return float(2.0 ** np.floor(np.log2(1.0 / bound)))


def _ramp(n: int, m: int, dtype=jnp.float32) -> jax.Array:
    """The (n, m) positional perturbation weights ``(i+1)^2 * (j+1)``."""
    gi = (jnp.arange(n, dtype=dtype) + 1.0)[:, None]
    gj = (jnp.arange(m, dtype=dtype) + 1.0)[None, :]
    return (gi * gi) * gj


def _pair_costs(pi_slots, pj_slots, weights_scaled):
    """All (kc, kc, kl, kl) scaled Algorithm-3 costs, in-program.

    Same computation as ``migration.pairwise_migration_cost`` over the
    full pair fan-out; EMPTY (-1) slots index a zero weight via an
    explicit remap (jnp clamps negative gather indices, so the host's
    negative-tail trick would silently read weight[0])."""
    zero_idx = weights_scaled.shape[0] - 1
    safe_i = jnp.where(pi_slots >= 0, pi_slots, zero_idx)
    safe_j = jnp.where(pj_slots >= 0, pj_slots, zero_idx)
    wu = weights_scaled[safe_i]  # (kc, kl, P)
    wv = weights_scaled[safe_j]
    eq = (
        pi_slots[:, None, :, None, :, None] == pj_slots[None, :, None, :, None, :]
    )  # (kc, kc, kl, kl, P, P)
    u_in_v = eq.any(-1)
    v_in_u = eq.any(-2)
    cost_out = (wu[:, None, :, None, :] * ~u_in_v).sum(-1)
    cost_in = (wv[None, :, None, :, :] * ~v_in_u).sum(-1)
    return cost_out + cost_in


def _pair_top2(use_kernel: bool, tb: float):
    """Bid top-2 over a raw COST matrix: jnp assembly (cheap on CPU) or
    the fused Pallas kernel (no HBM benefit matrix; same value order, so
    the two paths are bit-identical on in-budget integer instances)."""
    if use_kernel:
        from repro.kernels.lap_bid import lap_bid_fused_pallas

        return lambda cost, p: lap_bid_fused_pallas(cost, p, tb)
    return lambda cost, p: _top2((tb * _ramp(*cost.shape, cost.dtype) - cost) - p[None, :])


def _pair_auction(cost, eps_min, init_prices, init_col_of, warm, max_iters, use_kernel, tb):
    """One square Jacobi auction with explicit initial state, on a raw
    scaled COST matrix (benefit assembled in the bid's top-2 — see
    :func:`_pair_top2`).  The :func:`auction._auction_lap_jit` loop with
    an ``init_col_of``: a warm instance whose initial assignment is
    already complete terminates with ZERO bid rounds (the clean-pair
    fast path).  Returns ``(col_of, prices, iters, converged)``."""
    n = cost.shape[-1]
    eps_min = jnp.asarray(eps_min, jnp.float32)
    span = jnp.maximum(jnp.max(jnp.abs(cost)), 1.0)
    eps0 = jnp.where(warm, eps_min, jnp.maximum(span / 4.0, eps_min))
    bid_round = _make_bid_round(cost, n, _pair_top2(use_kernel, tb))

    def cond(state):
        _, col_of, eps, it = state
        done = jnp.all(col_of >= 0) & (eps <= eps_min * (1 + 1e-6))
        return (~done) & (it < max_iters)

    def body(state):
        prices, col_of, eps, it = state
        all_assigned = jnp.all(col_of >= 0)

        def next_phase(_):
            return prices, jnp.full((n,), -1, jnp.int32), jnp.maximum(eps / 5.0, eps_min)

        def same_phase(_):
            p, c = bid_round(prices, col_of, eps)
            return p, c, eps

        prices, col_of, eps = jax.lax.cond(
            all_assigned & (eps > eps_min * (1 + 1e-6)), next_phase, same_phase, None
        )
        return prices, col_of, eps, it + 1

    init = (init_prices, init_col_of, eps0, jnp.asarray(0, jnp.int32))
    prices, col_of, eps, iters = jax.lax.while_loop(cond, body, init)
    converged = jnp.all(col_of >= 0) & (eps <= eps_min * (1 + 1e-6))
    return col_of, prices, iters, converged


@functools.partial(
    jax.jit,
    static_argnames=("kc", "kl", "shards", "max_iters", "use_kernel", "tb_pair", "tb_node"),
)
def _fused_round(
    pi_slots,        # (kc, kl, P) int32 — restricted PREV (physical) plan
    pj_slots,        # (kc, kl, P) int32 — restricted NEW (logical) plan
    new_slots,       # (kc, kl, P) int32 — FULL new logical plan (scatter src)
    weights_scaled,  # (max_id + 2,) f32 — scale/(2g) per job id, zero tail
    pen_scaled,      # (kc, kc) f32 — scaled relabel penalties (zeros if none)
    cache_pi,        # (kc, kl, P) int32 — last round's pi_slots
    cache_pj,
    cache_col_of,    # (kc*kc, kl) int32 — last round's pair assignments
    cache_prices,    # (kc*kc, kl) f32 — last round's pair prices
    cache_node_prices,  # (kc,) f32
    cache_valid,     # () bool
    *,
    kc: int,
    kl: int,
    shards: int,
    max_iters: int,
    use_kernel: bool,
    tb_pair: float,  # 0.0 = tie-break off
    tb_node: float,
):
    """One fused migration round: diff → assemble → sharded pair fan-out →
    node match → physical scatter, all one XLA program.  Everything the
    host needs comes back in the single returned tuple (one readout)."""
    n_pairs = kc * kc
    eps_pair = (tb_pair if tb_pair > 0.0 else 1.0) / (kl + 1)
    eps_node = (tb_node if tb_node > 0.0 else 1.0) / (kc + 1)

    # --- per-node occupancy diff -> per-pair dirty mask ------------------ #
    dirty_i = jnp.any(pi_slots != cache_pi, axis=(1, 2)) | ~cache_valid
    dirty_j = jnp.any(pj_slots != cache_pj, axis=(1, 2)) | ~cache_valid
    dirty = (dirty_i[:, None] | dirty_j[None, :]).reshape(n_pairs)

    # --- in-program cost assembly (exact integers in f32) ---------------- #
    cost_p = _pair_costs(pi_slots, pj_slots, weights_scaled).reshape(n_pairs, kl, kl)

    # clean pairs re-enter at their cached optimum (zero bid rounds);
    # dirty pairs warm-start from cached prices when the cache is live
    arange_kl = jnp.arange(kl, dtype=jnp.int32)
    init_col = jnp.where(dirty[:, None], -1, cache_col_of)
    init_prices = jnp.where(cache_valid, cache_prices, jnp.zeros_like(cache_prices))
    warm = ~dirty | cache_valid  # clean: eps_min re-entry; dirty+cache: warm lane

    # --- sharded pair fan-out -------------------------------------------- #
    pad = (-n_pairs) % shards
    if pad:
        # dummy clean pairs: identity assignment, zero prices, zero cost —
        # the while_loop exits immediately; results are sliced off below
        cost_p = jnp.concatenate([cost_p, jnp.zeros((pad, kl, kl), cost_p.dtype)])
        init_col = jnp.concatenate(
            [init_col, jnp.broadcast_to(arange_kl, (pad, kl))]
        )
        init_prices = jnp.concatenate([init_prices, jnp.zeros((pad, kl), jnp.float32)])
        warm = jnp.concatenate([warm, jnp.ones((pad,), bool)])

    def solve_shard(cost_s, col_s, price_s, warm_s):
        return jax.vmap(
            lambda c, ic, ip, w: _pair_auction(
                c, eps_pair, ip, ic, w, max_iters, use_kernel, tb_pair
            )
        )(cost_s, col_s, price_s, warm_s)

    if shards > 1:
        mesh = Mesh(np.array(jax.devices()[:shards]), ("pairs",))
        solve_shard = shard_map(
            solve_shard,
            mesh=mesh,
            in_specs=(P("pairs"), P("pairs"), P("pairs"), P("pairs")),
            out_specs=(P("pairs"), P("pairs"), P("pairs"), P("pairs")),
            check_rep=False,
        )
    col_of, prices, iters, conv = solve_shard(cost_p, init_col, init_prices, warm)
    if pad:
        col_of, prices, iters, conv = (
            col_of[:n_pairs],
            prices[:n_pairs],
            iters[:n_pairs],
            conv[:n_pairs],
        )
        cost_p = cost_p[:n_pairs]

    # --- node match over pair totals ------------------------------------- #
    picked = jnp.take_along_axis(cost_p, col_of[:, :, None], axis=2)
    total_scaled = picked[:, :, 0].sum(axis=1)  # (n_pairs,)
    node_cost = total_scaled.reshape(kc, kc) + pen_scaled
    node_col, node_prices, node_iters, node_conv = _pair_auction(
        node_cost,
        eps_node,
        jnp.where(cache_valid, cache_node_prices, jnp.zeros_like(cache_node_prices)),
        jnp.full((kc,), -1, jnp.int32),
        cache_valid,
        max_iters,
        False,  # node instance: plain jnp assembly (one LAP, no fan-out win)
        tb_node,
    )

    # --- physical scatter (argsort == host gpu_assign, inverse == host
    # node_assignment[n_cols] = n_rows) ----------------------------------- #
    node_assignment = _inverse_assignment(node_col, kc)  # logical l -> physical k
    gpu_assign = jnp.argsort(col_of, axis=-1).astype(jnp.int32)  # (n_pairs, kl) v -> u
    pair_idx = node_assignment * kc + jnp.arange(kc, dtype=jnp.int32)
    u_of_v = gpu_assign[pair_idx]  # (kc_logical, kl)
    phys = jnp.full((kc, kl, new_slots.shape[-1]), EMPTY, new_slots.dtype)
    phys = phys.at[node_assignment[:, None], u_of_v].set(new_slots)

    matching_cost_scaled = jnp.sum(
        jnp.take_along_axis(node_cost, jnp.maximum(node_col, 0)[:, None], axis=1)[:, 0]
    )
    converged = jnp.all(conv) & node_conv
    stats = jnp.stack(
        [iters.sum(), node_iters, dirty.sum().astype(jnp.int32)]
    )
    return (
        phys,
        node_assignment,
        matching_cost_scaled,
        converged,
        stats,
        col_of,
        prices,
        node_prices,
        pi_slots,
        pj_slots,
    )


class FusedMigrationPlanner:
    """Device-resident Algorithm-2 planner: one jitted, sharded program and
    one readout per round (see module docstring).

    Drop-in for the scheduler's migrate stage (``fused_fanout=True``):
    :meth:`plan` has the :func:`~repro.core.migration.plan_migration`
    contract for ``algorithm="node"`` and returns the same
    :class:`MigrationResult` (``algorithm="node-fused"``).  Rounds the
    fused program cannot serve exactly — f32 mantissa budget exceeded, or
    an auction hitting ``max_iters`` — fall back to the host planner and
    invalidate the device cache; both are counted in :attr:`stats`.
    """

    def __init__(
        self,
        shards: int = 1,
        use_kernel: bool = False,
        max_iters: int = 20_000,
        obs=None,
    ):
        self.shards = max(1, min(int(shards), len(jax.devices())))
        self.use_kernel = bool(use_kernel)
        self.max_iters = int(max_iters)
        #: opt-in observability bundle — spans around the fused program,
        #: its single readout, and host fallbacks.  Pure host-side
        #: bookkeeping: no extra device work, no decision inputs touched.
        self.obs = obs
        self._cache = None  # device arrays: pi, pj, col_of, prices, node_prices
        self._cache_key = None  # (kc, kl, P, scale, tie_break)
        #: why the most recent :meth:`plan` call fell back to the host
        #: planner (``"fused-budget"`` / ``"fused-nonconverged"``), or
        #: ``None`` when it was served fused.  The scheduler folds this
        #: into the round's ``DegradeReason``.
        self.last_fallback_reason: Optional[str] = None
        self.stats: Dict[str, int] = {
            "fused_rounds": 0,
            "fused_host_fallbacks": 0,
            "fused_budget_fallbacks": 0,
            "fused_nonconverged_fallbacks": 0,
            "fused_dirty_pairs": 0,
            "fused_pair_instances": 0,
            "fused_bid_iters": 0,
            "fused_readouts": 0,
        }

    def invalidate(self) -> None:
        self._cache = None
        self._cache_key = None

    def invalidate_nodes(self, nodes) -> None:
        """TARGETED invalidation: poison only the cached occupancy rows of
        the given physical/logical nodes (node-down / node-up events), so
        next round's in-program diff marks exactly the pairs touching them
        dirty while every healthy pair stays clean (zero bid rounds).  The
        poison value ``-2`` can never equal a real slot id (ids are >= -1),
        so the dirty bit is guaranteed to trip even if the node's occupancy
        is coincidentally unchanged."""
        if self._cache is None:
            return
        idx = np.asarray(sorted(int(n) for n in nodes), dtype=np.int32)
        if idx.size == 0:
            return
        pi, pj, col_of, prices, node_prices = self._cache
        poison = jnp.full((idx.size,) + tuple(pi.shape[1:]), -2, pi.dtype)
        pi = pi.at[idx].set(poison)
        pj = pj.at[idx].set(poison)
        self._cache = (pi, pj, col_of, prices, node_prices)

    def plan(
        self,
        prev: PlacementPlan,
        new_logical: PlacementPlan,
        num_gpus_of: Dict[int, int],
        tie_break: bool = False,
        down_nodes: Optional[np.ndarray] = None,
        speed_factor: Optional[np.ndarray] = None,
    ) -> MigrationResult:
        tracer = tracer_of(self.obs)
        with tracer.span(
            "migrate.fused", shards=self.shards, kernel=self.use_kernel
        ) as sp:
            before = dict(self.stats)
            res = self._plan_impl(
                prev, new_logical, num_gpus_of, tie_break, down_nodes,
                speed_factor, tracer,
            )
            sp.annotate(
                fallback=self.last_fallback_reason or "none",
                dirty_pairs=self.stats["fused_dirty_pairs"]
                - before["fused_dirty_pairs"],
                bid_iters=self.stats["fused_bid_iters"]
                - before["fused_bid_iters"],
                readouts=self.stats["fused_readouts"]
                - before["fused_readouts"],
                migrations=res.num_migrations,
            )
        return res

    def _plan_impl(
        self,
        prev: PlacementPlan,
        new_logical: PlacementPlan,
        num_gpus_of: Dict[int, int],
        tie_break: bool,
        down_nodes: Optional[np.ndarray],
        speed_factor: Optional[np.ndarray],
        tracer,
    ) -> MigrationResult:
        t0 = time.perf_counter()
        self.last_fallback_reason = None
        cluster = prev.cluster
        kc, kl = cluster.num_nodes, cluster.gpus_per_node
        pmax = prev.slots.shape[-1]
        scale = _cost_scale(num_gpus_of, "auction")
        tb_pair = _tb_scale(kl, kl) if tie_break else 0.0
        tb_node = _tb_scale(kc, kc) if tie_break else 0.0

        # Health terms enter the fused program EXACTLY as the host planner
        # computes them: the same _relabel_penalties matrix (down-node
        # domination, straggler-drain half-units, type/rack terms) is
        # scaled and added to the in-program node cost, and its magnitude
        # counts against the same f32 mantissa budget below — so fused
        # plans with health terms on stay bit-identical to the host path.
        occupied_logical = (new_logical.slots != EMPTY).any(axis=(1, 2))
        pen = _relabel_penalties(
            cluster, down_nodes, occupied_logical, speed_factor
        )
        pen_max = 0.0 if pen is None else float(pen.max())

        # f32 exactness budget: the largest scaled node-cost magnitude
        # (each pair cell is <= 2 * MAX_PACK * 1/2 * scale, a pair total
        # sums kl cells, plus the relabel penalty) against the finest
        # tie-break quantum.  Outside the budget the fused program could
        # mis-round — serve the round from the host instead.
        quantum = min(tb_pair or 1.0, tb_node or 1.0)
        max_abs = (2.0 * pmax * kl + pen_max) * scale
        if max_abs / quantum >= _F32_MANTISSA:
            self.stats["fused_host_fallbacks"] += 1
            self.stats["fused_budget_fallbacks"] += 1
            self.last_fallback_reason = "fused-budget"
            self.invalidate()
            with tracer.span("migrate.fused.host_fallback", reason="fused-budget"):
                return self._host(
                    prev, new_logical, num_gpus_of, tie_break, down_nodes,
                    speed_factor,
                )

        common = prev.job_ids() & new_logical.job_ids()
        pi = prev.restricted_to(common).slots.astype(np.int32)
        pj = new_logical.restricted_to(common).slots.astype(np.int32)

        max_id = max(num_gpus_of) if num_gpus_of else 0
        weights = np.zeros(max_id + 2, np.float32)
        for j, g in num_gpus_of.items():
            weights[j] = scale / (2.0 * g)  # tessalint: mantissa-ok(exact for power-of-two gpu counts; the _F32_MANTISSA budget guard above falls back to host otherwise)
        pen_scaled = (
            np.zeros((kc, kc), np.float32)
            if pen is None
            else (pen * scale).astype(np.float32)
        )

        # NOT keyed on max_id: the weights table regrows as job ids climb,
        # but a clean pair's slots pin the exact same ids (and per-id
        # num_gpus is immutable), so its cached cost/assignment stays valid
        key = (kc, kl, pmax, scale, tie_break)
        if self._cache_key != key:
            self.invalidate()
        if self._cache is None:
            cache = (
                jnp.zeros((kc, kl, pmax), jnp.int32),
                jnp.zeros((kc, kl, pmax), jnp.int32),
                jnp.broadcast_to(jnp.arange(kl, dtype=jnp.int32), (kc * kc, kl)),
                jnp.zeros((kc * kc, kl), jnp.float32),
                jnp.zeros((kc,), jnp.float32),
                jnp.asarray(False),
            )
        else:
            cache = (*self._cache, jnp.asarray(True))

        with tracer.span("migrate.fused.program", kc=kc, kl=kl):
            out = _fused_round(
                jnp.asarray(pi),
                jnp.asarray(pj),
                jnp.asarray(new_logical.slots.astype(np.int32)),
                jnp.asarray(weights),
                jnp.asarray(pen_scaled),
                *cache,
                kc=kc,
                kl=kl,
                shards=self.shards,
                max_iters=self.max_iters,
                use_kernel=self.use_kernel,
                tb_pair=tb_pair,
                tb_node=tb_node,
            )
        # THE readout: everything host-side comes off the device here, once
        phys_dev, node_assign_dev, cost_dev, conv_dev, stats_dev = out[:5]
        with tracer.span("migrate.fused.readout"):
            phys, node_assignment, cost_scaled, converged, stats = jax.device_get(  # tessalint: sync-ok(THE one sanctioned readout per fused round; see BENCH_fused_decide.json)
                (phys_dev, node_assign_dev, cost_dev, conv_dev, stats_dev)
            )
        self.stats["fused_readouts"] += 1

        if not bool(converged):
            self.stats["fused_host_fallbacks"] += 1
            self.stats["fused_nonconverged_fallbacks"] += 1
            self.last_fallback_reason = "fused-nonconverged"
            self.invalidate()
            with tracer.span(
                "migrate.fused.host_fallback", reason="fused-nonconverged"
            ):
                return self._host(
                    prev, new_logical, num_gpus_of, tie_break, down_nodes,
                    speed_factor,
                )

        # cache stays device-resident for next round's diff / warm start
        self._cache = (out[8], out[9], out[5], out[6], out[7])
        self._cache_key = key
        self.stats["fused_rounds"] += 1
        self.stats["fused_pair_instances"] += kc * kc
        self.stats["fused_dirty_pairs"] += int(stats[2])
        self.stats["fused_bid_iters"] += int(stats[0]) + int(stats[1])

        phys_plan = PlacementPlan(cluster, np.asarray(phys, np.int64))
        n_mig = count_migrations(prev, phys_plan)
        return MigrationResult(
            phys_plan,
            n_mig,
            float(cost_scaled) / scale,
            np.asarray(node_assignment, np.int64),
            time.perf_counter() - t0,
            "node-fused",
        )

    def _host(
        self,
        prev,
        new_logical,
        num_gpus_of,
        tie_break,
        down_nodes=None,
        speed_factor=None,
    ) -> MigrationResult:
        res = plan_migration(
            prev,
            new_logical,
            num_gpus_of,
            algorithm="node",
            backend="auto",
            tie_break=tie_break,
            down_nodes=down_nodes,
            speed_factor=speed_factor,
        )
        return MigrationResult(
            res.physical_plan,
            res.num_migrations,
            res.matching_cost,
            res.node_assignment,
            res.wall_time_s,
            "node-fused-fallback",
        )
