"""Packing as maximum-weight bipartite matching (§4.2, Algorithm 4).

Build G = (V1, V2, E): V1 = placed_jobs, V2 = pending_jobs, an edge (u, v)
iff the two jobs request the same number of GPUs (so v can overlay u's
GPUs), weight = profiled combined normalised throughput — maximised over
job u's parallelism-strategy candidates when enabled (Fig. 7b).

Solving the matching (Hungarian / auction) yields at most one pending job
per placed job, maximising total cluster throughput.  Jobs flagged
non-packable (strict deadline / priority, §4.3 "Fairness") get no edges.

Implementation note: we embed the bipartite graph in a rectangular benefit
matrix with 0 for missing edges; a zero-weight "match" is interpreted as
*no packing* (packing with combined weight 0 is never beneficial since any
positive weight adds throughput for a job that would otherwise idle in the
queue).  The matrix is typically very skew (|placed| >> |pending| on a
busy cluster); the engine's rectangular path solves it without the
``max(n, m)^2`` square embedding, and a :class:`MatchContext` carried by
the scheduler warm-starts / memoises consecutive rounds whose graph barely
changed.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.jobs import JobState
from repro.core.matching import MatchContext, solve_lap_batched
from repro.core.profiler import ThroughputProfile


@dataclasses.dataclass
class PackingResult:
    #: pending job id -> placed job id
    matches: Dict[int, int]
    #: placed job id -> chosen parallelism strategy (LLM jobs whose strategy
    #: the matcher re-optimised to lift the edge weight)
    strategies: Dict[int, str]
    total_weight: float
    wall_time_s: float
    num_edges: int


def build_packing_graph(
    placed: Sequence[JobState],
    pending: Sequence[JobState],
    profile: ThroughputProfile,
    optimize_strategy: bool = True,
    packed_ok=None,
    placed_gpu_types: Optional[Sequence[str]] = None,
) -> np.ndarray:
    """Benefit matrix (|placed| x |pending|), fully vectorised.

    The per-MODEL-pair weight is memoised in the profile; the per-JOB-pair
    matrix is assembled with numpy indexing (the O(n^2) loop in pure Python
    was the scalability bottleneck — see EXPERIMENTS.md §Perf, scheduler
    iteration 1).

    ``placed_gpu_types`` (heterogeneous clusters) gives the GPU type of
    the node each PLACED job occupies; the edge weight — including memory
    feasibility, the thing that actually flips on 16 GB parts — is then
    profiled per type via :meth:`ThroughputProfile.for_gpu_type`.  ``None``
    (the default, and every homogeneous caller) is the seed path."""
    p, q = len(placed), len(pending)
    if p == 0 or q == 0:
        return np.zeros((p, q), dtype=np.float64)

    models = sorted({u.spec.model for u in placed} | {v.spec.model for v in pending})
    midx = {m: i for i, m in enumerate(models)}
    n_m = len(models)
    if placed_gpu_types is None:
        pairw = np.zeros((n_m, n_m), dtype=np.float64)
        for a in models:
            for b in models:
                pairw[midx[a], midx[b]] = profile.combined_weight(
                    a, b, optimize_strategy=optimize_strategy
                )[0]
        mp = np.array([midx[u.spec.model] for u in placed])
    else:
        # one weight table per GPU type present among the placed jobs; the
        # placed row then indexes (its node's type, its model)
        types = sorted(set(placed_gpu_types))
        tidx = {t: k for k, t in enumerate(types)}
        pairw = np.zeros((len(types), n_m, n_m), dtype=np.float64)
        for t in types:
            prof_t = profile.for_gpu_type(t)
            for a in models:
                for b in models:
                    pairw[tidx[t], midx[a], midx[b]] = prof_t.combined_weight(
                        a, b, optimize_strategy=optimize_strategy
                    )[0]
        mp = np.array(
            [
                tidx[t] * n_m + midx[u.spec.model]
                for u, t in zip(placed, placed_gpu_types)
            ]
        )
        pairw = pairw.reshape(len(types) * n_m, n_m)
    mq = np.array([midx[v.spec.model] for v in pending])
    gi = np.array([u.num_gpus for u in placed])
    gj = np.array([v.num_gpus for v in pending])
    ok_p = np.array(
        [u.spec.packable and u.packed_with is None for u in placed], dtype=bool
    )
    ok_q = np.array([v.spec.packable for v in pending], dtype=bool)

    mask = (gi[:, None] == gj[None, :]) & ok_p[:, None] & ok_q[None, :]
    if packed_ok is not None:
        if getattr(packed_ok, "vectorized_on_gpus", False):
            mask &= packed_ok.gpu_mask(gi, gj)
        else:
            ii, jj = np.nonzero(mask)
            for i, j in zip(ii, jj):
                if not packed_ok(placed[i], pending[j]):
                    mask[i, j] = False
    return np.where(mask, pairw[mp[:, None], mq[None, :]], 0.0)


def pack_jobs(
    placed: Sequence[JobState],
    pending: Sequence[JobState],
    profile: ThroughputProfile,
    optimize_strategy: bool = True,
    backend: str = "auto",
    packed_ok=None,
    context: Optional[MatchContext] = None,
    placed_gpu_types: Optional[Sequence[str]] = None,
    tie_break: bool = False,
) -> PackingResult:
    """Algorithm 4.

    ``backend`` is any matching-engine backend; the rectangular max-weight
    matching dispatches through
    :func:`repro.core.matching.solve_lap_batched`, so the same config knob
    that batches migration LAPs also selects the packing solver
    (``auction`` is near-optimal within ``n*eps`` on these float
    throughput weights; the default ``auto`` stays exact).  ``context``
    threads the scheduler's :class:`MatchContext`, keyed by JOB identity:
    rows are placed job ids and columns are pending job ids, so a graph
    that gains/loses a job (the dominant round-to-round event under churn)
    re-assembles last round's auction prices for the surviving jobs
    instead of cold-starting the whole matrix, and an unchanged graph
    memo-hits outright.
    """
    t0 = time.perf_counter()
    if not placed or not pending:
        return PackingResult({}, {}, 0.0, time.perf_counter() - t0, 0)
    w = build_packing_graph(
        placed, pending, profile, optimize_strategy, packed_ok, placed_gpu_types
    )
    num_edges = int((w > 0).sum())
    if num_edges == 0:
        return PackingResult({}, {}, 0.0, time.perf_counter() - t0, 0)
    rows, cols = solve_lap_batched(
        w[None],
        maximize=True,
        backend=backend,
        context=context,
        context_key="packing",
        instance_ids=np.zeros(1, np.int64),
        row_ids=np.array([u.job_id for u in placed], np.int64),
        col_ids=np.array([v.job_id for v in pending], np.int64),
        tie_break=tie_break,
    ).pairs(0)
    matches: Dict[int, int] = {}
    strategies: Dict[int, str] = {}
    total = 0.0
    for i, j in zip(rows, cols):
        if w[i, j] <= 0.0:
            continue  # zero-weight assignment = leave unpacked
        u, v = placed[i], pending[j]
        matches[v.job_id] = u.job_id
        prof_u = (
            profile
            if placed_gpu_types is None
            else profile.for_gpu_type(placed_gpu_types[i])
        )
        _, s = prof_u.combined_weight(
            u.spec.model, v.spec.model, optimize_strategy=optimize_strategy
        )
        if s != "dp":
            strategies[u.job_id] = s
        total += w[i, j]
    return PackingResult(
        matches, strategies, float(total), time.perf_counter() - t0, num_edges
    )
