"""Failure events for the fault-injection layer (Helios/Philly semantics).

Real GPU clusters lose whole nodes (hardware faults, maintenance reboots),
see individual accelerators degrade (thermal throttling, ECC retirement
pressure) and lose jobs outright (OOM, NCCL timeouts, user bugs) — the
Helios/Philly characterisations (PAPERS.md, arxiv 2109.01313) show these
events dominate tail behaviour.  This module defines the EVENT vocabulary
the simulator consumes; the seeded generators that *emit* these events
live in :mod:`repro.workloads.failures` (the workload side of the lab),
keeping the dependency direction workloads -> core.

Semantics (enforced by :class:`~repro.core.simulator.Simulator`):

* ``node-down`` — the node drops to zero capacity; every job with at
  least one GPU on it is preempted WITHOUT a checkpoint save (work since
  the last checkpoint is lost) and requeued through the retry/backoff
  ladder.
* ``node-up`` — the node rejoins at full speed; the scheduler's warm
  matching state for it is invalidated (targeted — healthy nodes keep
  their warm state).
* ``gpu-degrade`` — the node's GPUs run at ``factor`` of nominal speed
  (``factor=1.0`` restores).  Truth-side only: the scheduler's beliefs
  are unchanged, modelling an undetected straggler.
* ``job-fail`` — a software failure of one running job: lost work back to
  the last checkpoint, one retry consumed, exponential backoff before the
  job is eligible again.  A job that is not running when the event fires
  is unaffected (the hazard missed).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

NODE_DOWN = "node-down"
NODE_UP = "node-up"
GPU_DEGRADE = "gpu-degrade"
JOB_FAIL = "job-fail"

EVENT_KINDS = (NODE_DOWN, NODE_UP, GPU_DEGRADE, JOB_FAIL)


@dataclasses.dataclass(frozen=True)
class FailureEvent:
    """One failure-model event, applied at the first round boundary at or
    after ``time_s`` (round-based semantics, like everything else in the
    simulator)."""

    time_s: float
    kind: str
    #: target node (``node-down`` / ``node-up`` / ``gpu-degrade``).
    node: Optional[int] = None
    #: target job (``job-fail``).
    job_id: Optional[int] = None
    #: speed factor in (0, 1] for ``gpu-degrade``; 1.0 restores nominal.
    factor: Optional[float] = None

    def __post_init__(self):
        if self.kind not in EVENT_KINDS:
            raise ValueError(
                f"unknown failure-event kind {self.kind!r}; "
                f"expected one of {EVENT_KINDS}"
            )
        if self.time_s < 0:
            raise ValueError(f"{self.kind}: negative event time {self.time_s}")
        if self.kind in (NODE_DOWN, NODE_UP, GPU_DEGRADE):
            if self.node is None or self.node < 0:
                raise ValueError(f"{self.kind}: needs a non-negative node")
        if self.kind == JOB_FAIL and self.job_id is None:
            raise ValueError(f"{self.kind}: needs a job_id")
        if self.kind == GPU_DEGRADE:
            if self.factor is None or not (0.0 < self.factor <= 1.0):
                raise ValueError(
                    f"{self.kind}: factor must be in (0, 1], got {self.factor}"
                )

    #: deterministic total order for merged event streams: time first,
    #: then kind (ups before downs at the same instant would resurrect a
    #: node mid-crash, so downs sort first via the EVENT_KINDS index),
    #: then targets.
    def sort_key(self):
        return (
            self.time_s,
            EVENT_KINDS.index(self.kind),
            -1 if self.node is None else self.node,
            -1 if self.job_id is None else self.job_id,
        )

    # -- (de)serialisation (the JobTrace JSON envelope's failure rows) ---- #
    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        return {k: v for k, v in d.items() if v is not None}

    @classmethod
    def from_dict(cls, d: Dict) -> "FailureEvent":
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - fields
        if unknown:
            raise ValueError(f"unknown FailureEvent fields: {sorted(unknown)}")
        return cls(**d)
