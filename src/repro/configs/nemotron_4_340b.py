"""Nemotron-4-340B [arXiv:2402.16819]: dense GQA with squared-ReLU MLP."""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    arch_type="dense",
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    head_dim=192,
    mlp_type="squared_relu",
    rope_theta=1.0e4,
    attention_window=16384,
    source="arXiv:2402.16819 (Nemotron-4)",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="nemotron-smoke",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
    )
