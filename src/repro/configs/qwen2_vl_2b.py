"""Qwen2-VL-2B language backbone [arXiv:2409.12191].

VLM: M-RoPE (3-section temporal/height/width rotary), dynamic-resolution
vision tokens.  The ViT frontend is a stub per the brief — ``input_specs``
supplies precomputed patch embeddings of shape (B, frontend_len, d_model).
"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    arch_type="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    mrope=True,
    rope_theta=1.0e6,
    mlp_type="swiglu",
    frontend="vision",
    frontend_len=256,  # patch embeddings per image
    attention_window=16384,  # sliding-window variant for long_500k decode
    source="arXiv:2409.12191 (Qwen2-VL)",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="qwen2-vl-smoke",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        frontend_len=16,
    )
