"""DBRX-132B [hf:databricks/dbrx-base]: fine-grained MoE, 16 experts top-4."""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    arch_type="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    head_dim=128,
    num_experts=16,
    num_experts_per_token=4,
    moe_d_ff=10752,
    mlp_type="swiglu",
    rope_theta=5.0e5,
    attention_window=16384,
    source="hf:databricks/dbrx-base",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="dbrx-smoke",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=512,
        moe_d_ff=512,
        num_experts=4,
        num_experts_per_token=2,
        vocab_size=512,
    )
