"""DeepSeek-67B [arXiv:2401.02954]: llama-architecture dense GQA."""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    arch_type="dense",
    num_layers=95,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=102400,
    head_dim=128,
    mlp_type="swiglu",
    rope_theta=1.0e4,
    attention_window=16384,
    source="arXiv:2401.02954 (DeepSeek LLM)",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="deepseek-67b-smoke",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
    )
