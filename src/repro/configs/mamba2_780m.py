"""Mamba2-780m [arXiv:2405.21060]: attention-free SSD state-space model."""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    arch_type="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    head_dim=0,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=128,
    tie_embeddings=True,
    source="arXiv:2405.21060 (Mamba-2 / SSD)",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="mamba2-smoke",
        num_layers=2,
        d_model=256,
        ssm_state=32,
        ssm_head_dim=64,
        ssm_chunk=32,
        vocab_size=512,
    )
