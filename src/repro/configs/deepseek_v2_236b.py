"""DeepSeek-V2-236B [arXiv:2405.04434]: MLA + fine-grained MoE.

Multi-head latent attention with kv_lora_rank=512 (the KV cache stores the
512-dim compressed latent + 64-dim decoupled RoPE key, NOT per-head K/V),
160 routed experts top-6 plus 2 shared experts, expert hidden dim 1536.
"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    arch_type="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,   # MLA: per-head K/V reconstructed from the latent
    d_ff=1536,          # routed-expert hidden dim per assignment
    vocab_size=102400,
    head_dim=128,
    use_mla=True,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    num_experts=160,
    num_experts_per_token=6,
    num_shared_experts=2,
    moe_d_ff=1536,
    mlp_type="swiglu",
    attention_window=16384,
    source="arXiv:2405.04434 (DeepSeek-V2)",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="deepseek-v2-smoke",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        qk_nope_dim=32,
        qk_rope_dim=16,
        v_head_dim=32,
        kv_lora_rank=64,
        d_ff=128,
        moe_d_ff=128,
        num_experts=4,
        num_experts_per_token=2,
        num_shared_experts=1,
        vocab_size=512,
    )
