"""Zamba2-2.7B [arXiv:2411.15242]: Mamba2 backbone + SHARED attention block.

54 Mamba2 (SSD) layers; one weight-shared attention+MLP block is applied
every ``hybrid_attn_every`` SSM layers, consuming concat(hidden, original
embedding) — the Zamba trick for global context at tiny parameter cost.
"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    arch_type="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    head_dim=80,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=128,
    hybrid_attn_every=9,  # 6 shared-block applications over 54 layers
    mlp_type="gelu",
    source="arXiv:2411.15242 (Zamba2)",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="zamba2-smoke",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        head_dim=64,
        d_ff=512,
        ssm_state=32,
        ssm_head_dim=64,
        ssm_chunk=32,
        hybrid_attn_every=1,
        vocab_size=512,
    )
