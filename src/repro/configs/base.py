"""Model configuration shared by all 10 assigned architectures.

One frozen dataclass covers the six architecture families (dense / MoE /
SSM / hybrid / VLM / audio enc-dec); each ``src/repro/configs/<arch>.py``
instantiates it with the exact assigned numbers and provides ``reduced()``
(<= 2 layers, d_model <= 512, <= 4 experts) for the CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # -- attention ------------------------------------------------------- #
    qk_norm: bool = False           # qwen3
    rope_theta: float = 1.0e4
    mrope: bool = False             # qwen2-vl multimodal rotary
    #: sliding window (tokens) used for long-context decode on archs whose
    #: full attention would be quadratic; None = full attention.
    attention_window: Optional[int] = None

    # -- feed-forward ------------------------------------------------------ #
    mlp_type: str = "swiglu"        # swiglu | squared_relu | gelu

    # -- MoE --------------------------------------------------------------- #
    num_experts: int = 0
    num_experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0               # per-expert hidden dim (d_ff if 0)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # -- MLA (deepseek-v2) -------------------------------------------------- #
    use_mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # -- SSM (mamba2 SSD) ---------------------------------------------------- #
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    ssm_conv_width: int = 4

    # -- hybrid (zamba2) ---------------------------------------------------- #
    #: apply the single SHARED attention+MLP block after every N ssm layers
    hybrid_attn_every: int = 0

    # -- encoder-decoder (seamless-m4t) -------------------------------------- #
    encoder_layers: int = 0

    # -- modality frontend stubs ---------------------------------------------- #
    frontend: Optional[str] = None  # "vision" | "audio"
    #: number of frontend embedding positions (patches / audio frames)
    frontend_len: int = 0

    # -- numerics ------------------------------------------------------------- #
    dtype: str = "bfloat16"
    norm_eps: float = 1.0e-5
    tie_embeddings: bool = False

    #: citation for the assigned config (paper / model card)
    source: str = ""

    # --------------------------------------------------------------------- #
    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_experts and self.moe_d_ff == 0:
            object.__setattr__(self, "moe_d_ff", self.d_ff)

    # -- derived sizes ------------------------------------------------------ #
    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (embedding + layers), used for roofline
        MODEL_FLOPS = 6*N*D and for migration-overhead modelling."""
        d, v = self.d_model, self.vocab_size
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d  # lm head
        layer = 0
        hd = self.head_dim
        if self.arch_type in ("dense", "moe", "vlm", "audio"):
            if self.use_mla:
                q_dim = self.num_heads * (self.qk_nope_dim + self.qk_rope_dim)
                layer += d * q_dim
                layer += d * (self.kv_lora_rank + self.qk_rope_dim)
                layer += self.kv_lora_rank * self.num_heads * (
                    self.qk_nope_dim + self.v_head_dim
                )
                layer += self.num_heads * self.v_head_dim * d
            else:
                layer += d * self.num_heads * hd          # q
                layer += 2 * d * self.num_kv_heads * hd   # k, v
                layer += self.num_heads * hd * d          # o
            layer += self._ffn_params(self.d_ff if not self.num_experts else 0)
            if self.num_experts:
                e_ff = self.moe_d_ff
                layer += d * self.num_experts  # router
                layer += self.num_experts * self._ffn_params(e_ff)
                layer += self.num_shared_experts * self._ffn_params(e_ff)
        if self.arch_type in ("ssm", "hybrid"):
            di, n = self.ssm_d_inner, self.ssm_state
            h = self.ssm_heads
            layer += d * (2 * di + 2 * n + h)  # in_proj (z, x, B, C, dt)
            layer += di * d                    # out_proj
            layer += (di + 2 * n) * self.ssm_conv_width + 2 * h  # conv + A, D
        total += self.num_layers * layer
        if self.arch_type == "hybrid" and self.hybrid_attn_every:
            # ONE shared attention+MLP block (reused)
            shared = 2 * d * self.num_heads * hd  # q, o (concat-proj folded)
            shared += 2 * d * self.num_kv_heads * hd
            shared += 2 * d * d  # concat-in projection
            shared += self._ffn_params(self.d_ff)
            total += shared
        if self.is_encoder_decoder:
            # encoder layers (self-attn + ffn) + decoder cross-attn extra
            enc_layer = 4 * d * d + self._ffn_params(self.d_ff)
            total += self.encoder_layers * enc_layer
            total += self.num_layers * (2 * d * self.num_kv_heads * hd + 2 * d * self.num_heads * hd)
        return total

    def _ffn_params(self, ff: int) -> int:
        if ff == 0:
            return 0
        if self.mlp_type == "swiglu":
            return 3 * self.d_model * ff
        return 2 * self.d_model * ff

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k + shared experts only)."""
        if not self.num_experts:
            return self.param_count()
        full = self.param_count()
        all_expert = self.num_layers * self.num_experts * self._ffn_params(self.moe_d_ff)
        active_expert = self.num_layers * self.num_experts_per_token * self._ffn_params(
            self.moe_d_ff
        )
        return full - all_expert + active_expert
