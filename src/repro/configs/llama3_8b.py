"""Llama-3-8B [arXiv:2407.21783]: dense GQA, 128k vocab."""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    arch_type="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    head_dim=128,
    mlp_type="swiglu",
    rope_theta=5.0e5,
    attention_window=16384,
    source="arXiv:2407.21783 (Llama 3)",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="llama3-smoke",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
    )
