"""Qwen3-14B [hf:Qwen/Qwen3-8B family]: dense GQA decoder with QK-norm."""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    arch_type="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=17408,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1.0e6,
    mlp_type="swiglu",
    attention_window=16384,
    source="hf:Qwen/Qwen3-8B (scaled per assignment)",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="qwen3-smoke",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
    )
