"""SeamlessM4T-medium transformer backbone [arXiv:2308.11596].

Encoder-decoder; the conformer speech frontend (mel-spectrogram + conv
feature extractor) is a stub — ``input_specs`` supplies precomputed frame
embeddings (B, frames, d_model).  12 encoder + 12 decoder layers, MHA
(GQA with kv == heads).
"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    arch_type="audio",
    num_layers=12,            # decoder layers
    encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    head_dim=64,
    mlp_type="gelu",
    frontend="audio",
    frontend_len=512,         # encoder frames after the (stubbed) conv codec
    attention_window=16384,
    source="arXiv:2308.11596 (SeamlessM4T)",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        name="seamless-smoke",
        num_layers=2,
        encoder_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        frontend_len=32,
    )
