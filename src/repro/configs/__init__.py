"""Registry of the 10 assigned architectures (+ reduced smoke variants).

Every config cites its source in ``ModelConfig.source``; ``get_config(id)``
returns the full assigned config, ``get_reduced(id)`` the <=2-layer /
<=512-d_model / <=4-expert smoke variant exercised on CPU.
"""

from __future__ import annotations

import importlib
from typing import List

from repro.configs.base import ModelConfig

ARCH_IDS: List[str] = [
    "qwen2_vl_2b",
    "qwen3_14b",
    "seamless_m4t_medium",
    "nemotron_4_340b",
    "deepseek_v2_236b",
    "mamba2_780m",
    "dbrx_132b",
    "deepseek_67b",
    "zamba2_2p7b",
    "llama3_8b",
]

#: CLI-facing ids (--arch <id>) -> module name
ALIASES = {
    "qwen2-vl-2b": "qwen2_vl_2b",
    "qwen3-14b": "qwen3_14b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "nemotron-4-340b": "nemotron_4_340b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "mamba2-780m": "mamba2_780m",
    "dbrx-132b": "dbrx_132b",
    "deepseek-67b": "deepseek_67b",
    "zamba2-2.7b": "zamba2_2p7b",
    "llama3-8b": "llama3_8b",
}


def _module(arch: str):
    mod_name = ALIASES.get(arch, arch.replace("-", "_").replace(".", "p"))
    return importlib.import_module(f"repro.configs.{mod_name}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_reduced(arch: str) -> ModelConfig:
    return _module(arch).reduced()


def list_archs() -> List[str]:
    return list(ALIASES.keys())
