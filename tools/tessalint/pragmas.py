"""Line-level suppression pragmas.

Syntax (trailing comment on the flagged line, or any physical line of the
flagged multi-line expression)::

    x = np.asarray(dev)  # tessalint: sync-ok(THE one readout per round)

Several rules may share one pragma comment, comma-separated::

    # tessalint: sync-ok(readout), det-ok(seeded upstream)

Every suppression MUST carry a non-empty reason — a bare ``sync-ok()`` is
itself reported (rule ``pragma``), as is a pragma naming an unknown rule
or one the runner can't parse.  Blanket (file- or block-level)
suppressions are deliberately unsupported: the point of the pragma is a
reviewed, per-site justification.
"""

from __future__ import annotations

import io
import re
import tokenize
from typing import Dict, Iterator, List, Tuple

from tools.tessalint.findings import Finding

_PRAGMA_RE = re.compile(r"#\s*tessalint:\s*(?P<body>.*)$")
_ITEM_START_RE = re.compile(r"(?P<rule>[A-Za-z][\w-]*)-ok\(")


def _comment_tokens(lines: List[str]) -> Iterator[Tuple[int, int, str]]:
    """(line, col, text) of every REAL comment — a ``# tessalint:`` inside
    a string literal (e.g. this linter's own docstrings) is not a pragma."""
    reader = io.StringIO("\n".join(lines) + "\n").readline
    try:
        for tok in tokenize.generate_tokens(reader):
            if tok.type == tokenize.COMMENT:
                yield tok.start[0], tok.start[1], tok.string
    except (tokenize.TokenizeError, IndentationError, SyntaxError):
        # unparseable file: the runner already reports it; no pragmas
        return


def scan_pragmas(
    path: str, lines: List[str], known_rules
) -> Tuple[Dict[int, Dict[str, str]], List[Finding]]:
    """Parse every ``# tessalint:`` comment in ``lines``.

    Returns ``(pragmas, problems)`` where ``pragmas[lineno][rule]`` is the
    suppression reason (1-based line numbers) and ``problems`` are
    ``pragma``-rule findings for malformed/empty/unknown entries.
    """
    pragmas: Dict[int, Dict[str, str]] = {}
    problems: List[Finding] = []
    for i, col, comment in _comment_tokens(lines):
        raw = lines[i - 1] if i <= len(lines) else comment
        m = _PRAGMA_RE.search(comment)
        if not m:
            continue
        body = m.group("body").strip()
        entries: Dict[str, str] = {}
        # reasons may contain parens/commas: each item's reason runs to the
        # LAST ')' before the next `<rule>-ok(` (or the end of the comment)
        starts = list(_ITEM_START_RE.finditer(body))
        ok = bool(starts) and starts[0].start() == 0
        for k, im in enumerate(starts) if ok else []:
            seg_end = starts[k + 1].start() if k + 1 < len(starts) else len(body)
            seg = body[im.end(): seg_end]
            close = seg.rfind(")")
            trailer = seg[close + 1:].strip() if close >= 0 else ""
            if close < 0 or (trailer != "," if k + 1 < len(starts) else trailer):
                ok = False
                break
            rule, reason = im.group("rule"), seg[:close].strip()
            if rule not in known_rules:
                problems.append(
                    Finding(
                        "pragma", path, i, col,
                        f"pragma suppresses unknown rule {rule!r}",
                        snippet=raw.strip(),
                        hint=f"known rules: {', '.join(sorted(known_rules))}",
                        severity="P2",
                    )
                )
            elif not reason:
                problems.append(
                    Finding(
                        "pragma", path, i, col,
                        f"pragma {rule}-ok() has no reason",
                        snippet=raw.strip(),
                        hint="every suppression must carry a reviewed reason: "
                        f"{rule}-ok(<why this site is intentional>)",
                        severity="P2",
                    )
                )
            else:
                entries[rule] = reason
        if not ok:
            problems.append(
                Finding(
                    "pragma", path, i, col,
                    "malformed tessalint pragma",
                    snippet=raw.strip(),
                    hint="syntax: # tessalint: <rule>-ok(<reason>)[, <rule>-ok(<reason>)...]",
                    severity="P2",
                )
            )
            continue
        if entries:
            pragmas[i] = entries
    return pragmas, problems
