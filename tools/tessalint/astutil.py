"""Shared AST helpers: import-alias resolution and dotted-name utilities.

All passes resolve call targets through :class:`Imports` so rules match
the CANONICAL module path (``numpy.asarray``, ``time.time``,
``jax.device_get``) regardless of the import style at the top of the
file (``import numpy as np``, ``from time import time``, ...).
"""

from __future__ import annotations

import ast
from typing import Dict, Optional


class Imports:
    """Alias table for one module: maps local names to canonical dotted
    module paths.

    * ``import numpy as np``            → ``np → numpy``
    * ``import jax.numpy as jnp``       → ``jnp → jax.numpy``
    * ``from jax import numpy as jnp``  → ``jnp → jax.numpy``
    * ``from time import time``         → ``time → time.time``
    """

    def __init__(self, tree: ast.Module):
        self.aliases: Dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.aliases[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0]
                    )
            elif isinstance(node, ast.ImportFrom) and node.module and not node.level:
                for a in node.names:
                    if a.name == "*":
                        continue
                    self.aliases[a.asname or a.name] = f"{node.module}.{a.name}"

    def resolve(self, node: ast.AST) -> Optional[str]:
        """Canonical dotted path of a Name/Attribute chain, or None."""
        parts = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        root = self.aliases.get(node.id, node.id)
        parts.append(root)
        return ".".join(reversed(parts))


def dotted(node: ast.AST) -> Optional[str]:
    """Literal dotted source text of a Name/Attribute chain (NO alias
    resolution) — e.g. ``self.scheduler.prewarm``.  None for anything
    that is not a pure chain."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def functions_with_qualnames(tree: ast.Module):
    """Yield ``(qualname, FunctionDef)`` for every (async) function in the
    module, with ``Class.method`` / ``outer.<locals>.inner`` qualnames."""
    out = []

    def visit(node, prefix):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{prefix}{child.name}"
                out.append((q, child))
                visit(child, f"{q}.<locals>.")
            elif isinstance(child, ast.ClassDef):
                visit(child, f"{prefix}{child.name}.")
            else:
                visit(child, prefix)

    visit(tree, "")
    return out


def call_name(node: ast.Call, imports: Imports) -> Optional[str]:
    return imports.resolve(node.func)
