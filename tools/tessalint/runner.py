"""Orchestration: walk files, scope rules via the manifest, run passes,
apply pragma suppressions, and emit the report."""

from __future__ import annotations

import ast
from pathlib import Path
from typing import Iterable, List, Optional, Sequence, Tuple

from tools.tessalint.astutil import Imports
from tools.tessalint.findings import Finding, report
from tools.tessalint.manifest import DEFAULT_MANIFEST_PATH, Manifest
from tools.tessalint.passes import ALL_RULES, PASSES
from tools.tessalint.passes.base import FileContext
from tools.tessalint.pragmas import scan_pragmas


def iter_py_files(paths: Sequence) -> Iterable[Path]:
    for p in paths:
        p = Path(p)
        if p.is_dir():
            yield from sorted(
                q for q in p.rglob("*.py") if "__pycache__" not in q.parts
            )
        elif p.suffix == ".py":
            yield p


def lint_file(
    path: Path, manifest: Manifest, rules: Optional[Sequence[str]] = None
) -> List[Finding]:
    """All findings (suppressed ones included, marked) for one file."""
    source = path.read_text()
    lines = source.splitlines()
    try:
        tree = ast.parse(source, filename=str(path))
    except SyntaxError as e:
        return [
            Finding(
                "pragma",
                str(path),
                e.lineno or 1,
                e.offset or 0,
                f"file does not parse: {e.msg}",
                severity="P1",
            )
        ]
    imports = Imports(tree)
    pragmas, problems = scan_pragmas(str(path), lines, ALL_RULES)

    findings: List[Finding] = []
    active_rules = [
        r for r in PASSES if (rules is None or r in rules) and manifest.applies(r, path)
    ]
    for rule in active_rules:
        ctx = FileContext(
            path=str(path),
            source=source,
            lines=lines,
            tree=tree,
            imports=imports,
            options=manifest.options(rule),
        )
        findings.extend(PASSES[rule].run(ctx))

    # pragma suppression: a pragma on any physical line of the flagged
    # node suppresses findings of that rule there
    used: set = set()
    for f in findings:
        for line in range(f.line, f.end_line + 1):
            reason = pragmas.get(line, {}).get(f.rule)
            if reason is not None:
                f.suppressed = True
                f.suppress_reason = reason
                used.add((line, f.rule))
                break

    # unused pragmas for rules that RAN on this file are themselves
    # findings: a suppression that no longer suppresses anything is a
    # stale review artifact (the guarded site moved or was fixed)
    if rules is None or "pragma" in rules:
        findings.extend(problems)
        for line, entries in pragmas.items():
            for rule in entries:
                if rule in active_rules and (line, rule) not in used:
                    findings.append(
                        Finding(
                            "pragma",
                            str(path),
                            line,
                            0,
                            f"unused suppression: {rule}-ok on a line the "
                            f"{rule} pass no longer flags",
                            snippet=lines[line - 1].strip() if line <= len(lines) else "",
                            hint="delete the stale pragma (or re-anchor it on "
                            "the line the finding moved to)",
                            severity="P2",
                        )
                    )
    return findings


def run_paths(
    paths: Sequence,
    manifest: Optional[Manifest] = None,
    manifest_path=None,
    rules: Optional[Sequence[str]] = None,
) -> Tuple[dict, List[Finding]]:
    """Lint ``paths``; returns ``(json_report, all_findings)`` where the
    report counts only unsuppressed findings."""
    if manifest is None:
        manifest = Manifest.load(manifest_path or DEFAULT_MANIFEST_PATH)
    all_findings: List[Finding] = []
    n_files = 0
    for path in iter_py_files(paths):
        n_files += 1
        all_findings.extend(lint_file(path, manifest, rules))
    rep = report(all_findings, list(ALL_RULES), n_files)
    return rep, all_findings
