"""Rule ``mantissa`` — unquantised values in the fused cost-assembly graph.

The fused decide() assembles Algorithm-3 costs as EXACT integers in f32
under a 2^24 mantissa budget (``fused._F32_MANTISSA``): scaled costs are
integers, tie-break quanta are powers of two, and health penalties are
CEILed to half-units (``STRAGGLER_DRAIN_COST``) before scaling.  One
stray ``0.3`` flowing into a cost term silently breaks the bit-identity
between the fused program and the host planner — the 60-round
differential flakes, rarely, instead of a test failing loudly.

Within the manifest-scoped functions (``options.functions``, qualnames;
``"*"`` scopes a whole module), flag:

* float literals that are neither half-units (``k / 2``) nor exact
  powers of two — the two shapes the quantisation contract allows;
* true division whose result is bound to a cost-carrying name
  (``options.value_pattern`` regex, default
  ``cost|weight|pen|benefit``), unless the denominator is a
  power-of-two literal — anything else must justify why the quotient
  stays on the integer/half-unit lattice.
"""

from __future__ import annotations

import ast
import math
import re
from typing import List

from tools.tessalint.astutil import functions_with_qualnames
from tools.tessalint.findings import Finding
from tools.tessalint.passes.base import FileContext

RULE = "mantissa"

_DEFAULT_VALUE_PATTERN = r"cost|weight|pen|benefit"


def _is_half_unit(v: float) -> bool:
    return v == int(v) or (2.0 * v) == int(2.0 * v)


def _is_pow2(v: float) -> bool:
    if v <= 0.0 or math.isinf(v) or math.isnan(v):
        return False
    m, _ = math.frexp(v)
    return m == 0.5


def _pow2_literal(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant) and isinstance(node.value, (int, float)):
        return _is_pow2(float(node.value))
    if (
        isinstance(node, ast.BinOp)
        and isinstance(node.op, ast.Pow)
        and isinstance(node.left, ast.Constant)
        and node.left.value in (2, 2.0)
    ):
        return True
    return False


def _target_names(target: ast.AST) -> List[str]:
    """Root names of an assignment target (``weights[j]`` → ``weights``)."""
    node = target
    while isinstance(node, (ast.Subscript, ast.Attribute, ast.Starred)):
        node = node.value
    if isinstance(node, ast.Name):
        return [node.id]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for el in node.elts:
            out.extend(_target_names(el))
        return out
    return []


def run(ctx: FileContext) -> List[Finding]:
    findings: List[Finding] = []
    wanted = set(ctx.options.get("functions", []))
    pat = re.compile(ctx.options.get("value_pattern", _DEFAULT_VALUE_PATTERN))

    scoped: List[ast.AST] = []
    if "*" in wanted:
        scoped.append(ctx.tree)
    else:
        for qual, fn in functions_with_qualnames(ctx.tree):
            if qual in wanted or fn.name in wanted:
                scoped.append(fn)
    if not scoped:
        return findings

    def flag(node, message, hint):
        findings.append(
            Finding(
                RULE,
                ctx.path,
                node.lineno,
                node.col_offset,
                message,
                snippet=ctx.snippet(node.lineno),
                hint=hint,
                severity="P1",
                end_line=getattr(node, "end_lineno", node.lineno) or node.lineno,
            )
        )

    seen = set()
    for scope in scoped:
        for node in ast.walk(scope):
            if id(node) in seen:
                continue
            if (
                isinstance(node, ast.Constant)
                and isinstance(node.value, float)
                and not _is_half_unit(node.value)
                and not _is_pow2(node.value)
            ):
                seen.add(id(node))
                flag(
                    node,
                    f"float literal {node.value!r} is neither a half-unit "
                    "nor a power of two",
                    "cost terms must stay on the half-unit lattice "
                    "(CEIL to half-units like STRAGGLER_DRAIN_COST) so the "
                    "f32 assembly stays exact under the 2^24 budget",
                )
            elif isinstance(node, ast.Assign) and _divides_value(node.value):
                names = []
                for t in node.targets:
                    names.extend(_target_names(t))
                hits = [n for n in names if pat.search(n)]
                if hits:
                    seen.add(id(node))
                    flag(
                        node.value,
                        f"unquantised division feeds cost-carrying name "
                        f"{hits[0]!r}",
                        "divide by a power of two, or route through a "
                        "half-unit quantisation helper and document why the "
                        "quotient is exact",
                    )
    return findings


def _divides_value(value: ast.AST) -> bool:
    """True when the expression contains a true division NOT by a
    power-of-two literal."""
    for sub in ast.walk(value):
        if isinstance(sub, ast.BinOp) and isinstance(sub.op, ast.Div):
            if not _pow2_literal(sub.right):
                return True
    return False
