"""Rule ``thread`` — shared-state access while a background thread owns it.

The simulator's ``speculative_prewarm`` hands ``self.scheduler`` (and
with it the ``MatchContext`` and policy state) to a background thread
between rounds; the documented contract is that NOTHING touches the
scheduler until the future is joined at the top of the next round.  The
``MatchContext`` docstring says it outright: "Thread-safety: none".

This pass flags, inside any function that submits a BOUND METHOD to an
executor or thread:

* access to the submitted method's owner object (``self.scheduler`` in
  ``executor.submit(self.scheduler.prewarm, ...)``) at a point that is
  AFTER the submit in source order with no intervening join point
  (``.result()`` / ``.join()`` / ``.shutdown()``) — the window where the
  background thread may still own the object;
* a submit with NO join point anywhere in the function
  (fire-and-forget on shared state).

Source order is a deliberate approximation of execution order: the
repo's one submit sits at the bottom of the round loop with the join at
the top, so the back-edge window is clean by construction; an access
slipped between submit and loop end — the realistic regression — is
exactly what source order catches.  Full flow-sensitive ordering is the
next rung on the ladder (tools/tessalint/README.md).

Detected submit forms: ``<executor>.submit(obj.method, ...)`` and
``threading.Thread(target=obj.method, ...)``.
"""

from __future__ import annotations

import ast
from typing import List, Optional, Tuple

from tools.tessalint.astutil import call_name, dotted
from tools.tessalint.findings import Finding
from tools.tessalint.passes.base import FileContext

RULE = "thread"

_JOIN_METHODS = {"result", "join", "shutdown"}


def _submitted_owner(node: ast.Call, imports) -> Optional[str]:
    """Dotted owner expression of a bound method handed to a thread."""
    target = None
    if isinstance(node.func, ast.Attribute) and node.func.attr == "submit":
        if node.args:
            target = node.args[0]
    elif call_name(node, imports) == "threading.Thread":
        for kw in node.keywords:
            if kw.arg == "target":
                target = kw.value
    if isinstance(target, ast.Attribute):
        return dotted(target.value)
    return None


def run(ctx: FileContext) -> List[Finding]:
    findings: List[Finding] = []

    def flag(node, message, hint):
        findings.append(
            Finding(
                RULE,
                ctx.path,
                node.lineno,
                node.col_offset,
                message,
                snippet=ctx.snippet(node.lineno),
                hint=hint,
                severity="P1",
                end_line=getattr(node, "end_lineno", node.lineno) or node.lineno,
            )
        )

    for fn in ast.walk(ctx.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        submits: List[Tuple[int, str, ast.Call]] = []
        joins: List[int] = []
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            owner = _submitted_owner(node, ctx.imports)
            if owner is not None:
                submits.append((node.lineno, owner, node))
            elif (
                isinstance(node.func, ast.Attribute)
                and node.func.attr in _JOIN_METHODS
            ):
                joins.append(node.lineno)
        if not submits:
            continue

        for submit_line, owner, submit_node in submits:
            if not joins:
                flag(
                    submit_node,
                    f"background thread takes {owner!r} with no join point "
                    "in this function",
                    "join the future (.result()/.join()/.shutdown()) before "
                    "the shared object is touched again",
                )
                continue
            prefix = owner + "."
            submit_end = getattr(submit_node, "end_lineno", submit_line) or submit_line
            for node in ast.walk(fn):
                if not isinstance(node, (ast.Attribute, ast.Name)):
                    continue
                d = dotted(node)
                if d is None or (d != owner and not d.startswith(prefix)):
                    continue
                line = node.lineno
                if line <= submit_end:
                    continue
                # joined between submit and this access?
                if any(submit_line < j <= line for j in joins):
                    continue
                flag(
                    node,
                    f"{d!r} accessed while the background thread from line "
                    f"{submit_line} may still own {owner!r}",
                    "move the access above the submit or behind the join "
                    "point (.result()) — MatchContext is not thread-safe",
                )
                break  # one finding per submit is enough signal
    return findings
