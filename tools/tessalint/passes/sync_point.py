"""Rule ``sync`` — device→host transfers in device-resident modules.

The fused decide() path guarantees ONE device→host readout per round
(``BENCH_fused_decide.json``); the identity-keyed engine guarantees
readouts only at documented points (assignment extraction, the batched
match prologue, the LRU park).  Any other transfer is a silent sync that
shows up as a per-round latency cliff long before a benchmark catches it.

In modules the manifest declares device-resident, flag:

* ``np.asarray`` / ``np.array`` / ``np.ascontiguousarray`` on a value
  that (transitively) came from ``jax.numpy`` / ``jax.lax`` / another
  device producer;
* ``jax.device_get`` — ALWAYS flagged: every sanctioned readout is
  pragma-annotated, so the set of syncs is closed under review;
* ``.item()`` / ``.tolist()`` and ``float()/int()/bool()/complex()``
  coercions of device values;
* ``if`` / ``while`` tests and ``for`` iteration over device values
  (host control flow forces a blocking transfer);
* f-strings / ``print`` / ``repr`` / ``str`` formatting device values.

Taint is a per-scope, flow-insensitive fixpoint over assignments: a name
assigned from an expression containing a device producer (or a tainted
name) is tainted; host converters and ``jax.device_get`` LAUNDER their
result (the result is a host value — the call itself is what gets
flagged).  Parameters annotated ``jax.Array`` / ``jnp.ndarray`` are
tainted seeds, and nested functions inherit the enclosing scope's taint
(closure capture).  Flow-sensitive tracer tracking is the next rung on
the ladder (see tools/tessalint/README.md).

Options:
* ``device_producers``: extra canonical call prefixes that return device
  values (e.g. ``"repro.kernels."``).
"""

from __future__ import annotations

import ast
from typing import Iterator, List, Set

from tools.tessalint.astutil import call_name
from tools.tessalint.findings import Finding
from tools.tessalint.passes.base import FileContext

RULE = "sync"

_PRODUCER_PREFIXES = (
    "jax.numpy.",
    "jax.lax.",
    "jax.nn.",
    "jax.random.",
    "jax.scipy.",
    "jax.experimental.",
)
_PRODUCER_CALLS = {"jax.vmap", "jax.pmap", "jax.grad", "jax.value_and_grad"}
_HOST_CONVERTERS = {"numpy.asarray", "numpy.array", "numpy.ascontiguousarray"}
_ALWAYS_SYNC = {"jax.device_get"}
_COERCIONS = {"float", "int", "bool", "complex"}
_FORMATTERS = {"print", "repr", "str"}
_SYNC_METHODS = {"item", "tolist", "block_until_ready"}
# Array metadata that lives host-side: reading it never transfers data.
_META_ATTRS = {"shape", "ndim", "size", "dtype", "weak_type", "sharding", "nbytes", "itemsize"}

_FUNC = (ast.FunctionDef, ast.AsyncFunctionDef)


def own_nodes(scope_node: ast.AST) -> Iterator[ast.AST]:
    """Walk a scope's OWN nodes: stop at nested function boundaries (their
    bodies are separate scopes), but keep lambdas and comprehensions."""
    stack = list(ast.iter_child_nodes(scope_node))
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, _FUNC):
            stack.extend(ast.iter_child_nodes(node))


def _param_is_device(arg: ast.arg) -> bool:
    if arg.annotation is None:
        return False
    text = ast.unparse(arg.annotation)
    return any(tag in text for tag in ("jax.Array", "jnp.ndarray", "jax.numpy.ndarray"))


class _Scope:
    def __init__(self, ctx: FileContext, node, inherited: Set[str]):
        self.ctx = ctx
        self.node = node
        self.taint: Set[str] = set(inherited)
        if isinstance(node, _FUNC):
            a = node.args
            for arg in [*a.posonlyargs, *a.args, *a.kwonlyargs]:
                if _param_is_device(arg):
                    self.taint.add(arg.arg)
        self.extra = tuple(ctx.options.get("device_producers", []))

    def device_expr(self, node: ast.AST) -> bool:
        """True when the expression reads DEVICE DATA.  Prunes subtrees
        that only touch host-side metadata or launder to host:

        * host converters / ``device_get`` calls — their result is a host
          value (the call itself is flagged separately);
        * ``.shape`` / ``.ndim`` / ``.size`` / ``.dtype`` — array
          metadata lives host-side, branching on it never transfers;
        * ``is`` / ``is not`` comparisons — object identity, no read.
        """
        if isinstance(node, ast.Call):
            q = call_name(node, self.ctx.imports)
            if q in _HOST_CONVERTERS or q in _ALWAYS_SYNC:
                return False
            if q is not None and (
                q.startswith(_PRODUCER_PREFIXES)
                or q in _PRODUCER_CALLS
                or any(q.startswith(p) for p in self.extra)
            ):
                return True
        elif isinstance(node, ast.Attribute):
            if node.attr in _META_ATTRS:
                return False
        elif isinstance(node, ast.Compare):
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return False
        elif isinstance(node, ast.Name):
            return node.id in self.taint
        return any(self.device_expr(c) for c in ast.iter_child_nodes(node))

    def _rhs_taints(self, value: ast.AST) -> bool:
        return self.device_expr(value)

    def _bind(self, target: ast.AST) -> List[str]:
        if isinstance(target, ast.Name):
            return [target.id]
        if isinstance(target, (ast.Tuple, ast.List)):
            out = []
            for el in target.elts:
                out.extend(self._bind(el))
            return out
        return []

    def compute_taint(self) -> None:
        for _ in range(4):  # fixpoint: chains of assignments
            before = len(self.taint)
            for stmt in own_nodes(self.node):
                if isinstance(stmt, ast.Assign):
                    if self._rhs_taints(stmt.value):
                        for t in stmt.targets:
                            self.taint.update(self._bind(t))
                elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
                    if stmt.value is not None and self._rhs_taints(stmt.value):
                        self.taint.update(self._bind(stmt.target))
            if len(self.taint) == before:
                break


def run(ctx: FileContext) -> List[Finding]:
    findings: List[Finding] = []

    def flag(node, message, hint, severity="P1"):
        findings.append(
            Finding(
                RULE,
                ctx.path,
                node.lineno,
                node.col_offset,
                message,
                snippet=ctx.snippet(node.lineno),
                hint=hint,
                severity=severity,
                end_line=getattr(node, "end_lineno", node.lineno) or node.lineno,
            )
        )

    def check_scope(scope_node: ast.AST, inherited: Set[str]) -> None:
        scope = _Scope(ctx, scope_node, inherited)
        scope.compute_taint()

        for node in own_nodes(scope_node):
            if isinstance(node, _FUNC):
                check_scope(node, scope.taint)
                continue
            if isinstance(node, ast.Call):
                q = call_name(node, ctx.imports)
                if q in _ALWAYS_SYNC:
                    flag(
                        node,
                        "jax.device_get is a device→host sync point",
                        "if this is THE sanctioned readout, annotate it: "
                        "# tessalint: sync-ok(<why this readout is in budget>)",
                    )
                elif q in _HOST_CONVERTERS and any(
                    scope.device_expr(a) for a in node.args
                ):
                    flag(
                        node,
                        f"{q.split('.')[-1]} on a device value forces a "
                        "device→host transfer",
                        "keep the value on device (jnp), or move the readout "
                        "to the round's single sanctioned sync",
                    )
                elif (
                    q in _COERCIONS
                    and len(node.args) == 1
                    and scope.device_expr(node.args[0])
                ):
                    flag(
                        node,
                        f"{q}() coercion of a device value blocks on a "
                        "device→host transfer",
                        "coerce after the sanctioned readout, or keep the "
                        "value in the jitted program",
                    )
                elif q in _FORMATTERS and any(
                    scope.device_expr(a) for a in node.args
                ):
                    flag(
                        node,
                        f"{q}() of a device value forces a device→host "
                        "transfer",
                        "log host-side copies from the sanctioned readout "
                        "instead",
                        severity="P2",
                    )
                elif (
                    isinstance(node.func, ast.Attribute)
                    and node.func.attr in _SYNC_METHODS
                    and scope.device_expr(node.func.value)
                ):
                    flag(
                        node,
                        f".{node.func.attr}() on a device value is a "
                        "device→host sync point",
                        "read the value out with the round's single "
                        "sanctioned sync instead",
                    )
            elif isinstance(node, (ast.If, ast.While)) and scope.device_expr(
                node.test
            ):
                flag(
                    node.test,
                    "host control flow on a device value forces a blocking "
                    "transfer",
                    "use jnp.where / lax.cond, or branch on the host copy "
                    "from the sanctioned readout",
                )
            elif isinstance(node, ast.For) and scope.device_expr(node.iter):
                flag(
                    node.iter,
                    "host iteration over a device value syncs per element",
                    "vectorise with jnp, or iterate the host copy from the "
                    "sanctioned readout",
                )
            elif isinstance(node, ast.FormattedValue) and scope.device_expr(
                node.value
            ):
                flag(
                    node,
                    "f-string formats a device value (forces a device→host "
                    "transfer)",
                    "format the host copy from the sanctioned readout",
                    severity="P2",
                )

    check_scope(ctx.tree, set())
    return findings
