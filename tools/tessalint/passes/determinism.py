"""Rule ``det`` — nondeterminism reachable from plan construction.

Every CI gate in this repo (perf-smoke, chaos-smoke, fused-smoke, the
60-round churn differentials) asserts BIT-IDENTICAL plans across runs and
backends.  That only holds while plan construction never reads a
wall clock or an unseeded RNG, and never lets set-iteration order leak
into an ordering-sensitive output.

In manifest-scoped modules, flag:

* wall clock: ``time.time`` / ``time.time_ns`` / ``datetime.now`` /
  ``datetime.utcnow`` / ``date.today``.  (``time.perf_counter`` /
  ``time.monotonic`` are NOT flagged — they feed duration telemetry,
  never decisions; the decide-deadline watchdog takes an injectable
  clock for exactly this reason.)
* unseeded RNG: legacy module-level ``np.random.*`` (global-state), any
  ``random.*`` module function (``random.Random(seed)`` instances are
  fine), and ``np.random.default_rng()`` called with NO seed.
* iteration over sets: ``for``/comprehension iteration (or
  ``list()``/``tuple()`` materialisation) of a set literal, a
  ``set()``/``frozenset()`` call, a set comprehension, or a
  ``.intersection()/.union()/...`` result — unless wrapped in
  ``sorted()``.  CPython set order varies with insertion history and
  pointer hashing; a plan built from it is only accidentally stable.

Options:
* ``flag_dict_keys`` (default false): also flag ``.keys()`` iteration.
  Python 3.7+ dicts iterate in insertion order, so ``.keys()`` is
  deterministic whenever insertion is — scope this only onto modules
  whose dicts are filled from already-suspect orders.
"""

from __future__ import annotations

import ast
from typing import List, Optional

from tools.tessalint.astutil import call_name
from tools.tessalint.findings import Finding
from tools.tessalint.passes.base import FileContext

RULE = "det"

_WALLCLOCK = {
    "time.time",
    "time.time_ns",
    "datetime.datetime.now",
    "datetime.datetime.utcnow",
    "datetime.date.today",
}
_NP_RANDOM_OK = {
    "numpy.random.default_rng",
    "numpy.random.Generator",
    "numpy.random.SeedSequence",
    "numpy.random.PCG64",
    "numpy.random.Philox",
    "numpy.random.BitGenerator",
}
_PY_RANDOM_OK = {"random.Random", "random.SystemRandom", "random.getstate", "random.setstate"}
_SET_METHODS = {"intersection", "union", "difference", "symmetric_difference"}
_ORDER_SINKS = {"list", "tuple", "enumerate", "iter", "next"}
_ORDER_SAFE = {"sorted", "len", "sum", "min", "max", "any", "all", "set", "frozenset"}


def _setish(node: ast.AST, imports) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        q = call_name(node, imports)
        if q in {"set", "frozenset"}:
            return True
        if (
            isinstance(node.func, ast.Attribute)
            and node.func.attr in _SET_METHODS
            and _setish(node.func.value, imports)
        ):
            return True
    return False


def _keysish(node: ast.AST) -> bool:
    return (
        isinstance(node, ast.Call)
        and isinstance(node.func, ast.Attribute)
        and node.func.attr == "keys"
        and not node.args
    )


def run(ctx: FileContext) -> List[Finding]:
    findings: List[Finding] = []
    flag_keys = bool(ctx.options.get("flag_dict_keys", False))

    def flag(node, message, hint, severity="P1"):
        findings.append(
            Finding(
                RULE,
                ctx.path,
                node.lineno,
                node.col_offset,
                message,
                snippet=ctx.snippet(node.lineno),
                hint=hint,
                severity=severity,
                end_line=getattr(node, "end_lineno", node.lineno) or node.lineno,
            )
        )

    def check_iter(it: ast.AST, where: str):
        if _setish(it, ctx.imports):
            flag(
                it,
                f"{where} over a set: iteration order is not deterministic",
                "wrap in sorted(...) before the order can reach a plan, "
                "or keep the collection a list",
            )
        elif flag_keys and _keysish(it):
            flag(
                it,
                f"{where} over dict.keys(): order follows insertion "
                "history, which this module does not control",
                "iterate sorted(d) instead",
                severity="P2",
            )

    parents = {}
    for parent in ast.walk(ctx.tree):
        for child in ast.iter_child_nodes(parent):
            parents[id(child)] = parent

    def _inside_sorted(node: ast.AST) -> bool:
        p: Optional[ast.AST] = parents.get(id(node))
        if isinstance(p, ast.Call):
            q = call_name(p, ctx.imports)
            return q in _ORDER_SAFE
        return False

    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call):
            q = call_name(node, ctx.imports)
            if q in _WALLCLOCK:
                flag(
                    node,
                    f"wall clock {q}() reachable from plan construction",
                    "thread an injectable clock (the scheduler's watchdog "
                    "pattern) or use simulation time",
                )
            elif q is not None and q.startswith("numpy.random."):
                if q == "numpy.random.default_rng" and not node.args and not node.keywords:
                    flag(
                        node,
                        "np.random.default_rng() without a seed draws OS "
                        "entropy",
                        "pass an explicit seed (composable child streams: "
                        "default_rng([seed, salt]))",
                    )
                elif q not in _NP_RANDOM_OK:
                    flag(
                        node,
                        f"legacy global-state RNG {q}()",
                        "use a seeded np.random.default_rng(seed) generator "
                        "threaded through the call graph",
                    )
            elif (
                q is not None
                and q.startswith("random.")
                and q not in _PY_RANDOM_OK
            ):
                flag(
                    node,
                    f"module-level stdlib RNG {q}() shares mutable global "
                    "state",
                    "construct a seeded random.Random(seed) and thread it "
                    "explicitly",
                )
            elif q in _ORDER_SINKS and node.args and not _inside_sorted(node):
                check_iter(node.args[0], f"{q}()")
        elif isinstance(node, (ast.For, ast.AsyncFor)) and not _inside_sorted(
            node.iter
        ):
            check_iter(node.iter, "for loop")
        elif isinstance(node, (ast.ListComp, ast.GeneratorExp, ast.DictComp)):
            for gen in node.generators:
                if not _inside_sorted(gen.iter):
                    check_iter(gen.iter, "comprehension")
    return findings
