"""Rule ``jit`` — hygiene of ``@jax.jit`` functions.

The fused decide() relies on jitted programs whose compiled signature is
REUSED across churn rounds (bucket padding exists for exactly this).
Three statically-checkable hazards defeat that:

* **static-arg mismatches** — ``static_argnames`` naming a parameter the
  signature does not have, or ``static_argnums`` out of range: jax
  raises at call time (or silently treats the wrong arg as static after
  a refactor reorders parameters).  P1: mechanical, always a bug.
* **mutable closure capture** — a jitted function reading module-level
  mutable state (a list/dict/set, or anything rebound via ``global``):
  the value is baked in at TRACE time, so later mutations silently
  don't apply until an unrelated retrace.  P1.
* **shape-recompile hazards** — Python ``if``/``while`` on a traced
  parameter is a trace error (or constant-folds); branching on its
  ``.shape``/``len()`` is legal but recompiles per shape.  P2 for shape
  branches (sometimes intended), P1 for direct tracer conditionals.

Detected jit forms: ``@jax.jit``, ``@jax.jit(...)``,
``@functools.partial(jax.jit, ...)`` (and the bare ``partial`` alias),
plus ``name = jax.jit(fn, ...)`` rebinding a function defined in the
same module.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from tools.tessalint.astutil import call_name
from tools.tessalint.findings import Finding
from tools.tessalint.passes.base import FileContext

RULE = "jit"

_MUTABLE_CTORS = {"list", "dict", "set", "collections.OrderedDict", "collections.defaultdict"}


def _static_spec(call: Optional[ast.Call]) -> Tuple[List[int], List[str]]:
    """Literal static_argnums / static_argnames from a jit(...) call."""
    nums: List[int] = []
    names: List[str] = []
    if call is None:
        return nums, names
    for kw in call.keywords:
        if kw.arg == "static_argnums":
            for v in _iter_literal(kw.value):
                if isinstance(v, int):
                    nums.append(v)
        elif kw.arg == "static_argnames":
            for v in _iter_literal(kw.value):
                if isinstance(v, str):
                    names.append(v)
    return nums, names


def _iter_literal(node: ast.AST):
    if isinstance(node, ast.Constant):
        yield node.value
    elif isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        for el in node.elts:
            if isinstance(el, ast.Constant):
                yield el.value


def _jit_call_of(dec: ast.AST, imports) -> Optional[Tuple[bool, Optional[ast.Call]]]:
    """(is_jit, configuring_call) for a decorator / wrapping expression."""
    q = imports.resolve(dec)
    if q == "jax.jit":
        return True, None
    if isinstance(dec, ast.Call):
        qc = call_name(dec, imports)
        if qc == "jax.jit":
            return True, dec
        if qc == "functools.partial" and dec.args:
            if imports.resolve(dec.args[0]) == "jax.jit":
                return True, dec
    return None


def _module_mutables(tree: ast.Module, imports) -> Set[str]:
    """Module-level names bound to mutable containers."""
    out: Set[str] = set()
    for stmt in tree.body:
        targets = []
        value = None
        if isinstance(stmt, ast.Assign):
            targets, value = stmt.targets, stmt.value
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            targets, value = [stmt.target], stmt.value
        for t in targets:
            if not isinstance(t, ast.Name):
                continue
            if isinstance(value, (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp, ast.SetComp)):
                out.add(t.id)
            elif isinstance(value, ast.Call) and call_name(value, imports) in _MUTABLE_CTORS:
                out.add(t.id)
    return out


def run(ctx: FileContext) -> List[Finding]:
    findings: List[Finding] = []
    mutables = _module_mutables(ctx.tree, ctx.imports)

    def flag(node, message, hint, severity="P1"):
        findings.append(
            Finding(
                RULE,
                ctx.path,
                node.lineno,
                node.col_offset,
                message,
                snippet=ctx.snippet(node.lineno),
                hint=hint,
                severity=severity,
                end_line=getattr(node, "end_lineno", node.lineno) or node.lineno,
            )
        )

    # jitted functions: decorator form + `name = jax.jit(fn)` rebinding
    defs: Dict[str, ast.FunctionDef] = {
        n.name: n
        for n in ast.walk(ctx.tree)
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
    }
    jitted: List[Tuple[ast.FunctionDef, Optional[ast.Call], ast.AST]] = []
    for fn in defs.values():
        for dec in fn.decorator_list:
            info = _jit_call_of(dec, ctx.imports)
            if info:
                jitted.append((fn, info[1], dec))
                break
    for stmt in ast.walk(ctx.tree):
        if isinstance(stmt, ast.Assign) and isinstance(stmt.value, ast.Call):
            if call_name(stmt.value, ctx.imports) == "jax.jit" and stmt.value.args:
                target_fn = stmt.value.args[0]
                if isinstance(target_fn, ast.Name) and target_fn.id in defs:
                    jitted.append((defs[target_fn.id], stmt.value, stmt.value))

    for fn, call, site in jitted:
        a = fn.args
        pos_params = [p.arg for p in [*a.posonlyargs, *a.args]]
        all_params = pos_params + [p.arg for p in a.kwonlyargs]
        nums, names = _static_spec(call)

        # --- static-arg mismatches ----------------------------------- #
        for name in names:
            if name not in all_params:
                flag(
                    site,
                    f"static_argnames names {name!r}, which is not a "
                    f"parameter of {fn.name}()",
                    f"signature: ({', '.join(all_params)})",
                )
        for num in nums:
            if not (0 <= num < len(pos_params)):
                flag(
                    site,
                    f"static_argnums index {num} out of range for "
                    f"{fn.name}() ({len(pos_params)} positional parameters)",
                    "static_argnums indexes positional parameters only",
                )
        static = set(names) | {
            pos_params[i] for i in nums if 0 <= i < len(pos_params)
        }
        traced = [p for p in all_params if p not in static and p != "self"]

        # --- mutable closure capture --------------------------------- #
        local: Set[str] = set(all_params)
        for sub in ast.walk(fn):
            if isinstance(sub, ast.Assign):
                for t in sub.targets:
                    for n in ast.walk(t):
                        if isinstance(n, ast.Name):
                            local.add(n.id)
            elif isinstance(sub, ast.Global):
                for name in sub.names:
                    flag(
                        sub,
                        f"jitted {fn.name}() declares global {name!r}: "
                        "rebinding is invisible after the first trace",
                        "pass the value as an argument instead",
                    )
        reported: Set[str] = set()
        for sub in ast.walk(fn):
            if (
                isinstance(sub, ast.Name)
                and isinstance(sub.ctx, ast.Load)
                and sub.id in mutables
                and sub.id not in local
                and sub.id not in reported
            ):
                reported.add(sub.id)
                flag(
                    sub,
                    f"jitted {fn.name}() closes over module-level mutable "
                    f"{sub.id!r}: its value is baked in at trace time",
                    "pass it as a (possibly static) argument, or make the "
                    "module binding an immutable tuple/frozenset",
                )

        # --- Python control flow on traced parameters ----------------- #
        for sub in ast.walk(fn):
            if not isinstance(sub, (ast.If, ast.While)):
                continue
            # `if x is None:` dispatch on optional args happens at trace
            # time against the Python value None — idiomatic, no hazard.
            if isinstance(sub.test, ast.Compare) and all(
                isinstance(op, (ast.Is, ast.IsNot)) for op in sub.test.ops
            ):
                continue
            for ref in ast.walk(sub.test):
                if isinstance(ref, ast.Name) and ref.id in traced:
                    parent_attr = None
                    # distinguish `x.shape...` / `len(x)` from a raw tracer
                    flag_shape = False
                    for up in ast.walk(sub.test):
                        if (
                            isinstance(up, ast.Attribute)
                            and isinstance(up.value, ast.Name)
                            and up.value.id == ref.id
                            and up.attr in ("shape", "ndim", "size", "dtype")
                        ):
                            flag_shape = True
                        if (
                            isinstance(up, ast.Call)
                            and call_name(up, ctx.imports) == "len"
                            and up.args
                            and isinstance(up.args[0], ast.Name)
                            and up.args[0].id == ref.id
                        ):
                            flag_shape = True
                    # a shape branch that only raises is trace-time input
                    # validation, not a recompile knob
                    only_raises = isinstance(sub, ast.If) and all(
                        isinstance(s, ast.Raise) for s in sub.body
                    )
                    if flag_shape and only_raises:
                        break
                    if flag_shape:
                        flag(
                            sub.test,
                            f"jitted {fn.name}() branches on the shape of "
                            f"traced parameter {ref.id!r}: recompiles for "
                            "every new shape",
                            "bucket-pad inputs to a stable signature, or "
                            "mark the driving arg static",
                            severity="P2",
                        )
                    else:
                        flag(
                            sub.test,
                            f"jitted {fn.name}() has Python control flow on "
                            f"traced parameter {ref.id!r}",
                            "use lax.cond / jnp.where, or mark the "
                            "parameter static",
                        )
                    _ = parent_attr
                    break
    return findings
