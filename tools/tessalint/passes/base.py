"""Shared pass infrastructure: one parsed file + its per-rule options."""

from __future__ import annotations

import ast
import dataclasses
from typing import List

from tools.tessalint.astutil import Imports


@dataclasses.dataclass
class FileContext:
    path: str
    source: str
    lines: List[str]
    tree: ast.Module
    imports: Imports
    options: dict  # this rule's manifest options for this file

    def snippet(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""


def scopes(tree: ast.Module):
    """Yield every function body plus the module top level as analysis
    scopes (deepest functions LAST, so callers can overwrite outer-scope
    conclusions with inner-scope ones when keying by node)."""
    yield tree
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node
