"""Pass registry: rule id → (runner, one-line description)."""

from __future__ import annotations

from tools.tessalint.passes import (
    concurrency,
    determinism,
    jit_hygiene,
    mantissa,
    sync_point,
)

#: rule id -> pass module.  The ``pragma`` meta-rule (pragma hygiene:
#: malformed/empty/unknown/unused suppressions) is implemented by the
#: runner itself, not a pass.
PASSES = {
    sync_point.RULE: sync_point,
    determinism.RULE: determinism,
    jit_hygiene.RULE: jit_hygiene,
    mantissa.RULE: mantissa,
    concurrency.RULE: concurrency,
}

DESCRIPTIONS = {
    "sync": "device→host transfers outside sanctioned readouts "
    "(the one-readout-per-round contract)",
    "det": "wall clock / unseeded RNG / set-iteration order reachable "
    "from plan construction",
    "jit": "static-arg mismatches, mutable closure capture and "
    "recompile hazards in @jax.jit functions",
    "mantissa": "unquantised floats in the fused cost-assembly graph "
    "(the 2^24 f32 exactness budget)",
    "thread": "shared-state access while the speculative-prewarm "
    "background thread may own it",
    "pragma": "suppression hygiene: malformed, reason-less, unknown or "
    "unused tessalint pragmas",
}

#: every rule id a pragma may name
ALL_RULES = tuple(PASSES) + ("pragma",)
