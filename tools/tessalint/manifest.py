"""Per-module rule scoping: which rules run where, with what options.

The manifest is a JSON file (``tools/tessalint/manifest.json`` for this
repo; ``--manifest`` overrides) of the shape::

    {
      "version": "tessalint-manifest-v1",
      "rules": {
        "<rule>": {
          "include": ["src/repro/core/fused.py", "src/repro/kernels/*.py"],
          "exclude": ["src/repro/testing/*"],
          "options": {...rule-specific...}
        }
      }
    }

Patterns are ``fnmatch``-style against the POSIX form of the scanned
path; a pattern also matches when the path merely ENDS with it
(``*/<pattern>``), so the same manifest works from the repo root, from an
absolute path, or against a fixture copy of the tree.  A rule with no
manifest entry runs nowhere — scoping is opt-in by design: every pass is
repo-specific and only meaningful on the modules whose contract it
guards.
"""

from __future__ import annotations

import dataclasses
import fnmatch
import json
from pathlib import Path, PurePosixPath
from typing import Dict, List

MANIFEST_VERSION = "tessalint-manifest-v1"
DEFAULT_MANIFEST_PATH = Path(__file__).with_name("manifest.json")


@dataclasses.dataclass
class RuleConfig:
    include: List[str] = dataclasses.field(default_factory=list)
    exclude: List[str] = dataclasses.field(default_factory=list)
    options: dict = dataclasses.field(default_factory=dict)


def _match(path: str, pattern: str) -> bool:
    return fnmatch.fnmatch(path, pattern) or fnmatch.fnmatch(path, f"*/{pattern}")


class Manifest:
    def __init__(self, rules: Dict[str, RuleConfig]):
        self.rules = rules

    @classmethod
    def load(cls, path: Path = DEFAULT_MANIFEST_PATH) -> "Manifest":
        data = json.loads(Path(path).read_text())
        if data.get("version") != MANIFEST_VERSION:
            raise ValueError(
                f"manifest version {data.get('version')!r} != {MANIFEST_VERSION!r}"
            )
        rules = {
            name: RuleConfig(
                include=list(cfg.get("include", [])),
                exclude=list(cfg.get("exclude", [])),
                options=dict(cfg.get("options", {})),
            )
            for name, cfg in data.get("rules", {}).items()
        }
        return cls(rules)

    def applies(self, rule: str, path) -> bool:
        cfg = self.rules.get(rule)
        if cfg is None:
            return False
        p = str(PurePosixPath(Path(path).as_posix()))
        if not any(_match(p, pat) for pat in cfg.include):
            return False
        return not any(_match(p, pat) for pat in cfg.exclude)

    def options(self, rule: str) -> dict:
        cfg = self.rules.get(rule)
        return dict(cfg.options) if cfg else {}
