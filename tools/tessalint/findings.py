"""Finding record + the JSON report schema (``tessalint-v1``).

A finding is one rule violation at one source location.  The JSON report
is the machine surface CI consumes: ``{"version", "rules", "findings",
"counts", "suppressed_count", "files_scanned"}`` with each finding a flat
dict that round-trips losslessly through :meth:`Finding.to_dict` /
:meth:`Finding.from_dict` (pinned by the self-test suite).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

#: schema version stamped into every JSON report
JSON_VERSION = "tessalint-v1"

#: severity ladder: P1 findings break the contract the rule guards
#: (exactness, determinism, the one-readout budget); P2 findings are
#: hygiene (recompile hazards, pragma bookkeeping).
SEVERITIES = ("P1", "P2")


@dataclasses.dataclass
class Finding:
    rule: str          # rule id, e.g. "sync"
    path: str          # file path as scanned
    line: int          # 1-based line of the offending node
    col: int           # 0-based column
    message: str       # what is wrong
    snippet: str = ""  # the stripped source line
    hint: str = ""     # how to fix (or how to suppress legitimately)
    severity: str = "P1"
    suppressed: bool = False       # True when a pragma covers it
    suppress_reason: str = ""      # the pragma's (reason) text
    #: last line of the flagged node — pragmas anywhere in
    #: [line, end_line] suppress the finding (multi-line calls put the
    #: pragma on whichever physical line survives reformatting).
    end_line: int = 0

    def __post_init__(self):
        if self.end_line < self.line:
            self.end_line = self.line
        if self.severity not in SEVERITIES:
            raise ValueError(f"severity must be one of {SEVERITIES}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "Finding":
        return cls(**d)

    def format_text(self) -> str:
        loc = f"{self.path}:{self.line}:{self.col}"
        out = f"{loc} [{self.rule}/{self.severity}] {self.message}"
        if self.snippet:
            out += f"\n    | {self.snippet}"
        if self.hint:
            out += f"\n    hint: {self.hint}"
        if self.suppressed:
            out += f"\n    suppressed: {self.suppress_reason}"
        return out


def report(
    findings: List[Finding], rules: List[str], files_scanned: int
) -> dict:
    """The ``tessalint-v1`` JSON report for one run.  ``findings`` holds
    only UNSUPPRESSED findings; suppressed ones are counted."""
    active = [f for f in findings if not f.suppressed]
    counts: Dict[str, int] = {}
    for f in active:
        counts[f.rule] = counts.get(f.rule, 0) + 1
    return {
        "version": JSON_VERSION,
        "rules": sorted(rules),
        "findings": [f.to_dict() for f in active],
        "counts": counts,
        "suppressed_count": sum(1 for f in findings if f.suppressed),
        "files_scanned": files_scanned,
    }
