"""CLI: ``python -m tools.tessalint src/`` (or the ``tessalint`` console
script).  Exit code 0 = clean (pragma-suppressed findings allowed),
1 = unsuppressed findings, 2 = usage error."""

from __future__ import annotations

import argparse
import json
import sys

from tools.tessalint.manifest import DEFAULT_MANIFEST_PATH
from tools.tessalint.passes import ALL_RULES, DESCRIPTIONS
from tools.tessalint.runner import run_paths


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="tessalint",
        description="JAX-aware static analysis for the Tesserae repo: "
        "device residency, determinism, jit hygiene, mantissa budget, "
        "prewarm threading.",
    )
    ap.add_argument("paths", nargs="+", help="files or directories to lint")
    ap.add_argument(
        "--format", choices=("text", "json"), default="text", dest="fmt"
    )
    ap.add_argument(
        "--manifest",
        default=str(DEFAULT_MANIFEST_PATH),
        help="rule-scoping manifest (default: the repo manifest)",
    )
    ap.add_argument(
        "--rules",
        default=None,
        help="comma-separated rule subset (default: all): "
        + ",".join(ALL_RULES),
    )
    ap.add_argument(
        "--show-suppressed",
        action="store_true",
        help="also print pragma-suppressed findings (text format)",
    )
    args = ap.parse_args(argv)

    rules = None
    if args.rules:
        rules = [r.strip() for r in args.rules.split(",") if r.strip()]
        unknown = [r for r in rules if r not in ALL_RULES]
        if unknown:
            print(f"unknown rule(s): {', '.join(unknown)}", file=sys.stderr)
            return 2

    try:
        rep, findings = run_paths(args.paths, manifest_path=args.manifest, rules=rules)
    except (FileNotFoundError, ValueError) as e:
        print(f"tessalint: {e}", file=sys.stderr)
        return 2

    if args.fmt == "json":
        print(json.dumps(rep, indent=2, sort_keys=True))
    else:
        shown = [
            f
            for f in findings
            if not f.suppressed or args.show_suppressed
        ]
        for f in sorted(shown, key=lambda f: (f.path, f.line, f.col)):
            print(f.format_text())
        n = len(rep["findings"])
        print(
            f"tessalint: {n} finding{'s' if n != 1 else ''} "
            f"({rep['suppressed_count']} suppressed) in "
            f"{rep['files_scanned']} files"
        )
        if n:
            print("rules: " + ", ".join(f"{k}: {DESCRIPTIONS[k]}" for k in rep["counts"]))
    return 1 if rep["findings"] else 0


if __name__ == "__main__":
    sys.exit(main())
