"""tessalint — a JAX-aware static-analysis suite for the Tesserae repo.

Five AST passes enforce the contracts the CI gates only catch
dynamically (and flakily): device residency (``sync``), bit-identical
determinism (``det``), jit hygiene (``jit``), the f32 cost-exactness
budget (``mantissa``) and the prewarm threading contract (``thread``) —
plus a ``pragma`` meta-rule keeping the suppressions themselves honest.

Usage::

    python -m tools.tessalint src/ [--format json] [--rules sync,det]

Public API: :func:`tools.tessalint.runner.run_paths`,
:class:`tools.tessalint.findings.Finding`,
:class:`tools.tessalint.manifest.Manifest`.
"""

from tools.tessalint.findings import JSON_VERSION, Finding
from tools.tessalint.manifest import Manifest
from tools.tessalint.runner import lint_file, run_paths

__version__ = "1.0.0"

__all__ = ["Finding", "JSON_VERSION", "Manifest", "lint_file", "run_paths", "__version__"]
