"""Test-suite bootstrap.

1. Make ``repro`` importable even when neither ``PYTHONPATH=src`` nor the
   ``pythonpath`` pytest ini option took effect (e.g. pytest invoked from
   another directory).
2. Gate the optional ``hypothesis`` dependency: in hermetic containers
   where it cannot be installed, install the API-compatible fallback from
   :mod:`repro.testing.hypothesis_fallback` so the 4 property-test modules
   still collect and run as seeded random property checks.
"""

import os
import sys

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), os.pardir, "src"))
if os.path.isdir(_SRC) and _SRC not in (os.path.abspath(p) for p in sys.path):
    sys.path.insert(0, _SRC)

try:  # real hypothesis wins whenever it is installed (CI installs it)
    import hypothesis  # noqa: F401
except ImportError:
    from repro.testing import hypothesis_fallback

    hypothesis_fallback.install()
