"""Test-suite bootstrap.

1. Make ``repro`` importable even when neither ``PYTHONPATH=src`` nor the
   ``pythonpath`` pytest ini option took effect (e.g. pytest invoked from
   another directory).
2. Force a multi-device CPU topology BEFORE jax initialises: the fused
   sharded ``decide()`` parity suite (tests/test_fused_decide.py) builds
   1/2/8-device meshes from these forced host devices, so the shard_map
   fan-out is validated in-process without a TPU (the SNIPPETS.md
   ``--xla_force_host_platform_device_count`` idiom).  Single-device
   semantics are unchanged — arrays still default to device 0 — and an
   XLA_FLAGS value that already pins a device count (e.g. the fused-smoke
   CI lane) wins.
3. Gate the optional ``hypothesis`` dependency: in hermetic containers
   where it cannot be installed, install the API-compatible fallback from
   :mod:`repro.testing.hypothesis_fallback` so the 4 property-test modules
   still collect and run as seeded random property checks.  CI installs
   the real package from requirements.txt, so under ``CI=...`` a missing
   hypothesis is a broken environment and the shim must NOT paper over it
   — the import error is re-raised there (set
   ``REPRO_ALLOW_HYPOTHESIS_FALLBACK=1`` to override, e.g. for a
   deliberately-offline CI lane).
"""

import os
import sys

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8"
    ).strip()

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), os.pardir, "src"))
if os.path.isdir(_SRC) and _SRC not in (os.path.abspath(p) for p in sys.path):
    sys.path.insert(0, _SRC)

try:  # real hypothesis wins whenever it is installed (CI installs it)
    import hypothesis  # noqa: F401
except ImportError:
    if os.environ.get("CI") and not os.environ.get(
        "REPRO_ALLOW_HYPOTHESIS_FALLBACK"
    ):
        raise  # CI must run the real property tests, not the shim
    from repro.testing import hypothesis_fallback

    hypothesis_fallback.install()
