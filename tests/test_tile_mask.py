"""Shared ragged-edge tile masking helper: unit tests + cross-kernel parity
on padding-edge shapes (the helper is the one implementation behind both
``flash_decode``'s valid-length mask and the ``lap_bid`` family's
padding-free column masking)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.tile_mask import mask_ragged_cols, tile_col_ids


class TestHelper:
    def test_col_ids_offset(self):
        ids = np.asarray(tile_col_ids((2, 4), 8))
        np.testing.assert_array_equal(ids, [[8, 9, 10, 11]] * 2)

    @pytest.mark.parametrize("valid", [0, 1, 3, 4])
    def test_mask_static_valid(self, valid):
        x = jnp.arange(8.0).reshape(2, 4)
        got = np.asarray(mask_ragged_cols(x, 0, valid, -1.0))
        want = np.array(x)
        want[:, valid:] = -1.0
        np.testing.assert_array_equal(got, want)

    def test_mask_with_tile_offset(self):
        # tile holding global columns [4, 8) with 6 valid columns total:
        # local columns 0-1 stay, 2-3 are masked
        x = jnp.ones((3, 4))
        got = np.asarray(mask_ragged_cols(x, 4, 6, 0.0))
        np.testing.assert_array_equal(got[:, :2], 1.0)
        np.testing.assert_array_equal(got[:, 2:], 0.0)

    def test_traced_valid_len(self):
        # valid_cols may be a traced scalar (the flash_decode SMEM path)
        def f(x, vl):
            return mask_ragged_cols(x, 0, vl, -9.0)

        got = np.asarray(jax.jit(f)(jnp.ones((2, 5)), jnp.asarray(3)))
        assert (got[:, :3] == 1.0).all() and (got[:, 3:] == -9.0).all()

    def test_3d_tile(self):
        x = jnp.ones((1, 2, 6))
        got = np.asarray(mask_ragged_cols(x, 0, 4, 0.0))
        assert got[0, :, :4].all() and not got[0, :, 4:].any()


class TestSharedEdgeParity:
    """The two consumers must agree with their pure-jnp oracles on shapes
    that land exactly on / one off the tile boundaries."""

    @pytest.mark.parametrize("m", [127, 128, 129, 511, 512, 513])
    def test_lap_bid_padding_edges(self, m):
        from repro.core.matching.auction import _top2
        from repro.kernels.lap_bid import lap_bid_pallas

        rng = np.random.default_rng(m)
        a = jnp.asarray(rng.normal(size=(9, m)), jnp.float32)
        p = jnp.asarray(rng.normal(size=(m,)), jnp.float32)
        bv, bj, sv = lap_bid_pallas(a, p, interpret=True)
        rv, rj, rsv = _top2(a - p[None, :])
        np.testing.assert_allclose(bv, rv, rtol=1e-6)
        np.testing.assert_array_equal(bj, rj)
        np.testing.assert_allclose(sv, rsv, rtol=1e-6)

    @pytest.mark.parametrize("s", [511, 512, 513, 1023])
    def test_flash_decode_valid_len_edges(self, s):
        from repro.kernels import ref
        from repro.kernels.flash_decode import flash_decode_pallas

        rng = np.random.default_rng(s)
        q = jnp.asarray(rng.normal(size=(1, 4, 64)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, s, 2, 64)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, s, 2, 64)), jnp.float32)
        for vl in [1, s // 2, s]:
            got = flash_decode_pallas(q, k, v, jnp.asarray(vl), interpret=True)
            want = ref.flash_decode(q, k, v, jnp.asarray(vl))
            np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_zero_padding_never_wins_bid(self):
        """The padding-free contract: zero-padded columns past the ragged
        edge must never appear as best/second even when every real benefit
        is strictly negative (zeros would otherwise win)."""
        from repro.kernels.lap_bid import lap_bid_pallas

        a = jnp.full((4, 130), -5.0)  # pads to 512 cols with zeros
        p = jnp.zeros((130,))
        bv, bj, sv = lap_bid_pallas(a, p, interpret=True)
        assert (np.asarray(bj) < 130).all()
        np.testing.assert_allclose(bv, -5.0)
        np.testing.assert_allclose(sv, -5.0)
