"""Migration algorithm tests — including the paper's worked Examples 2-5."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cluster import ClusterSpec, PlacementPlan, count_migrations
from repro.core.migration import (
    node_level_matching,
    pairwise_migration_cost,
    plan_migration,
    plan_migration_batched_auction,
    _weight_lookup,
)


def _single_node_plan(cluster, gpu_jobs):
    """gpu_jobs: list over GPUs of tuple-of-job-ids (paper example format)."""
    plan = PlacementPlan(cluster)
    for gpu, jobs in enumerate(gpu_jobs):
        if isinstance(jobs, int):
            jobs = (jobs,)
        for j in jobs:
            plan.place_job(j, [gpu])
    return plan


class TestPaperExamples:
    """Appendix A, Examples 2-4 (single 4-GPU node) and Example 5."""

    def test_example_2(self):
        cluster = ClusterSpec(1, 4)
        p_i = _single_node_plan(cluster, [1, 2, 3, 4])
        p_j = _single_node_plan(cluster, [4, 1, 2, 3])
        num_gpus = {j: 1 for j in [1, 2, 3, 4]}
        weights = _weight_lookup(num_gpus)
        cost = pairwise_migration_cost(p_i.slots[0], p_j.slots[0], weights)
        expected = np.array(
            [[1, 0, 1, 1], [1, 1, 0, 1], [1, 1, 1, 0], [0, 1, 1, 1]], dtype=float
        )
        np.testing.assert_allclose(cost, expected)
        c, _ = node_level_matching(p_i.slots[0], p_j.slots[0], num_gpus)
        assert c == 0.0
        res = plan_migration(p_i, p_j, num_gpus)
        assert res.num_migrations == 0

    def test_example_3(self):
        cluster = ClusterSpec(1, 4)
        p_i = _single_node_plan(cluster, [(1, 5), (2,), (3,), (4,)])
        p_j = _single_node_plan(cluster, [(4, 5), (1,), (2,), (3,)])
        num_gpus = {j: 1 for j in [1, 2, 3, 4, 5]}
        weights = _weight_lookup(num_gpus)
        cost = pairwise_migration_cost(p_i.slots[0], p_j.slots[0], weights)
        expected = np.array(
            [
                [1.0, 0.5, 1.5, 1.5],
                [1.5, 1.0, 0.0, 1.0],
                [1.5, 1.0, 1.0, 0.0],
                [0.5, 1.0, 1.0, 1.0],
            ]
        )
        np.testing.assert_allclose(cost, expected)
        c, _ = node_level_matching(p_i.slots[0], p_j.slots[0], num_gpus)
        assert c == 1.0  # job 5 relocates from (co-1) to (co-4)
        res = plan_migration(p_i, p_j, num_gpus)
        assert res.num_migrations == 1

    def test_example_4(self):
        cluster = ClusterSpec(1, 4)
        p_i = _single_node_plan(cluster, [(1, 6), (2,), (3,), (4,)])
        p_j = _single_node_plan(cluster, [(4, 5), (1,), (2,), (3,)])
        num_gpus = {j: 1 for j in [1, 2, 3, 4, 5, 6]}
        res = plan_migration(p_i, p_j, num_gpus)
        # jobs 5 and 6 are not in both rounds -> removed; remaining jobs 1-4
        # permute with zero migrations.
        assert res.matching_cost == 0.0
        assert res.num_migrations == 0

    def test_example_5_consolidation(self):
        """Flat (Alg. 5) matching may scatter a packed plan; node-level
        (Alg. 2+3) must keep every job consolidated."""
        cluster = ClusterSpec(2, 4)
        p_i = PlacementPlan(cluster)
        p_i.place_job(1, [0, 1, 2, 3])       # node 0
        p_i.place_job(2, [4, 5, 6, 7])       # node 1
        p_j = PlacementPlan(cluster)
        p_j.place_job(1, [0, 1, 2, 3])       # packed on node 0
        p_j.place_job(2, [0, 1, 2, 3])
        num_gpus = {1: 4, 2: 4}
        res = plan_migration(p_i, p_j, num_gpus, algorithm="node")
        assert res.physical_plan.is_consolidated(1)
        assert res.physical_plan.is_consolidated(2)

    def test_fig1_cross_node_renaming(self):
        """Fig. 1: Gavel's policy migrates 3 jobs; GPU-ID remapping needs 0."""
        cluster = ClusterSpec(2, 2)
        p_i = _mk(cluster, {1: [0, 1], 2: [2], 3: [3]})
        # logical new plan: same jobs, nodes swapped
        p_j = _mk(cluster, {1: [2, 3], 2: [0], 3: [1]})
        num_gpus = {1: 2, 2: 1, 3: 1}
        baseline = plan_migration(p_i, p_j, num_gpus, algorithm="none")
        ours = plan_migration(p_i, p_j, num_gpus, algorithm="node")
        assert baseline.num_migrations == 3
        assert ours.num_migrations == 0


def _mk(cluster, placements):
    plan = PlacementPlan(cluster)
    for j, gpus in placements.items():
        plan.place_job(j, gpus)
    return plan


def _random_plans(rng, num_nodes=4, gpn=4, n_jobs=10):
    """Two random consolidated single-GPU-granularity plans over shared jobs."""
    cluster = ClusterSpec(num_nodes, gpn)
    num_gpus_of = {}
    plans = []
    for _ in range(2):
        plan = PlacementPlan(cluster)
        free = {n: list(range(gpn)) for n in range(num_nodes)}
        for j in range(n_jobs):
            g = int(rng.choice([1, 2, 4], p=[0.6, 0.3, 0.1]))
            num_gpus_of[j] = g
            nodes = [n for n in free if len(free[n]) >= g]
            if not nodes:
                continue
            node = nodes[int(rng.integers(len(nodes)))]
            locs = free[node][:g]
            free[node] = free[node][g:]
            plan.place_job(j, [cluster.gpu_id(node, l) for l in locs])
        plans.append(plan)
    return cluster, plans[0], plans[1], num_gpus_of


class TestMigrationProperties:
    @given(st.integers(0, 2**32 - 1))
    @settings(max_examples=40, deadline=None)
    def test_matching_cost_never_worse_than_identity(self, seed):
        """The invariant Algorithm 2 actually guarantees: its Hungarian
        COST is <= the identity (no-remap) assignment's cost.  The integer
        migration COUNT (Def. 1) can occasionally exceed no-remap's when a
        multi-GPU job moves partially (fractional cost < 1 but it counts as
        one migration) — hypothesis found such a case (seed 11240); see
        migration.py docstring."""
        rng = np.random.default_rng(seed)
        cluster, p_i, p_j, num_gpus_of = _random_plans(rng)
        node = plan_migration(p_i, p_j, num_gpus_of, algorithm="node")
        # identity assignment cost: node l stays on node l, GPU v on GPU v
        common = p_i.job_ids() & p_j.job_ids()
        pi = p_i.restricted_to(common)
        pj = p_j.restricted_to(common)
        weights = _weight_lookup(num_gpus_of)
        identity_cost = 0.0
        for n in range(cluster.num_nodes):
            c = pairwise_migration_cost(pi.slots[n], pj.slots[n], weights)
            identity_cost += float(np.trace(c))
        assert node.matching_cost <= identity_cost + 1e-9

    @given(st.integers(0, 2**32 - 1))
    @settings(max_examples=40, deadline=None)
    def test_migration_count_close_to_no_remap(self, seed):
        """Count can exceed no-remap only via partial multi-GPU moves; it
        must stay within the number of multi-GPU jobs of the optimum."""
        rng = np.random.default_rng(seed)
        cluster, p_i, p_j, num_gpus_of = _random_plans(rng)
        base = plan_migration(p_i, p_j, num_gpus_of, algorithm="none")
        node = plan_migration(p_i, p_j, num_gpus_of, algorithm="node")
        multi = sum(1 for g in num_gpus_of.values() if g > 1)
        assert node.num_migrations <= base.num_migrations + multi

    @given(st.integers(0, 2**32 - 1))
    @settings(max_examples=40, deadline=None)
    def test_physical_plan_preserves_jobs_and_consolidation(self, seed):
        rng = np.random.default_rng(seed)
        cluster, p_i, p_j, num_gpus_of = _random_plans(rng)
        res = plan_migration(p_i, p_j, num_gpus_of, algorithm="node")
        # same jobs with same GPU counts
        new_map = res.physical_plan.job_gpu_map()
        old_map = p_j.job_gpu_map()
        assert set(new_map) == set(old_map)
        for j, gpus in new_map.items():
            assert len(gpus) == len(old_map[j])
            assert res.physical_plan.is_consolidated(j)

    @given(st.integers(0, 2**32 - 1))
    @settings(max_examples=15, deadline=None)
    def test_flat_not_better_than_node_for_these(self, seed):
        """Alg 5 optimises the same objective without node structure, so its
        matching cost is <= node-level; but it may break consolidation."""
        rng = np.random.default_rng(seed)
        cluster, p_i, p_j, num_gpus_of = _random_plans(rng)
        node = plan_migration(p_i, p_j, num_gpus_of, algorithm="node")
        flat = plan_migration(p_i, p_j, num_gpus_of, algorithm="flat")
        assert flat.matching_cost <= node.matching_cost + 1e-9

    def test_identical_plans_zero(self):
        rng = np.random.default_rng(7)
        cluster, p_i, _, num_gpus_of = _random_plans(rng)
        res = plan_migration(p_i, p_i.copy(), num_gpus_of)
        assert res.num_migrations == 0
        assert res.matching_cost == 0.0


class TestBatchedAuctionMigration:
    @given(st.integers(0, 2**32 - 1))
    @settings(max_examples=10, deadline=None)
    def test_matches_hungarian_cost(self, seed):
        rng = np.random.default_rng(seed)
        cluster, p_i, p_j, num_gpus_of = _random_plans(rng, num_nodes=3, gpn=2, n_jobs=6)
        hung = plan_migration(p_i, p_j, num_gpus_of, algorithm="node")
        auct = plan_migration_batched_auction(p_i, p_j, num_gpus_of)
        # optimality of the batched auction == Hungarian on the SAME cost
        assert np.isclose(auct.matching_cost, hung.matching_cost)
        # count may differ from no-remap by partial multi-GPU moves (see
        # migration.py semantic note); bound it like the Hungarian test
        base = plan_migration(p_i, p_j, num_gpus_of, algorithm="none")
        multi = sum(1 for g in num_gpus_of.values() if g > 1)
        assert auct.num_migrations <= base.num_migrations + multi


class TestStragglerDrainPenalties:
    """Health terms in the relabelling benefit: the straggler-drain
    penalty drains degraded nodes through the SAME matching layer the
    rack/type terms use — half-unit quantised, occupied-rows only, and
    a no-op (None) on healthy clusters (the seed bit-identity)."""

    def test_healthy_speeds_add_no_term(self):
        from repro.core.migration import _relabel_penalties

        cluster = ClusterSpec(4, 4)
        assert _relabel_penalties(cluster) is None
        assert _relabel_penalties(cluster, speed_factor=np.ones(4)) is None

    def test_penalties_are_half_unit_quantised_and_targeted(self):
        from repro.core.migration import _relabel_penalties

        cluster = ClusterSpec(4, 4)
        speed = np.array([1.0, 0.37, 0.9, 1.0])
        occ = np.array([True, True, False, False])
        pen = _relabel_penalties(cluster, occupied_logical=occ,
                                 speed_factor=speed)
        assert pen is not None
        # exactness contract of the auction backends: multiples of 0.5
        np.testing.assert_array_equal(pen * 2.0, np.round(pen * 2.0))
        # only occupied logical columns are penalised, only slow rows pay
        assert np.all(pen[:, ~occ] == 0.0)
        assert np.all(pen[[0, 3], :] == 0.0)
        assert np.all(pen[1, occ] > 0.0)
        # deeper degradation, steeper penalty
        assert pen[1, 0] > pen[2, 0] > 0.0

    def test_drain_relabels_onto_spare_healthy_node(self):
        cluster = ClusterSpec(2, 4)
        prev = _mk(cluster, {0: [0, 1, 2, 3]})
        new = _mk(cluster, {0: [0, 1, 2, 3]})
        res = plan_migration(prev, new, {0: 4}, algorithm="node",
                             speed_factor=np.array([0.4, 1.0]))
        assert set(res.physical_plan.job_gpu_map()[0]) == {4, 5, 6, 7}
        assert res.num_migrations == 1
        # full-speed cluster: untouched (bit-identical seed path)
        res2 = plan_migration(prev, new, {0: 4}, algorithm="node",
                              speed_factor=np.ones(2))
        assert set(res2.physical_plan.job_gpu_map()[0]) == {0, 1, 2, 3}
        assert res2.num_migrations == 0
