"""Chaos differential suite: fault injection, the graceful-degradation
ladder, targeted warm-state invalidation and crash-resume.

The bulk test drives 200+ seeded failure sequences through the simulator
and asserts per-round safety invariants via the ``round_hook``:

* no placement ever touches a down node,
* gangs stay intact and per-GPU capacity (MAX_PACK) is respected,
* retry budgets are bounded,
* no job is lost — every job either completes or is accounted as a
  terminal failure.

The zero-failure configuration is asserted bit-identical to the seed
path, the ladder is forced step by step with an injected clock, the fused
planner's forced host fallback is checked bit-identical against the host
planner, and a killed-and-restored simulation must finish bit-identical
to an uninterrupted one.
"""

import numpy as np
import pytest

from repro.core.cluster import MAX_PACK, ClusterHealth, ClusterSpec
from repro.core.faults import (
    EVENT_KINDS,
    GPU_DEGRADE,
    JOB_FAIL,
    NODE_DOWN,
    NODE_UP,
    FailureEvent,
)
from repro.core.jobs import JobSpec
from repro.core.matching import MatchContext
from repro.core.matching.engine import solve_lap_batched
from repro.core.policies import FailureAwarePolicy, TiresiasPolicy
from repro.core.profiler import ThroughputProfile
from repro.core.scheduler import DegradeReason, TesseraeScheduler
from repro.core.simulator import SimConfig, Simulator
from repro.core.traces import TABLE1_MODELS, shockwave_trace
from repro.workloads import from_jobspecs
from repro.workloads.failures import (
    FailureRecipe,
    GpuDegradations,
    JobFailures,
    NodeOutages,
    generate_failures,
)

ROUND = 360.0


@pytest.fixture(scope="module")
def profile():
    return ThroughputProfile()


def _scheduler(cluster, profile, **kw):
    kw.setdefault("lap_backend", "numpy")
    kw.setdefault("migration_algorithm", "node")
    return TesseraeScheduler(cluster, TiresiasPolicy(profile), profile, **kw)


def _tiny_trace(profile, num_jobs, seed, max_rounds=6):
    """Jobs sized in ROUNDS (not hours) so chaos sims stay fast."""
    rng = np.random.default_rng([seed, 0xC4A05])
    specs = []
    for i in range(num_jobs):
        model = TABLE1_MODELS[int(rng.integers(len(TABLE1_MODELS)))]
        gpus = int(rng.choice([1, 1, 2, 4]))
        rate = profile.isolated(model, gpus, "dp")
        rounds = 2 + int(rng.integers(max_rounds))
        specs.append(
            JobSpec(
                job_id=i,
                model=model,
                num_gpus=gpus,
                total_iters=rate * ROUND * rounds,
                arrival_time=float(rng.integers(0, 6)) * ROUND,
            )
        )
    return specs


def _fingerprint(res):
    """The decision-relevant outcome of a run (no wall times)."""
    return {
        "jobs": {
            jid: (s.finish_time, s.iters_done, s.migrations, s.retries, s.failed)
            for jid, s in res.jobs.items()
        },
        "makespan": res.makespan_s,
        "migrations": res.total_migrations,
        "rounds": res.num_rounds,
        "degrade": tuple(res.degrade_rounds),
        "preemptions": res.preemptions,
    }


class _RecordingSim(Simulator):
    """Simulator that logs every crash as ``(job_id, retries_after,
    crash_time, eligible_time, terminal)`` so tests can pin the realised
    backoff schedule against ``backoff_base_s * factor ** (retries-1)``."""

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self.crash_log = []

    def _crash_job(self, st, s, preempt):
        super()._crash_job(st, s, preempt)
        self.crash_log.append(
            (s.job_id, s.retries, st.now, s.eligible_time, s.failed)
        )


# --------------------------------------------------------------------------- #
# FailureEvent schema
# --------------------------------------------------------------------------- #
class TestFailureEvents:
    def test_validation(self):
        with pytest.raises(ValueError):
            FailureEvent(0.0, "meteor-strike", node=0)
        with pytest.raises(ValueError):
            FailureEvent(-1.0, NODE_DOWN, node=0)
        with pytest.raises(ValueError):
            FailureEvent(0.0, NODE_DOWN)  # node required
        with pytest.raises(ValueError):
            FailureEvent(0.0, JOB_FAIL)  # job_id required
        with pytest.raises(ValueError):
            FailureEvent(0.0, GPU_DEGRADE, node=0, factor=0.0)
        with pytest.raises(ValueError):
            FailureEvent(0.0, GPU_DEGRADE, node=0, factor=1.5)

    def test_sort_key_total_order(self):
        evs = [
            FailureEvent(10.0, NODE_UP, node=1),
            FailureEvent(10.0, NODE_DOWN, node=0),
            FailureEvent(5.0, JOB_FAIL, job_id=3),
        ]
        ordered = sorted(evs, key=FailureEvent.sort_key)
        assert ordered[0].kind == JOB_FAIL
        # at equal times, downs sort before ups
        assert ordered[1].kind == NODE_DOWN and ordered[2].kind == NODE_UP
        assert EVENT_KINDS.index(NODE_DOWN) < EVENT_KINDS.index(NODE_UP)

    def test_dict_round_trip(self):
        ev = FailureEvent(12.5, GPU_DEGRADE, node=3, factor=0.5)
        assert FailureEvent.from_dict(ev.to_dict()) == ev
        assert "job_id" not in ev.to_dict()  # Nones dropped
        with pytest.raises(ValueError):
            FailureEvent.from_dict({"time_s": 0.0, "kind": NODE_DOWN, "node": 0,
                                    "blast_radius": 2})


# --------------------------------------------------------------------------- #
# Generators
# --------------------------------------------------------------------------- #
class TestFailureGenerators:
    def test_deterministic(self, profile):
        cluster = ClusterSpec(4, 4)
        rows = from_jobspecs(shockwave_trace(num_jobs=20, seed=0, profile=profile))
        recipe = FailureRecipe.helios_like()
        a = generate_failures(recipe, cluster, 36_000.0, seed=7, trace=rows)
        b = generate_failures(recipe, cluster, 36_000.0, seed=7, trace=rows)
        assert a == b
        c = generate_failures(recipe, cluster, 36_000.0, seed=8, trace=rows)
        assert a != c

    def test_axes_compose_without_crosstalk(self, profile):
        """Enabling the job axis must not perturb the node axis' draws."""
        cluster = ClusterSpec(4, 4)
        rows = from_jobspecs(shockwave_trace(num_jobs=20, seed=0, profile=profile))
        nodes_only = generate_failures(
            FailureRecipe(nodes=NodeOutages(mtbf_h=1.0)),
            cluster, 36_000.0, seed=3,
        )
        full = generate_failures(
            FailureRecipe(nodes=NodeOutages(mtbf_h=1.0), jobs=JobFailures()),
            cluster, 36_000.0, seed=3, trace=rows,
        )
        node_events = [e for e in full if e.kind in (NODE_DOWN, NODE_UP)]
        assert node_events == nodes_only

    def test_horizon_and_pairing(self):
        cluster = ClusterSpec(8, 4)
        evs = generate_failures(
            FailureRecipe(nodes=NodeOutages(mtbf_h=0.5), gpus=GpuDegradations(
                rate_per_node_per_day=48.0)),
            cluster, 7200.0, seed=0,
        )
        assert evs == sorted(evs, key=FailureEvent.sort_key)
        assert all(e.time_s < 7200.0 for e in evs)
        # every node sees at most one more DOWN than UP (open outage at
        # the horizon), never the reverse
        for n in range(8):
            downs = sum(1 for e in evs if e.kind == NODE_DOWN and e.node == n)
            ups = sum(1 for e in evs if e.kind == NODE_UP and e.node == n)
            assert downs - ups in (0, 1)


# --------------------------------------------------------------------------- #
# The 200-seed chaos bulk
# --------------------------------------------------------------------------- #
class TestChaosInvariants:
    NUM_SEEDS = 200

    def test_chaos_invariants_bulk(self, profile):
        totals = {"events": 0, "preempt": 0, "retries": 0, "failed": 0,
                  "crashes": 0}
        for seed in range(self.NUM_SEEDS):
            rng = np.random.default_rng([seed, 0xC4A06])
            num_nodes = 2 + seed % 3
            cluster = ClusterSpec(num_nodes, 4)
            trace = _tiny_trace(profile, 5 + seed % 4, seed)
            horizon = 40 * ROUND
            events = generate_failures(
                FailureRecipe(
                    nodes=NodeOutages(
                        mtbf_h=0.3 + 0.2 * (seed % 4),
                        repair_median_s=600.0,
                        repair_sigma=0.5,
                    ),
                    gpus=GpuDegradations(rate_per_node_per_day=24.0)
                    if seed % 3 == 0
                    else None,
                ),
                cluster, horizon, seed,
            )
            # per-job software failures, directly authored
            for s in trace:
                if rng.random() < 0.3:
                    events.append(FailureEvent(
                        s.arrival_time + float(rng.uniform(0, 8 * ROUND)),
                        JOB_FAIL, job_id=s.job_id,
                    ))
            events.sort(key=FailureEvent.sort_key)

            cfg = SimConfig(
                max_time_s=200 * ROUND,
                max_retries=3,
                backoff_base_s=ROUND,
                checkpoint_interval_s=2 * ROUND,
            )
            sched = _scheduler(cluster, profile)

            def hook(round_idx, now, decision, states, health,
                     cluster=cluster, cfg=cfg, seed=seed):
                gmap = decision.plan.job_gpu_map()
                per_gpu = {}
                for jid, gpus in gmap.items():
                    s = states[jid]
                    assert len(gpus) == s.num_gpus, (
                        f"seed {seed}: gang of job {jid} broken"
                    )
                    # backoff eligibility: the decision was taken at
                    # now - round (the hook fires after the clock advanced);
                    # a job still inside its backoff window is never placed
                    assert s.eligible_time <= now - cfg.round_duration_s + 1e-9, (
                        f"seed {seed} round {round_idx}: job {jid} placed "
                        f"before its backoff expired"
                    )
                    for g in gpus:
                        node = cluster.node_of(g)
                        assert health.up[node], (
                            f"seed {seed} round {round_idx}: job {jid} "
                            f"placed on down node {node}"
                        )
                        per_gpu[g] = per_gpu.get(g, 0) + 1
                assert all(v <= MAX_PACK for v in per_gpu.values()), (
                    f"seed {seed}: GPU capacity exceeded"
                )
                for s in states.values():
                    assert s.retries <= cfg.max_retries + 1, (
                        f"seed {seed}: retry budget exceeded on job {s.job_id}"
                    )

            sim = _RecordingSim(
                cluster, trace, sched, profile, cfg,
                failures=events, round_hook=hook,
            )
            res = sim.run()

            # realised backoff schedule: every non-terminal crash sets
            # eligibility exactly backoff_base * factor**(retries-1) out
            for jid, retries, t_crash, elig, failed in sim.crash_log:
                if failed:
                    continue
                expected = t_crash + cfg.backoff_base_s * (
                    cfg.backoff_factor ** (retries - 1)
                )
                assert elig == pytest.approx(expected), (
                    f"seed {seed}: job {jid} backoff #{retries} off-schedule"
                )
            totals["crashes"] += len(sim.crash_log)

            # no job lost: everything completed or is a terminal failure
            for jid, s in res.jobs.items():
                assert s.finished, f"seed {seed}: job {jid} never finished"
                if s.failed:
                    assert s.retries == cfg.max_retries + 1
                    assert jid in res.failed_jobs
                else:
                    assert s.iters_done >= s.spec.total_iters - 1e-6, (
                        f"seed {seed}: job {jid} short of its work"
                    )
            assert res.lost_iters_total >= 0.0
            assert len(res.degrade_rounds) == res.num_rounds
            totals["events"] += res.fault_events_applied
            totals["preempt"] += res.preemptions
            totals["retries"] += res.retries_total
            totals["failed"] += len(res.failed_jobs)
        # the sweep must actually exercise the machinery, not dodge it
        assert totals["events"] > self.NUM_SEEDS
        assert totals["preempt"] > 0
        assert totals["failed"] > 0
        assert totals["retries"] >= totals["preempt"]
        assert totals["crashes"] == totals["retries"]


# --------------------------------------------------------------------------- #
# Zero-failure bit-identity with the seed path
# --------------------------------------------------------------------------- #
class TestZeroFailureIdentity:
    def _run(self, profile, **kw):
        cluster = ClusterSpec(3, 4)
        trace = shockwave_trace(num_jobs=18, seed=2, profile=profile)
        sched = _scheduler(cluster, profile)
        return Simulator(cluster, trace, sched, profile, SimConfig(), **kw).run()

    def test_no_failures_equals_empty_failures(self, profile):
        a = self._run(profile)
        b = self._run(profile, failures=[])
        assert _fingerprint(a) == _fingerprint(b)
        assert all(r == DegradeReason.NONE for r in a.degrade_rounds)
        assert a.fault_events_applied == 0 and a.preemptions == 0

    def test_never_fired_event_is_inert(self, profile):
        a = self._run(profile)
        # an outage scheduled far past the makespan is never applied
        b = self._run(
            profile,
            failures=[FailureEvent(a.makespan_s * 1e3, NODE_DOWN, node=0)],
        )
        assert _fingerprint(a) == _fingerprint(b)

    def test_fault_knobs_are_inert_without_events(self, profile):
        a = self._run(profile)
        cluster = ClusterSpec(3, 4)
        trace = shockwave_trace(num_jobs=18, seed=2, profile=profile)
        sched = _scheduler(cluster, profile)
        b = Simulator(
            cluster, trace, sched, profile,
            SimConfig(max_retries=1, backoff_base_s=7.0, checkpoint_interval_s=1.0),
        ).run()
        assert _fingerprint(a) == _fingerprint(b)

    def test_event_on_missing_node_rejected(self, profile):
        cluster = ClusterSpec(2, 4)
        with pytest.raises(ValueError, match="node 9"):
            Simulator(
                cluster,
                shockwave_trace(num_jobs=4, seed=0, profile=profile),
                _scheduler(cluster, profile),
                profile,
                SimConfig(),
                failures=[FailureEvent(0.0, NODE_DOWN, node=9)],
            )


# --------------------------------------------------------------------------- #
# Health-aware decide()
# --------------------------------------------------------------------------- #
class TestHealthAwareDecide:
    def test_down_node_gets_nothing(self, profile):
        cluster = ClusterSpec(3, 4)
        trace = _tiny_trace(profile, 10, seed=1)
        from repro.core.jobs import JobState

        sched = _scheduler(cluster, profile)
        states = [JobState(spec=s) for s in trace]
        health = ClusterHealth(3)
        health.up[1] = False
        prev = None
        for rnd in range(4):
            dec = sched.decide(states, rnd * ROUND, prev, health=health)
            for jid, gpus in dec.plan.job_gpu_map().items():
                assert all(cluster.node_of(g) != 1 for g in gpus)
            prev = dec.plan
        # recovery: once the node is back, capacity is usable again
        health.up[1] = True
        seen_node1 = False
        for rnd in range(4, 8):
            dec = sched.decide(states, rnd * ROUND, prev, health=health)
            prev = dec.plan
            if any(cluster.node_of(g) == 1
                   for gpus in dec.plan.job_gpu_map().values() for g in gpus):
                seen_node1 = True
        assert seen_node1

    def test_all_up_health_matches_no_health(self, profile):
        cluster = ClusterSpec(2, 4)
        trace = _tiny_trace(profile, 8, seed=4)
        from repro.core.jobs import JobState

        a = _scheduler(cluster, profile)
        b = _scheduler(cluster, profile)
        sa = [JobState(spec=s) for s in trace]
        sb = [JobState(spec=s) for s in trace]
        prev_a = prev_b = None
        for rnd in range(3):
            da = a.decide(sa, rnd * ROUND, prev_a)
            db = b.decide(sb, rnd * ROUND, prev_b, health=ClusterHealth(2))
            assert np.array_equal(da.plan.slots, db.plan.slots)
            prev_a, prev_b = da.plan, db.plan


# --------------------------------------------------------------------------- #
# Degradation ladder (injected clock)
# --------------------------------------------------------------------------- #
def _scripted_clock(values):
    it = iter(values)
    last = [0.0]

    def clock():
        try:
            last[0] = next(it)
        except StopIteration:
            pass
        return last[0]

    return clock


class TestDegradationLadder:
    def _round_inputs(self, profile, cluster):
        from repro.core.jobs import JobState

        trace = _tiny_trace(profile, 10, seed=9)
        return [JobState(spec=s) for s in trace]

    def test_deadline_greedy(self, profile):
        cluster = ClusterSpec(3, 4)
        states = self._round_inputs(profile, cluster)
        base = _scheduler(cluster, profile)
        d0 = base.decide(states, 0.0)
        # clock: t_start=0, migrate-stage check reads 10 >> deadline
        sched = _scheduler(
            cluster, profile, decide_deadline_s=1.0,
            clock=_scripted_clock([0.0, 10.0]),
        )
        dec = sched.decide(states, ROUND, d0.plan)
        assert dec.degrade_reason == DegradeReason.DEADLINE_GREEDY
        assert dec.migration is not None and dec.migration.algorithm == "none"
        # the greedy plan is still a valid placement
        for jid, gpus in dec.plan.job_gpu_map().items():
            assert len(gpus) == next(
                s.num_gpus for s in states if s.job_id == jid
            )

    def test_deadline_host_demotion(self, profile):
        cluster = ClusterSpec(3, 4)
        states = self._round_inputs(profile, cluster)
        base = _scheduler(cluster, profile)
        d0 = base.decide(states, 0.0)
        host = _scheduler(cluster, profile)
        dh = host.decide(states, ROUND, d0.plan)

        fused = _scheduler(
            cluster, profile, fused_fanout=True,
            decide_deadline_s=1.0, clock=_scripted_clock([0.0, 0.7]),
        )
        df = fused.decide(states, ROUND, d0.plan)
        assert df.degrade_reason == DegradeReason.DEADLINE_HOST
        # demoted round is served by the exact host planner: bit-identical
        assert np.array_equal(df.plan.slots, dh.plan.slots)

    def test_no_deadline_never_degrades(self, profile):
        cluster = ClusterSpec(3, 4)
        states = self._round_inputs(profile, cluster)
        sched = _scheduler(cluster, profile, clock=_scripted_clock([0.0, 1e9]))
        d0 = sched.decide(states, 0.0)
        dec = sched.decide(states, ROUND, d0.plan)
        assert dec.degrade_reason == DegradeReason.NONE

    def test_generous_deadline_stays_on_ladder_top(self, profile):
        cluster = ClusterSpec(3, 4)
        states = self._round_inputs(profile, cluster)
        sched = _scheduler(cluster, profile, decide_deadline_s=3600.0)
        d0 = sched.decide(states, 0.0)
        dec = sched.decide(states, ROUND, d0.plan)
        assert dec.degrade_reason == DegradeReason.NONE


# --------------------------------------------------------------------------- #
# Fused planner: forced fallback + warm recovery (satellite a)
# --------------------------------------------------------------------------- #
class TestFusedFallbackAndRecovery:
    def test_forced_budget_fallback_is_bit_identical(self, profile, monkeypatch):
        import repro.core.fused as fused_mod

        cluster = ClusterSpec(3, 4)
        from repro.core.jobs import JobState

        trace = _tiny_trace(profile, 10, seed=5)
        states = [JobState(spec=s) for s in trace]

        host = _scheduler(cluster, profile)
        d0h = host.decide(states, 0.0)
        dh = host.decide(states, ROUND, d0h.plan)

        # an impossible mantissa budget forces the host fallback each round
        monkeypatch.setattr(fused_mod, "_F32_MANTISSA", 0.0)
        fused = _scheduler(cluster, profile, fused_fanout=True)
        d0f = fused.decide(states, 0.0)
        df = fused.decide(states, ROUND, d0f.plan)
        assert df.degrade_reason == DegradeReason.FUSED_BUDGET
        assert np.array_equal(df.plan.slots, dh.plan.slots)
        assert fused._fused_planner.stats["fused_budget_fallbacks"] >= 1
        assert df.match_stats.get("fused_host_fallbacks", 0) >= 1

    def test_simresult_counts_fallbacks(self, profile, monkeypatch):
        import repro.core.fused as fused_mod

        monkeypatch.setattr(fused_mod, "_F32_MANTISSA", 0.0)
        cluster = ClusterSpec(2, 4)
        trace = _tiny_trace(profile, 6, seed=6)
        sched = _scheduler(cluster, profile, fused_fanout=True)
        res = Simulator(cluster, trace, sched, profile, SimConfig()).run()
        assert res.fused_host_fallbacks > 0
        assert res.degrade_counts.get(DegradeReason.FUSED_BUDGET, 0) > 0

    def test_invalidate_then_two_round_recovery(self, profile):
        """After a node invalidation the fused cache must be fully warm
        again (0 dirty pairs, one readout per round) within 2 rounds."""
        from repro.core.fused import FusedMigrationPlanner
        from repro.core.jobs import JobState

        cluster = ClusterSpec(3, 4)
        trace = _tiny_trace(profile, 10, seed=7)
        states = [JobState(spec=s) for s in trace]
        sched = _scheduler(cluster, profile)
        d0 = sched.decide(states, 0.0)
        d1 = sched.decide(states, ROUND, d0.plan)

        planner = FusedMigrationPlanner()
        gmap = {s.job_id: s.num_gpus for s in states}

        def dirty_of(fn):
            before = dict(planner.stats)
            fn()
            return (
                planner.stats["fused_dirty_pairs"] - before["fused_dirty_pairs"],
                planner.stats["fused_readouts"] - before["fused_readouts"],
            )

        dirty_of(lambda: planner.plan(d0.plan, d1.plan, gmap))  # cold
        dirty, readouts = dirty_of(lambda: planner.plan(d0.plan, d1.plan, gmap))
        assert dirty == 0 and readouts == 1  # steady state

        planner.invalidate_nodes([1])
        d_1, r_1 = dirty_of(lambda: planner.plan(d0.plan, d1.plan, gmap))
        assert d_1 > 0 and r_1 == 1  # poisoned rows re-solve...
        d_2, r_2 = dirty_of(lambda: planner.plan(d0.plan, d1.plan, gmap))
        assert d_2 == 0 and r_2 == 1  # ...and the cache is warm again

        # the re-solved plan matches a fresh planner's exactly
        fresh = FusedMigrationPlanner()
        a = planner.plan(d0.plan, d1.plan, gmap)
        b = fresh.plan(d0.plan, d1.plan, gmap)
        assert np.array_equal(a.physical_plan.slots, b.physical_plan.slots)


# --------------------------------------------------------------------------- #
# Targeted invalidation of the MatchContext
# --------------------------------------------------------------------------- #
class TestTargetedInvalidation:
    def test_invalidate_instances_is_targeted(self):
        ctx = MatchContext()
        rng = np.random.default_rng(0)
        costs = rng.random((3, 4, 4))
        ids = np.array([10, 11, 12])
        kw = dict(context=ctx, context_key="t", instance_ids=ids, backend="numpy")
        r1 = solve_lap_batched(costs, **kw)
        solve_lap_batched(costs, **kw)
        assert ctx.stats["memo_instances"] == 3  # all memo-hit

        n = ctx.invalidate_instances([11], families=("t",))
        assert n == 1
        assert ctx.stats["instances_invalidated"] == 1
        before = ctx.stats["memo_instances"]
        r3 = solve_lap_batched(costs, **kw)
        # 10 and 12 still memo-hit; 11 re-solves to the same assignment
        assert ctx.stats["memo_instances"] == before + 2
        assert np.array_equal(r3.col_of, r1.col_of)

    def test_unknown_family_is_noop(self):
        ctx = MatchContext()
        solve_lap_batched(
            np.eye(3)[None], context=ctx, context_key="t",
            instance_ids=[5], backend="numpy",
        )
        assert ctx.invalidate_instances([5], families=("other",)) == 0

    def test_scheduler_invalidate_node(self, profile):
        cluster = ClusterSpec(3, 4)
        from repro.core.jobs import JobState

        trace = _tiny_trace(profile, 10, seed=8)
        states = [JobState(spec=s) for s in trace]
        sched = _scheduler(cluster, profile)
        d0 = sched.decide(states, 0.0)
        sched.decide(states, ROUND, d0.plan)  # populate migration families
        count = sched.invalidate_node(1)
        assert count > 0
        assert sched.match_context.stats["instances_invalidated"] == count


# --------------------------------------------------------------------------- #
# MatchContext save / load (satellite c)
# --------------------------------------------------------------------------- #
class TestMatchContextPersistence:
    def _populated(self):
        ctx = MatchContext()
        rng = np.random.default_rng(1)
        solve_lap_batched(
            rng.random((4, 5, 5)), context=ctx, context_key="fam-a",
            instance_ids=[1, 2, 3, 4], backend="auction",
        )
        solve_lap_batched(
            rng.random((2, 3, 3)), context=ctx, context_key="fam-b",
            instance_ids=[7, 8], backend="numpy", maximize=True,
        )
        return ctx

    def test_round_trip_no_suffix_append(self, tmp_path):
        ctx = self._populated()
        path = str(tmp_path / "ctx-state")  # no .npz suffix
        ctx.save(path)
        import os

        assert os.path.exists(path) and not os.path.exists(path + ".npz")
        loaded = MatchContext.load(path)
        assert loaded.stats == ctx.stats

    def test_loaded_context_memo_hits(self, tmp_path):
        ctx = self._populated()
        path = str(tmp_path / "s.npz")
        ctx.save(path)
        loaded = MatchContext.load(path)
        rng = np.random.default_rng(1)
        costs = rng.random((4, 5, 5))
        before = loaded.stats["memo_instances"]
        res = solve_lap_batched(
            costs, context=loaded, context_key="fam-a",
            instance_ids=[1, 2, 3, 4], backend="auction",
        )
        assert loaded.stats["memo_instances"] == before + 4
        fresh = solve_lap_batched(costs, backend="numpy")
        assert res.total_cost == pytest.approx(fresh.total_cost)

    def test_version_check(self, tmp_path):
        import json

        ctx = self._populated()
        path = str(tmp_path / "s.npz")
        ctx.save(path)
        with np.load(path, allow_pickle=False) as z:
            arrays = {k: z[k] for k in z.files}
        meta = json.loads(str(arrays["meta_json"][()]))
        meta["version"] = "tesserae-matchctx-v999"
        arrays["meta_json"] = np.array(json.dumps(meta))
        with open(path, "wb") as f:
            np.savez(f, **arrays)
        with pytest.raises(ValueError, match="v999"):
            MatchContext.load(path)


# --------------------------------------------------------------------------- #
# Crash-resume differential (satellite c)
# --------------------------------------------------------------------------- #
class TestCrashResume:
    def _make(self, profile, failures):
        cluster = ClusterSpec(3, 4)
        trace = _tiny_trace(profile, 12, seed=11, max_rounds=8)
        sched = _scheduler(cluster, profile)
        cfg = SimConfig(max_retries=3, backoff_base_s=ROUND)
        return Simulator(cluster, trace, sched, profile, cfg, failures=failures)

    def _failures(self):
        return [
            FailureEvent(2 * ROUND, NODE_DOWN, node=1),
            FailureEvent(5 * ROUND, NODE_UP, node=1),
            FailureEvent(3 * ROUND, GPU_DEGRADE, node=0, factor=0.5),
            FailureEvent(7 * ROUND, GPU_DEGRADE, node=0, factor=1.0),
            FailureEvent(4 * ROUND, JOB_FAIL, job_id=2),
        ]

    @pytest.mark.parametrize("kill_after", [1, 4, 9])
    def test_resume_is_bit_identical(self, profile, tmp_path, kill_after):
        baseline = self._make(profile, self._failures()).run()

        victim = self._make(profile, self._failures())
        out = victim.run(stop_after_rounds=kill_after)
        assert out is None  # paused, not finished
        snap = str(tmp_path / f"snap{kill_after}.npz")
        victim.save_state(snap)

        resumed = self._make(profile, self._failures())  # fresh everything
        resumed.load_state(snap)
        res = resumed.run()
        assert _fingerprint(res) == _fingerprint(baseline)

    def test_resume_without_failures(self, profile, tmp_path):
        baseline = self._make(profile, None).run()
        victim = self._make(profile, None)
        assert victim.run(stop_after_rounds=3) is None
        snap = str(tmp_path / "snap.npz")
        victim.save_state(snap)
        resumed = self._make(profile, None)
        resumed.load_state(snap)
        assert _fingerprint(resumed.run()) == _fingerprint(baseline)

    def test_continue_in_place_matches(self, profile):
        """Pausing and continuing the SAME simulator is also identical."""
        baseline = self._make(profile, self._failures()).run()
        paused = self._make(profile, self._failures())
        assert paused.run(stop_after_rounds=2) is None
        res = paused.run()
        assert _fingerprint(res) == _fingerprint(baseline)

    def test_save_without_pause_raises(self, profile, tmp_path):
        sim = self._make(profile, None)
        with pytest.raises(RuntimeError, match="stop_after_rounds"):
            sim.save_state(str(tmp_path / "x.npz"))


# --------------------------------------------------------------------------- #
# NaN / inf cost validation (satellite b)
# --------------------------------------------------------------------------- #
class TestCostValidation:
    def test_nan_rejected_with_instance_id(self):
        costs = np.random.default_rng(0).random((2, 3, 3))
        costs[1, 2, 0] = np.nan
        with pytest.raises(ValueError) as ei:
            solve_lap_batched(costs, instance_ids=[70, 99], backend="numpy")
        msg = str(ei.value)
        assert "instance id 99" in msg and "row 2" in msg and "col 0" in msg

    def test_attractive_inf_rejected(self):
        costs = np.ones((1, 2, 2))
        costs[0, 0, 0] = -np.inf  # infinitely attractive under minimisation
        with pytest.raises(ValueError, match="-inf"):
            solve_lap_batched(costs, backend="numpy")
        benefit = np.ones((1, 2, 2))
        benefit[0, 1, 1] = np.inf  # infinitely attractive under maximisation
        with pytest.raises(ValueError, match="inf"):
            solve_lap_batched(benefit, maximize=True, backend="numpy")

    def test_forbidden_edges_still_legal(self):
        costs = np.ones((1, 2, 2))
        costs[0, 0, 1] = np.inf  # forbidden under minimisation: fine
        res = solve_lap_batched(costs, backend="numpy")
        assert res.col_of[0, 0] == 0
        benefit = np.ones((1, 2, 2))
        benefit[0, 0, 1] = -np.inf  # forbidden under maximisation: fine
        solve_lap_batched(benefit, maximize=True, backend="numpy")

    def test_count_reported(self):
        costs = np.full((1, 2, 2), np.nan)
        with pytest.raises(ValueError, match="4 invalid entries"):
            solve_lap_batched(costs, backend="numpy")


# --------------------------------------------------------------------------- #
# Crash accounting: every progress metric rewinds to the checkpoint
# --------------------------------------------------------------------------- #
class TestCrashAccounting:
    """A crash must rewind attained_service and executed_time to their
    checkpoint-time values — not just iters_done — so LAS priority and
    the periodic-checkpoint cadence see only the surviving progress."""

    def _crashed_state(self, profile):
        from repro.core.jobs import JobState
        from repro.core.simulator import _SimState

        cluster = ClusterSpec(2, 4)
        spec = JobSpec(job_id=0, model="resnet50", num_gpus=2,
                       total_iters=1e9, arrival_time=0.0)
        s = JobState(spec=spec)
        s.iters_done = 100.0
        s.attained_service = 4000.0
        s.executed_time = 2000.0
        s.ckpt_iters = 60.0
        s.ckpt_executed = 1200.0
        s.ckpt_service = 2400.0
        s.gpus = frozenset([0, 1])
        sim = Simulator(cluster, [spec], _scheduler(cluster, profile),
                        profile, SimConfig(backoff_base_s=ROUND))
        st = _SimState(states={0: s}, num_gpus_of={0: 2},
                       health=ClusterHealth(2), now=10 * ROUND)
        return sim, st, s

    def test_rewinds_every_progress_metric(self, profile):
        sim, st, s = self._crashed_state(profile)
        sim._crash_job(st, s, preempt=True)
        assert s.iters_done == 60.0
        assert s.attained_service == 2400.0
        assert s.executed_time == 1200.0
        assert s.lost_iters == pytest.approx(40.0)
        assert st.lost_iters == pytest.approx(40.0)
        # lost-work telemetry: executed seconds beyond the checkpoint
        assert st.lost_work_s == pytest.approx(800.0)
        assert s.retries == 1 and s.preemptions == 1
        assert not s.gpus and s.packed_with is None

    def test_crashed_priority_equals_uncrashed_peer(self, profile):
        """Differential regression: after the crash, Tiresias ranks the
        victim exactly like a never-crashed job with identical surviving
        progress (same arrival)."""
        from repro.core.jobs import JobState
        from repro.core.simulator import _SimState

        cluster = ClusterSpec(2, 4)
        pol = TiresiasPolicy(profile)
        spec_v = JobSpec(job_id=0, model="resnet50", num_gpus=1,
                         total_iters=1e9, arrival_time=0.0)
        spec_p = JobSpec(job_id=1, model="resnet50", num_gpus=1,
                         total_iters=1e9, arrival_time=0.0)
        victim, peer = JobState(spec=spec_v), JobState(spec=spec_p)
        # victim ran into LAS queue 2; its last checkpoint is in queue 1
        victim.iters_done = 500.0
        victim.attained_service = 7200.0
        victim.executed_time = 7200.0
        victim.ckpt_iters = 200.0
        victim.ckpt_service = 3000.0
        victim.ckpt_executed = 3000.0
        victim.gpus = frozenset([0])
        peer.iters_done = 200.0
        peer.attained_service = 3000.0
        peer.executed_time = 3000.0
        # un-rewound, the victim would be demoted a queue below its peer
        assert pol.sort_key(victim, 0.0, cluster) > pol.sort_key(
            peer, 0.0, cluster
        )

        sim = Simulator(cluster, [spec_v, spec_p],
                        _scheduler(cluster, profile), profile,
                        SimConfig(backoff_base_s=ROUND))
        st = _SimState(states={0: victim, 1: peer},
                       num_gpus_of={0: 1, 1: 1},
                       health=ClusterHealth(2), now=4 * ROUND)
        sim._crash_job(st, victim, preempt=False)
        assert victim.attained_service == peer.attained_service
        assert pol.sort_key(victim, 5 * ROUND, cluster) == pol.sort_key(
            peer, 5 * ROUND, cluster
        )


# --------------------------------------------------------------------------- #
# Backoff eligibility: the idle-skip clamp and the realised schedule
# --------------------------------------------------------------------------- #
class TestBackoffEligibility:
    def _one_job_sim(self, profile, backoff_base_s, fail_at, rounds=30,
                     hook=None):
        cluster = ClusterSpec(1, 4)
        rate = profile.isolated("resnet50", 1, "dp")
        spec = JobSpec(job_id=0, model="resnet50", num_gpus=1,
                       total_iters=rate * ROUND * rounds, arrival_time=0.0)
        cfg = SimConfig(max_retries=3, backoff_base_s=backoff_base_s,
                        max_time_s=400 * ROUND)
        sched = _scheduler(cluster, profile)
        events = [FailureEvent(t, JOB_FAIL, job_id=0) for t in fail_at]
        return _RecordingSim(cluster, [spec], sched, profile, cfg,
                             failures=events, round_hook=hook)

    @pytest.mark.parametrize("mult", [10.0, 9.5])
    def test_idle_skip_wakes_exactly_at_backoff_expiry(self, profile, mult):
        """With nothing else to run, the simulator must skip straight to
        the first round boundary at/after the backoff expiry — never a
        round early (the job is not yet eligible) and never later."""
        decide_times = []

        def hook(round_idx, now, decision, states, health):
            decide_times.append(now - ROUND)  # hook fires after now += round

        sim = self._one_job_sim(profile, mult * ROUND, [ROUND], hook=hook)
        sim.run()
        assert len(sim.crash_log) == 1
        _, _, t_crash, elig, failed = sim.crash_log[0]
        assert not failed and t_crash == ROUND
        assert elig == pytest.approx(ROUND + mult * ROUND)
        wake = ROUND * np.ceil(elig / ROUND)
        post_crash = [t for t in decide_times if t > 0.0]
        assert post_crash[0] == pytest.approx(wake)
        assert all(t >= wake - 1e-9 for t in post_crash)

    def test_realised_backoff_sequence_is_geometric(self, profile):
        """Four crashes: three geometric backoffs (1x, 2x, 4x base),
        then the retry budget is exhausted and the job fails terminally."""
        fail_at = [1.5 * ROUND, 8 * ROUND, 16 * ROUND, 30 * ROUND]
        sim = self._one_job_sim(profile, ROUND, fail_at, rounds=60)
        res = sim.run()
        assert len(sim.crash_log) == 4
        deltas = [elig - t for (_, _, t, elig, _) in sim.crash_log[:3]]
        assert deltas == [ROUND, 2 * ROUND, 4 * ROUND]
        assert [r for (_, r, _, _, _) in sim.crash_log] == [1, 2, 3, 4]
        assert sim.crash_log[3][4] is True  # terminal
        assert res.jobs[0].failed and 0 in res.failed_jobs


# --------------------------------------------------------------------------- #
# GPU_DEGRADE routes through the scheduler's targeted invalidation
# --------------------------------------------------------------------------- #
class TestDegradeInvalidation:
    def test_degrade_and_recovery_invalidate_once_each(self, profile):
        cluster = ClusterSpec(3, 4)
        trace = _tiny_trace(profile, 8, seed=13, max_rounds=10)
        sched = _scheduler(cluster, profile)
        calls = []
        orig = sched.invalidate_node

        def spy(node):
            calls.append(node)
            return orig(node)

        sched.invalidate_node = spy
        events = [
            FailureEvent(2 * ROUND, GPU_DEGRADE, node=1, factor=0.5),
            # same factor again: no state change, no invalidation
            FailureEvent(4 * ROUND, GPU_DEGRADE, node=1, factor=0.5),
            # recovery back to full speed invalidates again
            FailureEvent(6 * ROUND, GPU_DEGRADE, node=1, factor=1.0),
        ]
        Simulator(cluster, trace, sched, profile, SimConfig(),
                  failures=events).run()
        assert calls == [1, 1]

    def test_untouched_nodes_warm_state_survives(self, profile):
        """The degrade-driven invalidation is targeted: matching memo
        state for pairs not touching the degraded node keeps hitting."""
        from repro.core.jobs import JobState

        cluster = ClusterSpec(3, 4)
        trace = _tiny_trace(profile, 10, seed=14)
        states = [JobState(spec=s) for s in trace]
        sched = _scheduler(cluster, profile)
        prev = None
        for rnd in range(3):
            prev = sched.decide(states, rnd * ROUND, prev).plan
        assert sched.invalidate_node(1) > 0
        before = sched.match_context.stats["memo_instances"]
        sched.decide(states, 3 * ROUND, prev)
        assert sched.match_context.stats["memo_instances"] > before


# --------------------------------------------------------------------------- #
# Tentpole: failure-aware placement through the matching layer
# --------------------------------------------------------------------------- #
class TestFailureAwarePlacement:
    def test_health_blind_ignores_degradation(self, profile):
        """knob off: degraded speeds and outage history change NOTHING —
        plans stay bit-identical to a health-free decide()."""
        from repro.core.jobs import JobState

        cluster = ClusterSpec(2, 4)
        trace = _tiny_trace(profile, 8, seed=15)
        a = _scheduler(cluster, profile)
        b = _scheduler(cluster, profile)
        sa = [JobState(spec=s) for s in trace]
        sb = [JobState(spec=s) for s in trace]
        health = ClusterHealth(2)
        health.speed_factor[0] = 0.5
        health.note_outage()
        prev_a = prev_b = None
        for rnd in range(3):
            da = a.decide(sa, rnd * ROUND, prev_a)
            db = b.decide(sb, rnd * ROUND, prev_b, health=health)
            assert np.array_equal(da.plan.slots, db.plan.slots)
            prev_a, prev_b = da.plan, db.plan

    def test_health_aware_all_healthy_is_bit_identical(self, profile):
        """knob on, pristine cluster: the health terms never activate and
        the plans are bit-identical to the seed path."""
        from repro.core.jobs import JobState

        cluster = ClusterSpec(2, 4)
        trace = _tiny_trace(profile, 8, seed=16)
        a = _scheduler(cluster, profile)
        b = _scheduler(cluster, profile, health_aware=True)
        sa = [JobState(spec=s) for s in trace]
        sb = [JobState(spec=s) for s in trace]
        prev_a = prev_b = None
        for rnd in range(3):
            da = a.decide(sa, rnd * ROUND, prev_a)
            db = b.decide(sb, rnd * ROUND, prev_b, health=ClusterHealth(2))
            assert np.array_equal(da.plan.slots, db.plan.slots)
            prev_a, prev_b = da.plan, db.plan

    def test_straggler_drain_moves_job_to_spare_capacity(self, profile):
        from repro.core.jobs import JobState

        cluster = ClusterSpec(2, 4)
        spec = JobSpec(job_id=0, model="resnet50", num_gpus=4,
                       total_iters=1e9, arrival_time=0.0)
        health = ClusterHealth(2)
        health.speed_factor[0] = 0.4

        aware = _scheduler(cluster, profile, health_aware=True)
        states = [JobState(spec=spec)]
        d0 = aware.decide(states, 0.0, None)
        assert {cluster.node_of(g) for g in d0.plan.job_gpu_map()[0]} == {0}
        d1 = aware.decide(states, ROUND, d0.plan, health=health)
        assert {cluster.node_of(g) for g in d1.plan.job_gpu_map()[0]} == {1}

        # a health-blind scheduler stays put on the straggler
        blind = _scheduler(cluster, profile)
        b0 = blind.decide(states, 0.0, None)
        b1 = blind.decide(states, ROUND, b0.plan, health=health)
        assert {cluster.node_of(g) for g in b1.plan.job_gpu_map()[0]} == {0}

    def test_no_drain_without_spare_capacity(self, profile):
        """Every node busy: the drain penalty is uniform over occupied
        rows, so it cannot justify churn — plans match the blind path."""
        from repro.core.jobs import JobState

        cluster = ClusterSpec(2, 4)
        specs = [JobSpec(job_id=i, model="resnet50", num_gpus=4,
                         total_iters=1e9, arrival_time=0.0)
                 for i in range(2)]
        states = [JobState(spec=s) for s in specs]
        health = ClusterHealth(2)
        health.speed_factor[0] = 0.4
        aware = _scheduler(cluster, profile, health_aware=True)
        blind = _scheduler(cluster, profile)
        pa = aware.decide(states, 0.0, None).plan
        pb = blind.decide(states, 0.0, None).plan
        da = aware.decide(states, ROUND, pa, health=health)
        db = blind.decide(states, ROUND, pb, health=health)
        assert np.array_equal(da.plan.slots, db.plan.slots)

    def test_fused_parity_with_health_terms(self, profile):
        """Fused decide() with the drain penalties folded in-kernel stays
        bit-identical to the host planner over a churn replay with moving
        degradations."""
        from repro.core.jobs import JobState

        cluster = ClusterSpec(3, 4)
        trace = _tiny_trace(profile, 10, seed=17)
        sh = [JobState(spec=s) for s in trace]
        sf = [JobState(spec=s) for s in trace]
        host = _scheduler(cluster, profile, health_aware=True,
                          tie_break=True)
        fused = _scheduler(cluster, profile, health_aware=True,
                           tie_break=True, fused_fanout=True)
        health = ClusterHealth(3)
        health.speed_factor[1] = 0.6
        health.note_outage()
        ph = pf = None
        for rnd in range(6):
            if rnd == 3:
                # mid-replay churn: the degradation moves nodes (the sim
                # invalidates the touched nodes; mirror it here)
                health.speed_factor[1] = 1.0
                health.speed_factor[2] = 0.3
                for n in (1, 2):
                    host.invalidate_node(n)
                    fused.invalidate_node(n)
            # deterministic service churn so plans keep changing
            for i, (x, y) in enumerate(zip(sh, sf)):
                bump = 137.0 * ((i + rnd) % 5)
                x.attained_service += bump
                y.attained_service += bump
            dh = host.decide(sh, rnd * ROUND, ph, health=health)
            df = fused.decide(sf, rnd * ROUND, pf, health=health)
            assert np.array_equal(dh.plan.slots, df.plan.slots), f"round {rnd}"
            ph, pf = dh.plan, df.plan
        # served by the fused lane, not the budget fallback
        assert fused._fused_planner.stats["fused_budget_fallbacks"] == 0

    def test_domain_spread_placement_spans_racks(self, profile):
        from repro.core.jobs import JobState
        from repro.core.placement import place_without_packing

        cluster = ClusterSpec(4, 4, nodes_per_rack=2)
        spec = JobSpec(job_id=0, model="resnet50", num_gpus=8,
                       total_iters=1e9, arrival_time=0.0)
        states = [JobState(spec=spec)]
        plan, _, _ = place_without_packing(cluster, states)
        racks = {cluster.rack_of(cluster.node_of(g))
                 for g in plan.job_gpu_map()[0]}
        assert racks == {0}  # seed behaviour: consolidate into one rack
        plan2, _, _ = place_without_packing(cluster, states,
                                            spread_domains=True)
        racks2 = {cluster.rack_of(cluster.node_of(g))
                  for g in plan2.job_gpu_map()[0]}
        assert racks2 == {0, 1}

    def test_hot_hazard_spreads_gangs(self, profile):
        """End-to-end decide(): a hot empirical outage process makes the
        failure-aware arm spread a 2-node gang across racks; a cold
        process keeps the seed consolidation."""
        from repro.core.jobs import JobState

        cluster = ClusterSpec(4, 4, nodes_per_rack=2)
        sched = TesseraeScheduler(
            cluster, FailureAwarePolicy(TiresiasPolicy(profile)), profile,
            lap_backend="numpy", migration_algorithm="node",
            health_aware=True,
        )
        spec = JobSpec(job_id=0, model="resnet50", num_gpus=8,
                       total_iters=1e9, arrival_time=0.0)
        states = [JobState(spec=spec)]
        hot = ClusterHealth(4)
        for _ in range(40):
            hot.note_outage()  # tiny empirical MTBF: hazard is hot
        dec = sched.decide(states, ROUND, None, health=hot)
        racks = {cluster.rack_of(cluster.node_of(g))
                 for g in dec.plan.job_gpu_map()[0]}
        assert racks == {0, 1}
        cold = sched.decide(states, ROUND, None, health=ClusterHealth(4))
        racks_cold = {cluster.rack_of(cluster.node_of(g))
                      for g in cold.plan.job_gpu_map()[0]}
        assert racks_cold == {0}

    def test_failure_aware_policy_cold_order_identical(self, profile):
        from repro.core.jobs import JobState

        cluster = ClusterSpec(4, 4)
        inner = TiresiasPolicy(profile)
        wrapped = FailureAwarePolicy(inner)
        assert wrapped.name == "tiresias-fa"
        states = [JobState(spec=s) for s in _tiny_trace(profile, 12, seed=18)]
        for i, s in enumerate(states):
            s.attained_service = 911.0 * (i % 4)
        by_inner = sorted(states, key=lambda s: inner.sort_key(s, 0.0, cluster))
        by_wrap = sorted(states, key=lambda s: wrapped.sort_key(s, 0.0, cluster))
        assert [s.job_id for s in by_inner] == [s.job_id for s in by_wrap]

    def test_failure_aware_policy_hot_boost_is_subordinate(self, profile):
        from repro.core.jobs import JobState

        cluster = ClusterSpec(4, 4)
        wrapped = FailureAwarePolicy(TiresiasPolicy(profile))
        mk = lambda jid, gpus, arr: JobState(spec=JobSpec(
            job_id=jid, model="resnet50", num_gpus=gpus,
            total_iters=1e9, arrival_time=arr))
        small, gang, later_gang = mk(0, 1, 100.0), mk(1, 8, 100.0), mk(2, 8, 200.0)
        wrapped.set_spread_hot(True)
        # same inner tier: the multi-node gang wins the tie
        assert wrapped.sort_key(gang, 0.0, cluster) < wrapped.sort_key(
            small, 0.0, cluster
        )
        # different inner tier: queue discipline is untouched
        assert wrapped.sort_key(small, 0.0, cluster) < wrapped.sort_key(
            later_gang, 0.0, cluster
        )
        wrapped.set_spread_hot(False)
        assert wrapped.sort_key(gang, 0.0, cluster) == wrapped.sort_key(
            small, 0.0, cluster
        )


# --------------------------------------------------------------------------- #
# Adaptive checkpoint cadence (Young's interval)
# --------------------------------------------------------------------------- #
class TestAdaptiveCheckpoint:
    def test_interval_formula_and_clamps(self, profile):
        from repro.core.jobs import JobState, migration_overhead_s

        cluster = ClusterSpec(2, 4)
        spec = JobSpec(job_id=0, model="resnet50", num_gpus=8,
                       total_iters=1e9, arrival_time=0.0)
        s = JobState(spec=spec)
        s.gpus = frozenset(range(8))  # spans both nodes
        health = ClusterHealth(2)

        fixed = Simulator(cluster, [spec], _scheduler(cluster, profile),
                          profile, SimConfig())
        assert fixed._ckpt_interval_s(s, health, 1000.0) == 1800.0  # knob off

        cfg = SimConfig(adaptive_checkpoint=True,
                        checkpoint_interval_s=10_000.0)
        sim = Simulator(cluster, [spec], _scheduler(cluster, profile),
                        profile, cfg)
        # no observed outage yet: fixed cadence
        assert sim._ckpt_interval_s(s, health, 1000.0) == 10_000.0

        health.note_outage()
        now = 50_000.0
        mtbf = health.empirical_mtbf_s(now)
        young = (2.0 * 0.5 * migration_overhead_s("resnet50") * mtbf / 2) ** 0.5
        got = sim._ckpt_interval_s(s, health, now)
        assert got == pytest.approx(
            min(10_000.0, max(cfg.round_duration_s, young))
        )
        # a single-node job sees twice the gang's MTBF: longer cadence
        s1 = JobState(spec=JobSpec(job_id=1, model="resnet50", num_gpus=4,
                                   total_iters=1e9, arrival_time=0.0))
        s1.gpus = frozenset(range(4))
        assert sim._ckpt_interval_s(s1, health, now) >= got

    def test_adaptive_reduces_lost_work(self, profile):
        """Differential: with an observed outage, the adaptive cadence
        checkpoints aggressively and a later crash loses far less work
        than the fixed (here: effectively never) cadence."""
        cluster = ClusterSpec(2, 4)
        rate = profile.isolated("resnet50", 4, "dp")
        spec = JobSpec(job_id=0, model="resnet50", num_gpus=4,
                       total_iters=rate * ROUND * 40, arrival_time=0.0)
        events = [
            FailureEvent(1 * ROUND, NODE_DOWN, node=1),  # observed outage
            FailureEvent(2 * ROUND, NODE_UP, node=1),    # (job is on node 0)
            FailureEvent(20 * ROUND, JOB_FAIL, job_id=0),
        ]

        def run(adaptive):
            cfg = SimConfig(checkpoint_interval_s=1e9,
                            adaptive_checkpoint=adaptive,
                            backoff_base_s=ROUND, max_retries=5)
            sched = _scheduler(cluster, profile)
            return Simulator(cluster, [spec], sched, profile, cfg,
                             failures=list(events)).run()

        fixed = run(False)
        adapt = run(True)
        assert not fixed.jobs[0].failed and not adapt.jobs[0].failed
        assert fixed.lost_work_s_total > 0.0
        assert adapt.lost_work_s_total < fixed.lost_work_s_total


# --------------------------------------------------------------------------- #
# ClusterHealth: empirical MTBF and the hazard flag
# --------------------------------------------------------------------------- #
class TestClusterHealthHazard:
    def test_empirical_mtbf_and_hazard(self):
        h = ClusterHealth(4)
        assert h.empirical_mtbf_s(7200.0) is None
        assert not h.hazard_hot(7200.0, 1e12)
        h.note_outage()
        h.note_outage()
        # pooled estimate: elapsed * num_nodes / outages
        assert h.empirical_mtbf_s(7200.0) == pytest.approx(7200.0 * 4 / 2)
        assert h.hazard_hot(7200.0, 20_000.0)
        assert not h.hazard_hot(7200.0, 10_000.0)
        # degenerate now: the elapsed floor keeps the estimate finite
        assert h.empirical_mtbf_s(0.0) == pytest.approx(2.0)

    def test_copy_carries_outage_history(self):
        h = ClusterHealth(3)
        h.note_outage()
        c = h.copy()
        assert c.outages == 1
        c.note_outage()
        assert h.outages == 1 and c.outages == 2
