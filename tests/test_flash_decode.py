"""Flash-decoding kernel vs oracle: shape/dtype/GQA/ring-validity sweep."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_decode import flash_decode_pallas


def _mk(rng, b, h, kvh, s, d, dtype):
    q = jnp.asarray(rng.normal(size=(b, h, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, s, kvh, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, s, kvh, d)), dtype)
    return q, k, v


class TestFlashDecode:
    @pytest.mark.parametrize(
        "b,h,kvh,s,d",
        [
            (1, 4, 4, 128, 64),     # MHA
            (2, 8, 2, 512, 64),     # GQA 4:1
            (1, 12, 2, 1024, 128),  # qwen2-vl-like 6:1
            (2, 8, 8, 300, 64),     # unaligned cache length
        ],
    )
    def test_matches_ref_full_cache(self, b, h, kvh, s, d):
        rng = np.random.default_rng(b * 100 + s)
        q, k, v = _mk(rng, b, h, kvh, s, d, jnp.float32)
        got = flash_decode_pallas(q, k, v, jnp.asarray(s), interpret=True)
        want = ref.flash_decode(q, k, v, s)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("valid", [1, 7, 100, 511, 512])
    def test_partial_validity(self, valid):
        """Ring buffer: slots beyond valid_len must not contribute."""
        rng = np.random.default_rng(valid)
        q, k, v = _mk(rng, 1, 4, 2, 512, 64, jnp.float32)
        got = flash_decode_pallas(q, k, v, jnp.asarray(valid), interpret=True)
        want = ref.flash_decode(q, k, v, valid)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
        # and garbage beyond valid_len is ignored entirely
        k2 = k.at[:, valid:].set(1e4)
        v2 = v.at[:, valid:].set(-1e4)
        got2 = flash_decode_pallas(q, k2, v2, jnp.asarray(valid), interpret=True)
        np.testing.assert_allclose(got2, want, rtol=2e-5, atol=2e-5)

    def test_bf16(self):
        rng = np.random.default_rng(0)
        q, k, v = _mk(rng, 2, 8, 2, 256, 64, jnp.bfloat16)
        got = flash_decode_pallas(q, k, v, jnp.asarray(256), interpret=True)
        want = ref.flash_decode(q, k, v, 256)
        np.testing.assert_allclose(
            got.astype(jnp.float32), want.astype(jnp.float32), rtol=3e-2, atol=3e-2
        )

    def test_matches_model_sdpa_path(self):
        """Kernel == the models' decode attention (sdpa with kv_valid_len)."""
        from repro.models.attention import sdpa

        rng = np.random.default_rng(1)
        b, h, kvh, s, d = 2, 8, 2, 256, 64
        q, k, v = _mk(rng, b, h, kvh, s, d, jnp.float32)
        valid = 100
        got = flash_decode_pallas(q, k, v, jnp.asarray(valid), interpret=True)
        want = sdpa(
            q[:, None, :, :],  # (B, 1, H, D): one query position
            k, v, causal=False, kv_valid_len=jnp.asarray(valid),
        )[:, 0]
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
