"""Churn-replay differential suite: the end-to-end proof that identity-
keyed warm starts preserve scheduler semantics under realistic churn.

A 60+ round trace-driven :class:`Simulator` replay (Poisson arrivals,
completions, and Tiresias demotion-resume on an oversubscribed cluster —
the Philly-style churn regime) is driven through the full Tesserae
pipeline twice per comparison:

* **warm scipy vs cold scipy** — the strict differential: the warm arm
  exercises the whole identity-keyed machinery (per-instance memoisation,
  identity remapping of cached assignments, partial-batch compaction)
  with an exact backend, so placements, packing matches, JCTs, makespan
  and migration counts must be BIT-IDENTICAL to a context-free replay.
* **warm auction vs cold scipy (shadow)** — per-round decision parity on
  IDENTICAL inputs: a shadow cold-scipy scheduler decides each round from
  the same (active set, previous plan), and the warm auction's migration
  matching cost must match it exactly (costs are integer-quantised, where
  the auction is provably exact) and its packing weight to within the
  documented ``S * eps`` bound.  Assignment IDs are compared at the cost
  level, not element-wise: equally-optimal ties (same-model pending jobs,
  interchangeable empty nodes) are broken differently by different
  solvers — see the "Semantic note" in ``migration.py``.
* **warm auction vs cold auction** — the speedup direction: threading one
  identity-keyed context across the replay must strictly reduce total bid
  iterations vs resetting it every round, while serving warm hits in
  nearly every round.  (The >= 2x gate vs the shape-keyed PR-2 emulation
  lives in ``benchmarks/matching_microbench.py --churn``, where the
  engine inputs are controlled directly.)
"""

import numpy as np
import pytest

from repro.core.cluster import ClusterSpec
from repro.core.policies import TiresiasPolicy
from repro.core.profiler import ThroughputProfile
from repro.core.scheduler import TesseraeScheduler
from repro.core.simulator import SimConfig, Simulator
from repro.core.traces import shockwave_trace

pytest.importorskip("scipy.optimize")

#: replay shape: 28 jobs arriving at ~220/h on a 16-GPU cluster gives a
#: 60+ round replay with arrivals/completions nearly every round and
#: repeated Tiresias queue demotions (queue_base well below job lengths).
N_JOBS = 28
ARRIVAL_RATE = 220.0
SEED = 5
MIN_ROUNDS = 30


def _profile():
    return ThroughputProfile()


def _trace(profile):
    return shockwave_trace(
        num_jobs=N_JOBS,
        arrival_rate_per_hour=ARRIVAL_RATE,
        seed=SEED,
        profile=profile,
    )


class RecordingScheduler(TesseraeScheduler):
    """Records each round's decision surface; optionally replays cold
    (context reset before every decide — the no-warm-start baseline)."""

    def __init__(self, *args, cold=False, shadow=None, **kwargs):
        super().__init__(*args, **kwargs)
        self.cold = cold
        #: optional scheduler solving the SAME round inputs first — the
        #: per-round differential oracle (its decisions are discarded and
        #: its own context is reset, so it is always a cold reference)
        self.shadow = shadow
        self.round_log = []

    def decide(self, active_jobs, now, prev_plan=None, num_gpus_of=None):
        if self.cold:
            self.match_context.reset()
        shadow_entry = None
        if self.shadow is not None:
            self.shadow.match_context.reset()
            sd = self.shadow.decide(active_jobs, now, prev_plan, num_gpus_of)
            shadow_entry = {
                "pack_w": sd.packing.total_weight,
                "packs": dict(sd.packing.matches),
                "mig_cost": None
                if sd.migration is None
                else sd.migration.matching_cost,
                "plan": {j: frozenset(g) for j, g in sd.plan.job_gpu_map().items()},
            }
        d = super().decide(active_jobs, now, prev_plan, num_gpus_of)
        self.round_log.append(
            {
                "plan": {j: frozenset(g) for j, g in d.plan.job_gpu_map().items()},
                "packs": dict(d.packing.matches),
                "pack_w": d.packing.total_weight,
                "mig_cost": None
                if d.migration is None
                else d.migration.matching_cost,
                "shadow": shadow_entry,
                "match_stats": dict(d.match_stats),
            }
        )
        return d


def _run(backend, cold=False, shadow_backend=None, enable_packing=True, tie_break=False):
    profile = _profile()
    cluster = ClusterSpec(4, 4)
    shadow = None
    if shadow_backend is not None:
        shadow = TesseraeScheduler(
            cluster,
            TiresiasPolicy(profile, queue_base=900.0),
            profile,
            lap_backend=shadow_backend,
            enable_packing=enable_packing,
            tie_break=tie_break,
        )
    sched = RecordingScheduler(
        cluster,
        TiresiasPolicy(profile, queue_base=900.0),
        profile,
        lap_backend=backend,
        cold=cold,
        shadow=shadow,
        enable_packing=enable_packing,
        tie_break=tie_break,
    )
    sim = Simulator(
        cluster,
        _trace(profile),
        sched,
        profile,
        SimConfig(round_duration_s=360.0, resume_fraction=0.25),
    )
    return sim.run(), sched


def _jcts(res):
    return np.array([res.jobs[j].finish_time for j in sorted(res.jobs)])


def _has_demotion_resume(round_log):
    """True iff some job left the plan mid-life and later returned — the
    Tiresias preempt/resume pattern the replay must exercise."""
    seen, gone, resumed = set(), set(), set()
    for entry in round_log:
        running = set(entry["plan"])
        gone |= {j for j in seen if j not in running}
        resumed |= gone & running
        seen |= running
    return bool(resumed)


class TestScipyDifferential:
    """Identity-keyed warm starts with an exact backend must be invisible:
    memo remaps and compacted sub-solves reproduce the cold replay
    bit-for-bit."""

    @pytest.fixture(scope="class")
    def arms(self):
        warm, warm_sched = _run("scipy", cold=False)
        cold, cold_sched = _run("scipy", cold=True)
        return warm, warm_sched, cold, cold_sched

    def test_replay_shape(self, arms):
        warm, warm_sched, *_ = arms
        assert warm.num_rounds >= MIN_ROUNDS
        assert _has_demotion_resume(warm_sched.round_log), (
            "trace never exercised Tiresias demotion-resume"
        )

    def test_identical_placements(self, arms):
        warm, warm_sched, cold, cold_sched = arms
        assert len(warm_sched.round_log) == len(cold_sched.round_log)
        for t, (a, b) in enumerate(zip(warm_sched.round_log, cold_sched.round_log)):
            assert a["plan"] == b["plan"], f"round {t}: physical plans differ"
            assert a["packs"] == b["packs"], f"round {t}: packing differs"

    def test_identical_jcts_and_makespan(self, arms):
        warm, _, cold, _ = arms
        np.testing.assert_array_equal(_jcts(warm), _jcts(cold))
        assert warm.makespan_s == cold.makespan_s
        assert warm.total_migrations == cold.total_migrations
        assert warm.num_rounds == cold.num_rounds

    def test_warm_arm_actually_warm(self, arms):
        warm, *_ = arms
        memo = sum(r.get("memo_instances", 0) for r in warm.match_rounds)
        assert memo > 0, "scipy arm never memo-hit: identity keying inert"
        assert warm.warm_hit_rounds(skip=2) >= 0.75 * (warm.num_rounds - 2)


class TestAuctionDifferential:
    """Warm identity-keyed auction vs a cold scipy shadow deciding from
    the SAME per-round inputs: integer-quantised migration matching costs
    must agree exactly; packing weights to within the documented bound."""

    @pytest.fixture(scope="class")
    def warm(self):
        return _run("auction", cold=False, shadow_backend="scipy")

    def test_migration_costs_exact(self):
        """Packing disabled, so both arms relabel the SAME logical plan
        every round: the integer-quantised node-pair + node matching cost
        of the warm identity-keyed auction must equal cold scipy's
        exactly, all rounds, despite churn."""
        _, sched = _run(
            "auction", cold=False, shadow_backend="scipy", enable_packing=False
        )
        compared = 0
        for t, entry in enumerate(sched.round_log):
            if entry["mig_cost"] is None:
                continue
            compared += 1
            assert entry["mig_cost"] == pytest.approx(
                entry["shadow"]["mig_cost"], abs=1e-9
            ), f"round {t}: warm auction migration cost != cold scipy"
        assert compared >= MIN_ROUNDS

    def test_migration_costs_exact_when_packing_agrees(self, warm):
        """With packing on, the migration inputs only coincide on rounds
        where both arms packed identically (ties aside, most rounds) —
        and there the costs must again agree exactly."""
        _, sched = warm
        compared = 0
        for t, entry in enumerate(sched.round_log):
            if entry["mig_cost"] is None or entry["packs"] != entry["shadow"]["packs"]:
                continue
            compared += 1
            assert entry["mig_cost"] == pytest.approx(
                entry["shadow"]["mig_cost"], abs=1e-9
            ), f"round {t}: warm auction migration cost != cold scipy"
        assert compared >= MIN_ROUNDS // 2

    def test_packing_weight_within_bound(self, warm):
        _, sched = warm
        for t, entry in enumerate(sched.round_log):
            # documented engine bound: S * eps_min < 1 with S the short
            # side of the packing graph (eps_min = 1/(S+1))
            assert entry["pack_w"] >= entry["shadow"]["pack_w"] - 1.0 - 1e-6, (
                f"round {t}: packing weight beyond the auction bound"
            )

    def test_jct_sanity(self, warm):
        """Not a strict differential (ties break differently): the warm
        auction replay must still finish every job with the same round
        count and a makespan within one round of the scipy baseline."""
        res, _ = warm
        cold, _ = _run("scipy", cold=True)
        assert res.num_rounds == pytest.approx(cold.num_rounds, abs=2)
        assert abs(res.makespan_s - cold.makespan_s) <= 2 * 360.0


class TestWarmSpeedup:
    """Threading ONE identity-keyed context across the replay must
    strictly cut auction work vs per-round cold resets, with warm hits in
    (nearly) every round — the steady-state the tentpole exists for."""

    def test_fewer_bid_iterations_and_warm_hits(self):
        warm, _ = _run("auction", cold=False)
        cold, _ = _run("auction", cold=True)
        assert warm.total_bid_iters < cold.total_bid_iters, (
            warm.total_bid_iters,
            cold.total_bid_iters,
        )
        # observed ~2.2x on this trace; gate conservatively at 1.5x here
        # (the >= 2x acceptance gate runs on the controlled engine-level
        # churn replay in CI: matching_microbench --churn)
        assert cold.total_bid_iters >= 1.5 * warm.total_bid_iters
        assert warm.warm_hit_rounds(skip=2) >= 0.75 * (warm.num_rounds - 2)

    def test_resume_fraction_knob_still_differentiates(self):
        """The churn trace must keep exercising the cold-start-vs-resume
        distinction (PR-2 satellite) — resumes getting free must not be a
        no-op on this workload."""
        profile = _profile()
        cluster = ClusterSpec(4, 4)

        def run(frac):
            sched = TesseraeScheduler(
                cluster,
                TiresiasPolicy(profile, queue_base=900.0),
                profile,
                lap_backend="scipy",
            )
            sim = Simulator(
                cluster,
                _trace(profile),
                sched,
                profile,
                SimConfig(round_duration_s=360.0, resume_fraction=frac),
            )
            return sim.run()

        free = run(0.0)
        costly = run(1.0)
        assert free.avg_jct_s < costly.avg_jct_s


class TestTieBreakDifferential:
    """Canonical tie-breaking closes the gap the cost-level comparisons
    above tolerate: with ``tie_break=True`` equally-optimal assignments
    are solver-independent, so the warm identity-keyed AUCTION arm is
    BIT-FOR-BIT the cold scipy shadow deciding from the same inputs —
    full physical plans, every round, the tie-free restriction removed
    (migration costs are integer-quantised, where the perturbed auction
    resolves the canonical optimum exactly)."""

    @pytest.fixture(scope="class")
    def arms(self):
        return _run(
            "auction",
            cold=False,
            shadow_backend="scipy",
            enable_packing=False,
            tie_break=True,
        )

    def test_plans_bit_identical_all_rounds(self, arms):
        _, sched = arms
        assert len(sched.round_log) >= MIN_ROUNDS
        for t, entry in enumerate(sched.round_log):
            assert entry["plan"] == entry["shadow"]["plan"], (
                f"round {t}: warm auction physical plan != cold scipy "
                f"(tie-break should have made them identical)"
            )

    def test_migration_costs_still_exact(self, arms):
        _, sched = arms
        compared = 0
        for t, entry in enumerate(sched.round_log):
            if entry["mig_cost"] is None:
                continue
            compared += 1
            assert entry["mig_cost"] == pytest.approx(
                entry["shadow"]["mig_cost"], abs=1e-9
            ), f"round {t}"
        assert compared >= MIN_ROUNDS

    def test_tie_break_scipy_arms_bit_identical(self):
        """Warm scipy vs its own cold shadow under tie-breaking: the
        perturbation must not disturb the exact-backend differential."""
        _, sched = _run(
            "scipy",
            cold=False,
            shadow_backend="scipy",
            enable_packing=True,
            tie_break=True,
        )
        for t, entry in enumerate(sched.round_log):
            assert entry["plan"] == entry["shadow"]["plan"], f"round {t}"
            assert entry["packs"] == entry["shadow"]["packs"], f"round {t}"

    def test_tie_break_off_is_seed_behaviour(self):
        """Default (no tie-break) replay is unchanged by the knob's
        existence: same JCTs as a fresh default run."""
        a, _ = _run("scipy", cold=True)
        b, _ = _run("scipy", cold=True, tie_break=False)
        np.testing.assert_array_equal(_jcts(a), _jcts(b))


class PermutingScheduler(RecordingScheduler):
    """Presents each round's packing graph with the job rows in a seeded
    random order.  Identity-keyed memoisation ranks (row_id, col_id)
    identities, never batch positions, so the permutation must be
    invisible to warm starts."""

    def decide(self, active_jobs, now, prev_plan=None, num_gpus_of=None):
        jobs = list(active_jobs)
        rng = np.random.default_rng([41, len(self.round_log)])
        order = rng.permutation(len(jobs))
        return super().decide([jobs[i] for i in order], now, prev_plan, num_gpus_of)


class TestPermutationMemoSurvival:
    """PR-6 replaced the batch-position tie-break ramps with the
    identity-keyed perturbation (``engine._tb_ranks``); this is the
    churn-replay-level regression gate: permuting the packing graph every
    round must not disturb memo hits (pre-fix, the positional ramp moved
    under permutation and every permuted round was a memo miss)."""

    def _run_permuted(self):
        profile = _profile()
        cluster = ClusterSpec(4, 4)
        sched = PermutingScheduler(
            cluster,
            TiresiasPolicy(profile, queue_base=900.0),
            profile,
            lap_backend="auction",
        )
        sim = Simulator(
            cluster,
            _trace(profile),
            sched,
            profile,
            SimConfig(round_duration_s=360.0, resume_fraction=0.25),
        )
        return sim.run(), sched

    def test_memo_hits_survive_packing_graph_permutation(self):
        permuted, _ = self._run_permuted()
        plain, _ = _run("auction", cold=False)
        cold, _ = _run("auction", cold=True)

        assert permuted.num_rounds >= MIN_ROUNDS
        # the same near-every-round warm-hit bar the unpermuted replay meets
        assert permuted.warm_hit_rounds(skip=2) >= 0.75 * (permuted.num_rounds - 2)
        # and the warm-start work reduction is intact, not accidentally
        # degraded to the cold baseline by permutation-induced misses
        assert cold.total_bid_iters >= 1.5 * permuted.total_bid_iters, (
            cold.total_bid_iters,
            permuted.total_bid_iters,
        )
        # permuting row order must not cost memo coverage vs the plain
        # warm arm (identities, not positions, key the fingerprints);
        # tolerate one round of slack for arrival-boundary effects
        assert permuted.warm_hit_rounds(skip=2) >= plain.warm_hit_rounds(skip=2) - 1
