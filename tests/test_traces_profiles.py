"""Trace-generator and profiler-model statistical sanity tests."""

import numpy as np
import pytest

from repro.core.profiler import (
    MODEL_CATALOG,
    ThroughputProfile,
    linear_bo_estimate,
    oracle_table,
)
from repro.core.traces import TABLE1_MODELS, gavel_trace, shockwave_trace


class TestShockwaveTrace:
    def test_gpu_distribution(self):
        trace = shockwave_trace(num_jobs=4000, seed=0)
        gpus = np.array([t.num_gpus for t in trace])
        # paper: 1/2/4/8 with 0.60/0.30/0.09/0.01
        for g, p in [(1, 0.60), (2, 0.30), (4, 0.09), (8, 0.01)]:
            frac = (gpus == g).mean()
            assert abs(frac - p) < 0.03, (g, frac)

    def test_arrival_rate(self):
        trace = shockwave_trace(num_jobs=2000, seed=1, arrival_rate_per_hour=80)
        arrivals = np.array([t.arrival_time for t in trace])
        gaps = np.diff(np.sort(arrivals))
        assert abs(gaps.mean() - 3600 / 80) < 4.0

    def test_models_from_table1(self):
        trace = shockwave_trace(num_jobs=200, seed=2)
        assert {t.model for t in trace} <= set(TABLE1_MODELS)


class TestGavelTrace:
    def test_duration_split(self):
        profile = ThroughputProfile()
        trace = gavel_trace(num_jobs=3000, seed=3, profile=profile)
        durations = np.array(
            [
                t.total_iters / profile.isolated(t.model, t.num_gpus)
                for t in trace
            ]
        )
        # 80% short (10^[1.5,3] min), 20% long (10^[3,4] min)
        long_frac = (durations > 1000 * 60).mean()
        assert 0.1 < long_frac < 0.3

    def test_gpu_distribution(self):
        trace = gavel_trace(num_jobs=4000, seed=4)
        gpus = np.array([t.num_gpus for t in trace])
        for g, p in [(1, 0.70), (2, 0.10), (4, 0.15), (8, 0.05)]:
            assert abs((gpus == g).mean() - p) < 0.03


class TestProfilerModel:
    def test_compute_memory_pairs_pack_best(self):
        """Roofline grounding: compute-bound + memory-bound packs better
        than two compute-bound jobs (the Fig. 7 structure)."""
        prof = ThroughputProfile()
        # resnet50 ci=0.82 (compute-bound), pointnet ci=0.25 (memory-bound)
        mix, _ = prof.combined_weight("resnet50", "pointnet", optimize_strategy=False)
        same, _ = prof.combined_weight("resnet50", "resnet50", optimize_strategy=False)
        assert mix > same

    def test_oom_pairs_have_zero_weight_on_v100(self):
        prof = ThroughputProfile(gpu_type="v100")  # 16 GB
        w, _ = prof.combined_weight("vgg19", "vgg19", optimize_strategy=False)
        assert w == 0.0

    def test_strategy_unlocks_oom_pair(self):
        """Fig.-8 mechanism: a lower-memory parallelism strategy makes an
        OOM pair packable and lifts the edge weight above zero."""
        prof = ThroughputProfile()  # a100, 40 GB
        # gpt3-3b (33 GB) + vgg19 (15 GB) OOMs at dp...
        na, _ = prof.normalized_packed("gpt3-3b", "vgg19", strat_a="dp")
        assert na == 0.0
        # ...but packs under tp (33*0.62 + 15 < 40)
        w, s = prof.combined_weight("gpt3-3b", "vgg19", optimize_strategy=True)
        assert w > 0.0 and s != "dp"

    def test_estimator_monotone_budget(self):
        """More BO probes never leave the estimator with a WORSE best-known
        strategy for the pair it optimises."""
        truth = ThroughputProfile()
        models = TABLE1_MODELS
        t_small = linear_bo_estimate(truth, models, strategy_budget=1, seed=0)
        t_big = linear_bo_estimate(truth, models, strategy_budget=5, seed=0)
        a, b = "gpt3-xl", "resnet50"
        w_small, _ = t_small.combined_weight(a, b)
        w_big, _ = t_big.combined_weight(a, b)
        truth_w, _ = truth.combined_weight(a, b)
        # bigger budget estimate is closer to (or as close to) the truth
        assert abs(w_big - truth_w) <= abs(w_small - truth_w) + 0.15
