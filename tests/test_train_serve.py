"""Training-loop, checkpoint and serving-engine tests."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.launch.train import train_loop
from repro.models import get_model
from repro.serve.engine import ServeConfig, greedy_generate
from repro.train.checkpoint import restore_checkpoint, save_checkpoint
from repro.train.data import DataConfig, SyntheticTokens, batch_for
from repro.train.step import TrainConfig, train_state_init


class TestData:
    def test_deterministic(self):
        a = next(SyntheticTokens(DataConfig(100, 4, 16, seed=3)))
        b = next(SyntheticTokens(DataConfig(100, 4, 16, seed=3)))
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_targets_shifted(self):
        batch = next(SyntheticTokens(DataConfig(100, 2, 8, seed=0)))
        # targets[t] is the token following tokens[t]
        assert batch["tokens"].shape == batch["targets"].shape == (2, 8)
        np.testing.assert_array_equal(
            batch["tokens"][:, 1:], batch["targets"][:, :-1]
        )

    def test_structure_learnable(self):
        """Bigram structure: successor entropy < uniform."""
        batch = next(SyntheticTokens(DataConfig(64, 16, 64, seed=1)))
        # count (tok, next) pairs: structured succ table has only 8 options
        from collections import Counter

        c = Counter()
        for row_t, row_n in zip(batch["tokens"], batch["targets"]):
            for t, n in zip(row_t, row_n):
                c[(int(t), int(n))] += 1
        # with structure=0.75 repeated bigrams must appear
        assert max(c.values()) >= 2


class TestTrainLoop:
    def test_loss_decreases(self):
        cfg = dataclasses.replace(
            get_reduced("llama3-8b"), vocab_size=256, num_layers=2
        )
        _, losses = train_loop(
            cfg, steps=25, batch_size=4, seq_len=32, lr=3e-3, log_every=100
        )
        assert np.mean(losses[-5:]) < np.mean(losses[:5])

    def test_checkpoint_roundtrip(self, tmp_path):
        cfg = get_reduced("llama3-8b")
        tc = TrainConfig()
        state = train_state_init(jax.random.PRNGKey(0), cfg, tc)
        path = str(tmp_path / "ckpt.npz")
        save_checkpoint(path, state, step=7)
        restored, step = restore_checkpoint(path, state)
        assert step == 7
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(
                np.asarray(a, np.float32), np.asarray(b, np.float32)
            )

    def test_resume_continues(self, tmp_path):
        cfg = dataclasses.replace(get_reduced("llama3-8b"), vocab_size=128)
        path = str(tmp_path / "c.npz")
        train_loop(
            cfg, steps=4, batch_size=2, seq_len=16, ckpt_path=path,
            ckpt_every=4, log_every=100,
        )
        _, losses = train_loop(
            cfg, steps=6, batch_size=2, seq_len=16, ckpt_path=path,
            resume=True, log_every=100,
        )
        assert len(losses) == 2  # resumed at step 4, ran 4..5


class TestServing:
    def test_greedy_deterministic(self):
        cfg = get_reduced("qwen3-14b")
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0), cfg)
        prompt = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
        sc = ServeConfig(batch_size=1, context_len=32)
        o1 = greedy_generate(params, cfg, prompt, 8, sc)
        o2 = greedy_generate(params, cfg, prompt, 8, sc)
        np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
        assert o1.shape == (1, 12)

    def test_cache_len_policy(self):
        sc = ServeConfig(batch_size=1, context_len=524_288)
        assert sc.cache_len(get_reduced("mamba2-780m")) == 1
        cfg = get_reduced("llama3-8b")  # window 16384
        assert sc.cache_len(cfg) == cfg.attention_window
        sc_small = ServeConfig(batch_size=1, context_len=1024)
        assert sc_small.cache_len(cfg) == 1024
