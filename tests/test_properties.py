"""System-level invariants (hypothesis property tests) + analytic checks."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, get_reduced
from repro.core.cluster import ClusterSpec, MAX_PACK
from repro.core.placement import place_without_packing
from repro.core.policies import TiresiasPolicy
from repro.core.profiler import ThroughputProfile
from repro.core.scheduler import TesseraeScheduler
from repro.core.simulator import SimConfig, Simulator
from repro.core.traces import shockwave_trace, synthetic_active_jobs


@pytest.fixture(scope="module")
def profile():
    return ThroughputProfile()


class TestPlacementInvariants:
    @given(st.integers(0, 2**32 - 1), st.integers(1, 8), st.integers(2, 8))
    @settings(max_examples=30, deadline=None)
    def test_no_overallocation_and_consolidation(self, seed, nodes, gpn_half):
        gpn = 2 * gpn_half  # even node sizes so 8-GPU jobs fit whole nodes
        profile = ThroughputProfile()
        cluster = ClusterSpec(nodes, gpn)
        jobs = synthetic_active_jobs(30, seed=seed, profile=profile)
        jobs = [j for j in jobs if j.num_gpus <= gpn or j.num_gpus % gpn == 0]
        plan, placed, pending = place_without_packing(cluster, jobs)
        # every GPU holds at most one job before packing
        for n in range(nodes):
            for l in range(gpn):
                assert len(plan.jobs_on_gpu(n, l)) <= 1
        # placed jobs got exactly their GPU count, consolidated
        gmap = plan.job_gpu_map()
        for j in placed:
            assert len(gmap[j.job_id]) == j.num_gpus
            assert plan.is_consolidated(j.job_id)
        # placed + pending = input
        assert len(placed) + len(pending) == len(jobs)


class TestSimulatorInvariants:
    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=8, deadline=None)
    def test_conservation(self, seed):
        profile = ThroughputProfile()
        cluster = ClusterSpec(2, 4)
        trace = shockwave_trace(num_jobs=15, seed=seed, profile=profile)
        sched = TesseraeScheduler(cluster, TiresiasPolicy(profile), profile)
        res = Simulator(cluster, trace, sched, profile, SimConfig()).run()
        for s in res.jobs.values():
            # finished after arrival; executed no longer than wall time
            assert s.finish_time > s.spec.arrival_time
            assert s.executed_time <= (s.finish_time - s.spec.arrival_time) + 1e-6
            # 2D service bounded by gpus * executed time
            assert s.attained_service <= s.num_gpus * s.executed_time + 1e-6
        # aggregate service can't exceed cluster capacity * makespan
        # (packing shares GPUs, each packed job still occupies the GPU set,
        # so the bound is capacity * makespan * MAX_PACK)
        total_service = sum(s.attained_service for s in res.jobs.values())
        assert total_service <= cluster.num_gpus * res.makespan_s * MAX_PACK

    def test_jct_at_least_isolated_runtime(self, profile):
        cluster = ClusterSpec(2, 4)
        trace = shockwave_trace(num_jobs=10, seed=5, profile=profile)
        sched = TesseraeScheduler(cluster, TiresiasPolicy(profile), profile)
        res = Simulator(cluster, trace, sched, profile, SimConfig()).run()
        for s in res.jobs.values():
            iso = s.spec.total_iters / profile.isolated(
                s.spec.model, s.num_gpus, "dp"
            )
            # strategy factors can speed a job up by <=~1.25x; JCT can't be
            # meaningfully below isolated runtime
            assert s.finish_time - s.spec.arrival_time >= 0.75 * iso


class TestMoEShardMapParity:
    def test_matches_reference_on_one_device(self):
        from repro.launch.mesh import make_smoke_mesh
        from repro.launch.pspec import ShardingRules, use_rules
        from repro.models.mlp import init_moe, moe_ffn, moe_ffn_sharded

        cfg = get_reduced("dbrx-132b")
        p = init_moe(jax.random.PRNGKey(0), cfg, jnp.bfloat16)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.bfloat16)
        ref, aux_ref = jax.jit(lambda p, x: moe_ffn(p, cfg, x))(p, x)
        mesh = make_smoke_mesh()
        with mesh, use_rules(ShardingRules(mesh)):
            got, aux_got = jax.jit(lambda p, x: moe_ffn_sharded(p, cfg, x))(p, x)
        assert float(aux_ref) == pytest.approx(float(aux_got), rel=1e-6)
        np.testing.assert_allclose(
            np.asarray(ref, np.float32), np.asarray(got, np.float32),
            rtol=3e-2, atol=3e-2,
        )

    def test_shared_experts_arch(self):
        from repro.launch.mesh import make_smoke_mesh
        from repro.launch.pspec import ShardingRules, use_rules
        from repro.models.mlp import init_moe, moe_ffn, moe_ffn_sharded

        cfg = get_reduced("deepseek-v2-236b")
        p = init_moe(jax.random.PRNGKey(0), cfg, jnp.bfloat16)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model), jnp.bfloat16)
        ref, _ = jax.jit(lambda p, x: moe_ffn(p, cfg, x))(p, x)
        mesh = make_smoke_mesh()
        with mesh, use_rules(ShardingRules(mesh)):
            got, _ = jax.jit(lambda p, x: moe_ffn_sharded(p, cfg, x))(p, x)
        np.testing.assert_allclose(
            np.asarray(ref, np.float32), np.asarray(got, np.float32),
            rtol=3e-2, atol=3e-2,
        )


class TestParamCounts:
    """Analytic counts must land on the published model sizes."""

    @pytest.mark.parametrize(
        "arch,expected_b,tol",
        [
            ("llama3-8b", 8.0, 0.1),
            ("qwen3-14b", 14.8, 0.15),
            ("mamba2-780m", 0.78, 0.15),
            ("deepseek-67b", 67.4, 0.1),
            ("dbrx-132b", 132.0, 0.1),
            ("nemotron-4-340b", 340.0, 0.1),
            ("deepseek-v2-236b", 236.0, 0.15),
            ("zamba2-2.7b", 2.7, 0.25),
        ],
    )
    def test_param_count(self, arch, expected_b, tol):
        got = get_config(arch).param_count() / 1e9
        assert abs(got - expected_b) / expected_b <= tol, got

    def test_moe_active_smaller(self):
        for arch in ["dbrx-132b", "deepseek-v2-236b"]:
            cfg = get_config(arch)
            assert cfg.active_param_count() < 0.4 * cfg.param_count()


class TestLoopCorrectionFormula:
    @given(
        st.integers(2, 16),     # mb
        st.integers(2, 96),     # layer trips
        st.floats(0, 1e9),      # glue_out
        st.floats(0, 1e9),      # mb_glue
        st.floats(1, 1e9),      # layer body
    )
    @settings(max_examples=100, deadline=None)
    def test_reconstructs_truth(self, mb, trips, glue_out, mb_glue, body):
        """base/diff measurements reconstruct the true loop-expanded cost."""
        base = glue_out + mb_glue + body          # each while body counted once
        layer_d = body                            # unroll diff isolates bodies
        mb_d = mb_glue + body
        truth = glue_out + mb * (mb_glue + trips * body)
        corrected = base + (mb - 1) * (mb_d - layer_d) + (mb * trips - 1) * layer_d
        assert corrected == pytest.approx(truth, rel=1e-9)


class TestTypeAffinityPlacement:
    """Hetero type-blindness bugfix: the placement key is speed-aware on
    heterogeneous clusters (gangs take a type-PURE node set, fastest pure
    type first; sub-node ties break toward the fastest type explicitly)
    and degenerates bit-identically to seed best-fit on homogeneous ones.
    """

    @staticmethod
    def _job(jid, g):
        from repro.core.jobs import JobSpec, JobState

        return JobState(JobSpec(jid, "resnet50", g, 1000.0, 0.0))

    @staticmethod
    def _hetero(types, gpn=4):
        return ClusterSpec(len(types), gpn, node_gpu_types=tuple(types))

    def test_gang_prefers_pure_fast_nodes(self):
        # v100 node 0 free, a100 nodes 2+3 free: the 8-GPU gang must take
        # the pure-a100 pair, not the index-ordered mixed (0, 2) set
        cluster = self._hetero(["v100", "a100", "a100", "a100"])
        blocker = self._job(1, 4)   # fills node 1 (a100: fastest, best fit ties -> idx 1)
        gang = self._job(2, 8)
        plan, placed, _ = place_without_packing(cluster, [blocker, gang])
        gmap = plan.job_gpu_map()
        gang_nodes = {cluster.node_of(g) for g in gmap[2]}
        assert gang_nodes == {2, 3}, gang_nodes

    def test_gang_takes_pure_slow_set_over_mixed(self):
        # one empty a100 + two empty v100s: a mixed set would throttle the
        # a100 to v100 speed AND burn it — the pure v100 pair is chosen
        cluster = self._hetero(["a100", "v100", "v100"])
        gang = self._job(1, 8)
        plan, placed, _ = place_without_packing(cluster, [gang])
        gang_nodes = {cluster.node_of(g) for g in plan.job_gpu_map()[1]}
        assert gang_nodes == {1, 2}, gang_nodes

    def test_gang_falls_back_to_mixed_when_no_pure_set_exists(self):
        cluster = self._hetero(["v100", "a100"])
        gang = self._job(1, 8)
        plan, placed, pending = place_without_packing(cluster, [gang])
        assert placed and not pending
        assert {cluster.node_of(g) for g in plan.job_gpu_map()[1]} == {0, 1}

    def test_subnode_tie_breaks_toward_fast_type(self):
        # equal holes on a v100 (idx 0) and an a100 (idx 1): the 1-GPU job
        # must take the a100 even though index order says otherwise
        cluster = self._hetero(["v100", "a100"])
        job = self._job(1, 1)
        plan, _, _ = place_without_packing(cluster, [job])
        assert cluster.node_of(min(plan.job_gpu_map()[1])) == 1

    def test_affinity_off_restores_seed_order(self):
        cluster = self._hetero(["v100", "a100"])
        job = self._job(1, 1)
        plan, _, _ = place_without_packing(cluster, [job], type_affinity=False)
        assert cluster.node_of(min(plan.job_gpu_map()[1])) == 0

    @given(st.integers(0, 2**32 - 1), st.integers(1, 6))
    @settings(max_examples=20, deadline=None)
    def test_homogeneous_is_bit_identical_to_seed(self, seed, nodes):
        profile = ThroughputProfile()
        cluster = ClusterSpec(nodes, 4)
        jobs = synthetic_active_jobs(20, seed=seed, profile=profile)
        jobs = [j for j in jobs if j.num_gpus <= 4 or j.num_gpus % 4 == 0]
        p_on, _, _ = place_without_packing(cluster, jobs, type_affinity=True)
        p_off, _, _ = place_without_packing(cluster, jobs, type_affinity=False)
        np.testing.assert_array_equal(p_on.slots, p_off.slots)
