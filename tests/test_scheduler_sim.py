"""End-to-end scheduler + simulator behaviour tests."""

import numpy as np
import pytest

from repro.core.cluster import ClusterSpec
from repro.core.policies import FifoPolicy, TiresiasPolicy, ThemisFtfPolicy
from repro.core.profiler import ThroughputProfile
from repro.core.scheduler import TesseraeScheduler, tiresias_single_packed_ok
from repro.core.simulator import SimConfig, Simulator
from repro.core.traces import gavel_trace, shockwave_trace, synthetic_active_jobs


@pytest.fixture(scope="module")
def profile():
    return ThroughputProfile()


def _sim(cluster, trace, scheduler, profile, **cfg):
    return Simulator(cluster, trace, scheduler, profile, SimConfig(**cfg)).run()


class TestSchedulerRound:
    def test_placement_respects_capacity(self, profile):
        cluster = ClusterSpec(2, 4)
        jobs = synthetic_active_jobs(30, seed=0, profile=profile)
        sched = TesseraeScheduler(cluster, TiresiasPolicy(profile), profile)
        dec = sched.decide(jobs, now=0.0)
        used = sum(len(g) for g in dec.plan.job_gpu_map().values())
        # each GPU holds at most 2 jobs
        assert all(
            len(dec.plan.jobs_on_gpu(n, l)) <= 2
            for n in range(2)
            for l in range(4)
        )
        placed_ids = {j.job_id for j in dec.placed}
        pend_ids = {j.job_id for j in dec.pending}
        assert placed_ids.isdisjoint(pend_ids)

    def test_consolidation_all_jobs(self, profile):
        cluster = ClusterSpec(4, 4)
        jobs = synthetic_active_jobs(40, seed=1, profile=profile)
        sched = TesseraeScheduler(cluster, TiresiasPolicy(profile), profile)
        dec = sched.decide(jobs, now=0.0)
        for j in dec.plan.job_gpu_map():
            assert dec.plan.is_consolidated(j), f"job {j} not consolidated"

    def test_packed_jobs_share_exact_gpus(self, profile):
        cluster = ClusterSpec(2, 4)
        jobs = synthetic_active_jobs(30, seed=2, profile=profile)
        sched = TesseraeScheduler(cluster, TiresiasPolicy(profile), profile)
        dec = sched.decide(jobs, now=0.0)
        gmap = dec.plan.job_gpu_map()
        for pending_id, placed_id in dec.packing.matches.items():
            assert gmap[pending_id] == gmap[placed_id]

    def test_migration_round_to_round(self, profile):
        cluster = ClusterSpec(2, 4)
        jobs = synthetic_active_jobs(12, seed=3, profile=profile)
        sched = TesseraeScheduler(cluster, TiresiasPolicy(profile), profile)
        d1 = sched.decide(jobs, now=0.0)
        # identical job set next round -> zero migrations expected
        d2 = sched.decide(jobs, now=360.0, prev_plan=d1.plan)
        assert d2.migration is not None
        assert d2.migration.num_migrations == 0


class TestSimulator:
    def test_all_jobs_finish(self, profile):
        cluster = ClusterSpec(4, 4)
        trace = shockwave_trace(num_jobs=25, seed=0, profile=profile)
        sched = TesseraeScheduler(cluster, TiresiasPolicy(profile), profile)
        res = _sim(cluster, trace, sched, profile)
        assert all(s.finished for s in res.jobs.values())
        assert res.makespan_s > 0
        assert np.all(res.jcts > 0)

    def test_deterministic(self, profile):
        cluster = ClusterSpec(2, 4)
        trace = shockwave_trace(num_jobs=15, seed=1, profile=profile)
        r1 = _sim(
            cluster,
            trace,
            TesseraeScheduler(cluster, TiresiasPolicy(profile), profile),
            profile,
        )
        r2 = _sim(
            cluster,
            trace,
            TesseraeScheduler(cluster, TiresiasPolicy(profile), profile),
            profile,
        )
        assert r1.avg_jct_s == r2.avg_jct_s
        assert r1.makespan_s == r2.makespan_s

    def test_packing_improves_jct_under_contention(self, profile):
        cluster = ClusterSpec(2, 4)
        trace = shockwave_trace(num_jobs=40, seed=2, profile=profile)
        base = _sim(
            cluster,
            trace,
            TesseraeScheduler(
                cluster, TiresiasPolicy(profile), profile, enable_packing=False
            ),
            profile,
        )
        packed = _sim(
            cluster,
            trace,
            TesseraeScheduler(
                cluster, TiresiasPolicy(profile), profile, enable_packing=True
            ),
            profile,
        )
        assert packed.avg_jct_s < base.avg_jct_s

    def test_migration_remap_reduces_migrations(self, profile):
        cluster = ClusterSpec(4, 4)
        trace = shockwave_trace(num_jobs=40, seed=3, profile=profile)
        none = _sim(
            cluster,
            trace,
            TesseraeScheduler(
                cluster,
                TiresiasPolicy(profile),
                profile,
                migration_algorithm="none",
            ),
            profile,
        )
        node = _sim(
            cluster,
            trace,
            TesseraeScheduler(
                cluster,
                TiresiasPolicy(profile),
                profile,
                migration_algorithm="node",
            ),
            profile,
        )
        assert node.total_migrations < none.total_migrations

    def test_tiresias_single_packs_less(self, profile):
        cluster = ClusterSpec(2, 4)
        trace = shockwave_trace(num_jobs=40, seed=4, profile=profile)
        full = _sim(
            cluster,
            trace,
            TesseraeScheduler(cluster, TiresiasPolicy(profile), profile),
            profile,
        )
        single = _sim(
            cluster,
            trace,
            TesseraeScheduler(
                cluster,
                TiresiasPolicy(profile),
                profile,
                packed_ok=tiresias_single_packed_ok,
            ),
            profile,
        )
        assert full.avg_jct_s <= single.avg_jct_s * 1.05

    def test_ftf_policy_runs(self, profile):
        cluster = ClusterSpec(2, 4)
        trace = gavel_trace(num_jobs=15, seed=5, profile=profile)
        res = _sim(
            cluster,
            trace,
            TesseraeScheduler(cluster, ThemisFtfPolicy(profile), profile),
            profile,
        )
        rho = res.ftf_ratios(profile)
        assert len(rho) == 15 and np.all(np.isfinite(rho))

    def test_fifo_orders_by_arrival(self, profile):
        cluster = ClusterSpec(1, 4)
        trace = shockwave_trace(num_jobs=8, seed=6, profile=profile)
        res = _sim(
            cluster,
            trace,
            TesseraeScheduler(cluster, FifoPolicy(profile), profile),
            profile,
        )
        assert all(s.finished for s in res.jobs.values())


class TestStartupDebtSemantics:
    """Regression pins for the cold-start / resume / migration debt model
    (the former dead conditional in ``Simulator._advance_round``)."""

    def _trace(self, iters=(5000.0,)):
        from repro.core.jobs import JobSpec

        return [
            JobSpec(job_id=i, model="resnet50", num_gpus=1,
                    total_iters=it, arrival_time=0.0)
            for i, it in enumerate(iters)
        ]

    def test_cold_start_pays_startup_fraction(self, profile):
        from repro.core.jobs import migration_overhead_s

        cluster = ClusterSpec(1, 1)
        sched = TesseraeScheduler(
            cluster, TiresiasPolicy(profile), profile, enable_packing=False
        )
        res = _sim(cluster, self._trace(), sched, profile)
        job = res.jobs[0]
        # first progress happens only after the cold-start debt is paid
        assert job.first_run_time == pytest.approx(
            0.5 * migration_overhead_s("resnet50")
        )

    def test_resume_fraction_default_matches_seed_semantics(self, profile):
        """``resume_fraction=None`` must behave exactly like the seed
        (resume charged at ``startup_fraction``)."""
        cluster = ClusterSpec(1, 1)
        mk = lambda: TesseraeScheduler(
            cluster, TiresiasPolicy(profile), profile, enable_packing=False
        )
        trace = self._trace((25000.0, 5000.0))
        r_default = _sim(cluster, trace, mk(), profile)
        r_explicit = _sim(cluster, trace, mk(), profile, resume_fraction=0.5)
        assert np.allclose(sorted(r_default.jcts), sorted(r_explicit.jcts))

    def test_resume_fraction_distinct_from_cold_start(self, profile):
        """A long job demotes past the Tiresias queue threshold, yields the
        single GPU to the short job, then RESUMES: making resumes free must
        shorten its JCT while a pricier resume must lengthen it (cold-start
        debt unchanged in all three runs)."""
        cluster = ClusterSpec(1, 1)
        mk = lambda: TesseraeScheduler(
            cluster, TiresiasPolicy(profile), profile, enable_packing=False
        )
        trace = self._trace((25000.0, 5000.0))
        base = _sim(cluster, trace, mk(), profile)
        free = _sim(cluster, trace, mk(), profile, resume_fraction=0.0)
        costly = _sim(cluster, trace, mk(), profile, resume_fraction=1.0)
        # the long job (id 0) is the one that resumes
        assert free.jobs[0].finish_time < base.jobs[0].finish_time
        assert base.jobs[0].finish_time < costly.jobs[0].finish_time
        # the short job never resumes: identical across configs
        assert free.jobs[1].finish_time == costly.jobs[1].finish_time

    def test_speculative_prewarm_does_not_change_outcomes(self, profile):
        cluster = ClusterSpec(2, 4)
        trace = shockwave_trace(num_jobs=15, seed=7, profile=profile)
        mk = lambda: TesseraeScheduler(cluster, TiresiasPolicy(profile), profile)
        plain = _sim(cluster, trace, mk(), profile)
        sched = mk()
        spec = _sim(cluster, trace, sched, profile, speculative_prewarm=True)
        assert np.allclose(sorted(plain.jcts), sorted(spec.jcts))
        assert plain.total_migrations == spec.total_migrations
        # the context actually absorbed the speculative solves
        assert sched.match_context.stats["solves"] > 0
        assert sched.match_context.stats["memo_hits"] > 0

    @pytest.mark.timing
    def test_speculative_prewarm_runs_off_the_critical_path(self, profile):
        """The prewarm decide work happens on the background thread: its
        wall time is telemetered, part of it OVERLAPS the sim loop (the
        loop never just sleeps on it), and the measured decide() rounds
        serve warm/memo hits the plain run cannot."""
        cluster = ClusterSpec(2, 4)
        trace = shockwave_trace(num_jobs=15, seed=7, profile=profile)
        mk = lambda: TesseraeScheduler(cluster, TiresiasPolicy(profile), profile)
        plain = _sim(cluster, trace, mk(), profile)
        assert plain.prewarm_wall_s == 0.0 and plain.prewarm_overlap_s == 0.0
        # the overlap claim is backed by the match_stats deltas: measured
        # rounds are warm (the thread did the cold work between rounds)
        warm = lambda r: sum(rs.get("warm_instances", 0) for rs in r.match_rounds)
        # Overlap is a wall-clock MEASUREMENT, not a decision: on a
        # contended CPU the background thread can land entirely inside a
        # gap the loop never waited through, measuring 0.0 overlap for a
        # run whose decisions are still correct.  The deterministic
        # invariants hold on every attempt; only the timing observation
        # gets a bounded retry.
        for _ in range(3):
            spec = _sim(cluster, trace, mk(), profile, speculative_prewarm=True)
            assert spec.prewarm_wall_s > 0.0
            assert spec.prewarm_overlap_s <= spec.prewarm_wall_s
            assert warm(spec) > warm(plain)
            if spec.prewarm_overlap_s > 0.0:
                break
        else:
            pytest.fail("prewarm overlap measured 0.0 in 3 consecutive runs")

    def test_speculative_prewarm_identical_under_auction_backend(self, profile):
        """Prewarm speculation must stay decision-invariant when the
        context actually carries auction price state."""
        cluster = ClusterSpec(2, 4)
        trace = shockwave_trace(num_jobs=12, seed=3, profile=profile)
        mk = lambda: TesseraeScheduler(
            cluster, TiresiasPolicy(profile), profile, lap_backend="auction"
        )
        plain = _sim(cluster, trace, mk(), profile)
        spec = _sim(cluster, trace, mk(), profile, speculative_prewarm=True)
        assert np.allclose(sorted(plain.jcts), sorted(spec.jcts))
        assert plain.total_migrations == spec.total_migrations
