"""Property + regression tests for the unified batched matching engine."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.matching import (
    available_backends,
    register_backend,
    solve_lap,
    solve_lap_batched,
)
from repro.core.matching.engine import _BACKENDS

scipy_lsa = pytest.importorskip("scipy.optimize").linear_sum_assignment

AUCTION_BACKENDS = ["auction", "auction_kernel"]
ALL_BACKENDS = ["scipy", "numpy", "auction", "auction_kernel", "auto"]


def _scipy_optimum(cost, maximize=False):
    """Reference total on a single (masked-out already) instance.

    Prefers scipy's native inf handling (exact for feasible instances,
    and independent of the engine's pad embedding — so it can catch
    embedding bugs); falls back to a size-scaled finite fill only when
    scipy declares the instance infeasible, mirroring the engine's
    drop-forbidden contract.
    """
    bad = ~np.isfinite(cost)
    try:
        rows, cols = scipy_lsa(
            np.where(bad, -np.inf if maximize else np.inf, cost),
            maximize=maximize,
        )
    except ValueError:  # infeasible: no complete finite matching exists
        span = np.abs(cost[~bad]).max() if (~bad).any() else 1.0
        size = max(cost.shape)
        fill = 2.0 * size * span + 1.0
        filled = np.where(bad, -fill if maximize else fill, cost)
        rows, cols = scipy_lsa(filled, maximize=maximize)
    keep = ~bad[rows, cols]
    return cost[rows[keep], cols[keep]].sum()


def _eps_bound(n, m, backend):
    """Documented auction bound: S * eps_min with eps_min = 1/(S+1)."""
    if backend not in AUCTION_BACKENDS:
        return 1e-9
    s = max(n, m)
    return s / (s + 1) + 1e-6


def _check_result(res, costs, maximize, rm=None, cm=None):
    """Validity: permutation, masks never win, forbidden edges never used."""
    for b in range(costs.shape[0]):
        rows, cols = res.pairs(b)
        assert len(set(cols.tolist())) == len(cols)
        assert np.isfinite(costs[b][rows, cols]).all()
        if rm is not None:
            assert rm[b][rows].all(), "row padding won an assignment"
        if cm is not None:
            assert cm[b][cols].all(), "col padding won an assignment"
        want = _scipy_optimum(
            costs[b][rm[b]][:, cm[b]] if rm is not None else costs[b],
            maximize,
        )
        bound = _eps_bound(costs.shape[1], costs.shape[2], res.backend)
        if res.used_fallback[b]:
            bound = 1e-9  # fallback is exact
        assert abs(res.total_cost[b] - want) <= bound, (
            f"instance {b}: got {res.total_cost[b]}, scipy {want}"
        )


class TestBatchedOptimality:
    @pytest.mark.parametrize("backend", ALL_BACKENDS)
    @given(
        st.integers(1, 5),   # batch
        st.integers(1, 9),   # n
        st.integers(0, 2**32 - 1),
    )
    @settings(max_examples=20, deadline=None)
    def test_square_integer(self, backend, b, n, seed):
        rng = np.random.default_rng(seed)
        costs = rng.integers(0, 30, (b, n, n)).astype(float)
        res = solve_lap_batched(costs, backend=backend)
        _check_result(res, costs, maximize=False)

    @pytest.mark.parametrize("backend", ALL_BACKENDS)
    @given(
        st.integers(1, 4),
        st.integers(1, 8),
        st.integers(1, 8),
        st.integers(0, 2**32 - 1),
    )
    @settings(max_examples=20, deadline=None)
    def test_rectangular_float(self, backend, b, n, m, seed):
        rng = np.random.default_rng(seed)
        costs = rng.uniform(0, 10, (b, n, m))
        maximize = bool(seed % 2)
        res = solve_lap_batched(costs, maximize=maximize, backend=backend)
        _check_result(res, costs, maximize=maximize)
        for i in range(b):
            rows, _ = res.pairs(i)
            assert len(rows) == min(n, m)

    @pytest.mark.parametrize("backend", ALL_BACKENDS)
    def test_ties(self, backend):
        # all-equal and block-tied matrices: any permutation is optimal,
        # but the result must still be a valid complete assignment.
        costs = np.stack([
            np.ones((6, 6)),
            np.kron(np.arange(4).reshape(2, 2), np.ones((3, 3)))[:6, :6],
        ])
        res = solve_lap_batched(costs, backend=backend)
        _check_result(res, costs, maximize=False)

    @pytest.mark.parametrize("backend", ALL_BACKENDS)
    @given(st.integers(2, 8), st.integers(0, 2**32 - 1))
    @settings(max_examples=20, deadline=None)
    def test_forbidden_edges(self, backend, n, seed):
        rng = np.random.default_rng(seed)
        costs = rng.integers(0, 20, (3, n, n)).astype(float)
        forbid = rng.random((3, n, n)) < 0.2
        # keep a feasible diagonal so a complete matching always exists
        forbid[:, np.arange(n), np.arange(n)] = False
        costs = np.where(forbid, np.inf, costs)
        res = solve_lap_batched(costs, backend=backend)
        _check_result(res, costs, maximize=False)
        # a complete finite matching exists -> forbidden edges must never
        # force a dropped pair
        for i in range(costs.shape[0]):
            rows, _ = res.pairs(i)
            assert len(rows) == n

    @pytest.mark.parametrize("backend", ALL_BACKENDS)
    def test_mixed_sign_forbidden_regression(self, backend):
        """Found in review: with a constant -(2*span+1) pad, the square
        embedding preferred the forbidden cell over the complete finite
        matching on mixed-sign costs (pad now scales with instance size).
        """
        cost = np.array([[2.0, np.inf], [-2.0, 2.0]])
        res = solve_lap_batched(cost[None], backend=backend)
        rows, cols = res.pairs(0)
        assert len(rows) == 2, "forbidden edge displaced a real pair"
        assert res.total_cost[0] == 4.0

    @pytest.mark.parametrize("backend", ALL_BACKENDS)
    @given(st.integers(2, 7), st.integers(0, 2**32 - 1))
    @settings(max_examples=15, deadline=None)
    def test_mixed_sign_costs(self, backend, n, seed):
        rng = np.random.default_rng(seed)
        costs = rng.uniform(-10, 10, (3, n, n))
        forbid = rng.random((3, n, n)) < 0.2
        forbid[:, np.arange(n), np.arange(n)] = False
        costs = np.where(forbid, np.inf, costs)
        res = solve_lap_batched(costs, backend=backend)
        _check_result(res, costs, maximize=False)
        for i in range(3):
            rows, _ = res.pairs(i)
            assert len(rows) == n

    @pytest.mark.parametrize("backend", ALL_BACKENDS)
    @given(st.integers(3, 8), st.integers(3, 8), st.integers(0, 2**32 - 1))
    @settings(max_examples=20, deadline=None)
    def test_masks_never_win(self, backend, n, m, seed):
        rng = np.random.default_rng(seed)
        costs = rng.integers(0, 25, (4, n, m)).astype(float)
        rm = rng.random((4, n)) < 0.7
        cm = rng.random((4, m)) < 0.7
        rm[:, 0] = True  # keep at least one real row/col per instance
        cm[:, 0] = True
        res = solve_lap_batched(costs, row_mask=rm, col_mask=cm, backend=backend)
        _check_result(res, costs, maximize=False, rm=rm, cm=cm)
        # padded rows must be unassigned in col_of
        assert (res.col_of[~rm] == -1).all()


class TestRegressionCorpus:
    def test_200_instance_corpus(self):
        """Acceptance criterion: scipy-optimal total (within the documented
        n*eps bound) on 100% of a 200-instance corpus spanning square /
        rectangular / masked shapes, for every registered backend."""
        rng = np.random.default_rng(2026)
        corpus = []
        for i in range(200):
            n = int(rng.integers(1, 10))
            m = n if i % 3 == 0 else int(rng.integers(1, 10))
            integer = i % 2 == 0
            cost = (
                rng.integers(0, 40, (n, m)).astype(float)
                if integer
                else rng.uniform(0, 10, (n, m))
            )
            rm = cm = None
            maximize = bool(i % 4 == 1)
            if i % 5 == 4 and n > 1 and m > 1:
                rm = rng.random(n) < 0.8
                cm = rng.random(m) < 0.8
                rm[0] = cm[0] = True
            if i % 7 == 6:
                forbid = rng.random((n, m)) < 0.15
                # sign-appropriate forbidden encoding (the engine rejects
                # "attractive" infinities of the opposite sign)
                cost = np.where(forbid, -np.inf if maximize else np.inf, cost)
            corpus.append((cost, rm, cm, maximize))

        for backend in ["scipy", "numpy", "auction", "auction_kernel"]:
            failures = 0
            for cost, rm, cm, maximize in corpus:
                res = solve_lap_batched(
                    cost[None],
                    maximize=maximize,
                    row_mask=None if rm is None else rm[None],
                    col_mask=None if cm is None else cm[None],
                    backend=backend,
                )
                sub = cost
                if rm is not None:
                    sub = sub[rm][:, cm]
                want = _scipy_optimum(sub, maximize)
                bound = _eps_bound(*cost.shape, backend)
                if res.used_fallback[0]:
                    bound = 1e-9
                if abs(res.total_cost[0] - want) > bound:
                    failures += 1
            assert failures == 0, f"{backend}: {failures}/200 corpus failures"


class TestEngineApi:
    def test_registry_lists_backends(self):
        names = available_backends()
        for expected in ["scipy", "numpy", "smallperm", "auction", "auction_kernel", "auto"]:
            assert expected in names

    def test_unknown_backend_raises(self):
        with pytest.raises(ValueError, match="unknown LAP backend"):
            solve_lap_batched(np.zeros((1, 2, 2)), backend="nope")

    def test_register_custom_backend(self):
        @register_backend("_test_identity")
        def _identity(benefit, eps_min=None, max_iters=None):
            b, s, _ = benefit.shape
            col = np.tile(np.arange(s, dtype=np.int64), (b, 1))
            return col, np.ones(b, bool)

        try:
            costs = np.ones((2, 3, 3))
            res = solve_lap_batched(costs, backend="_test_identity")
            assert (res.col_of == np.arange(3)).all()
            assert np.allclose(res.total_cost, 3.0)
        finally:
            del _BACKENDS["_test_identity"]

    def test_single_instance_2d_input(self):
        rng = np.random.default_rng(0)
        cost = rng.integers(0, 10, (5, 5)).astype(float)
        res = solve_lap_batched(cost, backend="auction")
        assert res.col_of.shape == (1, 5)

    def test_solve_lap_auction_matches_scipy(self):
        rng = np.random.default_rng(1)
        cost = rng.integers(0, 30, (9, 9)).astype(float)
        rows, cols = solve_lap(cost, backend="auction")
        want = _scipy_optimum(cost)
        assert np.isclose(cost[rows, cols].sum(), want)

    def test_empty_batch_and_empty_instance(self):
        res = solve_lap_batched(np.zeros((0, 4, 4)))
        assert res.col_of.shape == (0, 4)
        res = solve_lap_batched(np.zeros((2, 0, 3)))
        assert res.col_of.shape == (2, 0)
        assert (res.total_cost == 0).all()

    def test_smallperm_rejects_large(self):
        with pytest.raises(ValueError, match="smallperm"):
            solve_lap_batched(np.zeros((1, 8, 8)), backend="smallperm")

    def test_wall_time_recorded(self):
        res = solve_lap_batched(np.ones((1, 3, 3)))
        assert res.wall_time_s >= 0.0


class TestConvergenceFallback:
    def test_non_converged_instances_fall_back(self):
        """Starved of iterations, the auction cannot finish; the engine must
        hand exactly those instances to scipy and still return optimal."""
        rng = np.random.default_rng(3)
        costs = rng.integers(0, 50, (4, 8, 8)).astype(float)
        res = solve_lap_batched(costs, backend="auction", max_iters=2)
        assert res.used_fallback.all()
        assert not res.converged.any()
        _check_result(res, costs, maximize=False)

    def test_converged_instances_do_not_fall_back(self):
        rng = np.random.default_rng(4)
        costs = rng.integers(0, 20, (3, 5, 5)).astype(float)
        res = solve_lap_batched(costs, backend="auction")
        assert res.converged.all()
        assert not res.used_fallback.any()
