"""Dry-run integration: run the real 512-device lower+compile in a
subprocess (keeps this test process at 1 device, per the brief)."""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=420):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
        cwd=REPO,
    )


@pytest.mark.parametrize(
    "arch,shape",
    [
        ("mamba2-780m", "decode_32k"),     # SSM serve_step
        ("qwen2-vl-2b", "prefill_32k"),    # VLM frontend stub + M-RoPE
    ],
)
def test_single_pod_dryrun_compiles(arch, shape):
    res = _run(["--arch", arch, "--shape", shape, "--no-correct"])
    assert res.returncode == 0, res.stderr[-2000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("{")][-1]
    d = json.loads(line)
    assert d["chips"] == 256 and d["mesh"] == "16x16"
    assert d["hlo_flops_per_device"] > 0
    assert d["bottleneck"] in ("compute", "memory", "collective")


def test_multi_pod_dryrun_compiles():
    res = _run(
        ["--arch", "mamba2-780m", "--shape", "decode_32k", "--multi-pod", "--no-correct"]
    )
    assert res.returncode == 0, res.stderr[-2000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("{")][-1]
    d = json.loads(line)
    assert d["chips"] == 512 and d["mesh"] == "2x16x16"
    # cross-pod data parallelism must produce collectives
    assert d["collective_bytes_per_device"] > 0
