"""Per-architecture smoke tests (reduced configs, CPU).

For each of the 10 assigned architectures: instantiate the reduced variant
(<=2 layers, d_model<=512, <=4 experts), run one forward and one train
step, assert output shapes and no NaNs; run one decode step against a KV
cache.  Plus decode-vs-forward consistency checks (prefill parity) for one
attention arch and one SSM arch.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced, list_archs
from repro.models import get_model
from repro.serve.engine import ServeConfig, init_serving_cache, make_serve_step
from repro.train.data import batch_for
from repro.train.step import TrainConfig, loss_fn, make_train_step, train_state_init

SEQ = 32
BATCH = 2


def _batch(cfg, batch=BATCH, seq=SEQ, seed=0):
    b = batch_for(
        cfg.vocab_size,
        batch,
        seq,
        seed=seed,
        frontend=cfg.frontend,
        frontend_len=cfg.frontend_len,
        d_model=cfg.d_model,
    )
    return {k: jnp.asarray(v) for k, v in b.items()}


@pytest.mark.parametrize("arch", list_archs())
class TestArchSmoke:
    def test_forward_shapes_no_nan(self, arch):
        cfg = get_reduced(arch)
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0), cfg)
        batch = _batch(cfg)
        logits, aux = jax.jit(lambda p, b: model.forward(p, cfg, b))(params, batch)
        s_total = SEQ + (cfg.frontend_len if cfg.frontend == "vision" else 0)
        assert logits.shape == (BATCH, s_total, cfg.vocab_size)
        assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
        assert not bool(jnp.isnan(aux))

    def test_train_step(self, arch):
        cfg = get_reduced(arch)
        tc = TrainConfig()
        state = train_state_init(jax.random.PRNGKey(0), cfg, tc)
        step = jax.jit(make_train_step(cfg, tc))
        batch = _batch(cfg)
        state, metrics = step(state, batch)
        assert np.isfinite(float(metrics["loss"]))
        assert float(metrics["grad_norm"]) > 0.0
        # params actually changed
        before = train_state_init(jax.random.PRNGKey(0), cfg, tc)["params"]
        diff = jax.tree.map(
            lambda a, b: float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()),
            state["params"],
            before,
        )
        assert max(jax.tree.leaves(diff)) > 0.0

    def test_decode_step(self, arch):
        cfg = get_reduced(arch)
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0), cfg)
        sc = ServeConfig(batch_size=BATCH, context_len=64)
        cache = init_serving_cache(cfg, sc)
        step = jax.jit(make_serve_step(cfg))
        tok = jnp.zeros((BATCH, 1), jnp.int32)
        logits, new_cache = step(params, tok, cache, jnp.asarray(0))
        assert logits.shape == (BATCH, 1, cfg.vocab_size)
        assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
        # cache structure preserved
        assert jax.tree.structure(cache) == jax.tree.structure(new_cache)

    @pytest.mark.slow
    def test_microbatched_train_step_matches(self, arch):
        cfg = get_reduced(arch)
        if cfg.frontend == "audio":
            pytest.skip("audio frames are static across microbatches")
        tc1 = TrainConfig(microbatches=1)
        tc2 = TrainConfig(microbatches=2)
        s1 = train_state_init(jax.random.PRNGKey(0), cfg, tc1)
        s2 = train_state_init(jax.random.PRNGKey(0), cfg, tc2)
        batch = _batch(cfg)
        _, m1 = jax.jit(make_train_step(cfg, tc1))(s1, batch)
        _, m2 = jax.jit(make_train_step(cfg, tc2))(s2, batch)
        assert np.isfinite(float(m2["loss"]))
        # MoE aux differs (per-microbatch balance); NLL should be close
        np.testing.assert_allclose(
            float(m1["nll"]), float(m2["nll"]), rtol=0.08
        )


class TestDecodeParity:
    """Prefill parity: stepping tokens one-by-one through decode_step must
    reproduce the full-sequence forward logits."""

    @pytest.mark.slow
    @pytest.mark.parametrize("arch", ["llama3-8b", "qwen3-14b", "mamba2-780m", "deepseek-v2-236b"])
    def test_decode_matches_forward(self, arch):
        import dataclasses

        cfg = get_reduced(arch)
        if cfg.num_experts:
            # capacity dropping only exists in the batched forward — make the
            # router lossless so decode parity is well-defined.  MoE parity
            # also runs in f32: in bf16 the absorbed MLA decode path and the
            # batched forward accumulate in different association orders,
            # and that sub-tolerance noise (~0.03 on logits, within
            # rtol/atol=0.05 everywhere) can flip the DISCONTINUOUS top-k
            # router for knife-edge tokens — observed: one token whose #2/#3
            # expert probs differ by 0.005 routes differently, making that
            # single token's logits diverge by 0.68 while all other
            # positions agree.  In f32 the absorbed/cached path matches the
            # forward to ~4e-6, so this asserts the cache-path MATH strictly
            # instead of loosening the tolerance past a routing flip.
            cfg = dataclasses.replace(
                cfg, capacity_factor=16.0, dtype="float32"
            )
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(1), cfg)
        seq = SEQ
        batch = _batch(cfg, seq=seq, seed=3)
        logits_full, _ = jax.jit(lambda p, b: model.forward(p, cfg, b))(params, batch)

        cache = model.init_cache(cfg, BATCH, seq)
        step = jax.jit(
            lambda p, t, c, pos: model.decode_step(p, cfg, {"tokens": t}, c, pos)
        )
        outs = []
        toks = batch["tokens"]
        for i in range(seq):
            lg, cache = step(params, toks[:, i : i + 1], cache, jnp.asarray(i))
            outs.append(lg)
        logits_step = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(
            np.asarray(logits_step, np.float32),
            np.asarray(logits_full, np.float32),
            rtol=0.05,
            atol=0.05,
        )

    def test_sliding_window_ring_buffer(self):
        """Decode past the window: ring buffer must overwrite oldest slots
        and logits must match a model whose cache is exactly the window of
        most recent tokens."""
        cfg = get_reduced("llama3-8b")
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(2), cfg)
        window = 8
        cache = model.init_cache(cfg, 1, window)
        step = jax.jit(
            lambda p, t, c, pos: model.decode_step(p, cfg, {"tokens": t}, c, pos)
        )
        rng = np.random.default_rng(0)
        toks = rng.integers(0, cfg.vocab_size, size=(1, 20)).astype(np.int32)
        for i in range(20):
            lg, cache = step(params, jnp.asarray(toks[:, i : i + 1]), cache, jnp.asarray(i))
        assert np.isfinite(np.asarray(lg, np.float32)).all()
