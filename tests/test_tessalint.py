"""tessalint self-tests: per-rule positive/negative fixtures, pragma
suppression semantics, manifest scoping, the JSON schema round-trip, and
the "real tree lints clean" gate the CI lane enforces.
"""

import json
import textwrap
from pathlib import Path

import pytest

from tools.tessalint import JSON_VERSION, Finding, Manifest, lint_file, run_paths
from tools.tessalint.__main__ import main as cli_main
from tools.tessalint.findings import report
from tools.tessalint.manifest import (
    DEFAULT_MANIFEST_PATH,
    MANIFEST_VERSION,
    RuleConfig,
)
from tools.tessalint.passes import ALL_RULES

REPO_ROOT = Path(__file__).resolve().parents[1]

_JAX_PRELUDE = """\
import jax
import jax.numpy as jnp
import numpy as np
"""


def _lint(tmp_path, source, rule, options=None, filename="mod.py", rules=...):
    """Lint a fixture source with one rule scoped over it."""
    p = tmp_path / filename
    p.write_text(textwrap.dedent(source))
    man = Manifest({rule: RuleConfig(include=["*.py"], options=options or {})})
    if rules is ...:
        rules = [rule]
    return lint_file(p, man, rules=rules)


def _active(findings, rule):
    return [f for f in findings if f.rule == rule and not f.suppressed]


# --------------------------------------------------------------------------- #
# Rule: sync
# --------------------------------------------------------------------------- #
class TestSyncRule:
    @pytest.mark.parametrize(
        "body,needle",
        [
            # np.asarray on a device-annotated parameter
            ("def f(x: jax.Array):\n    return np.asarray(x)\n", "asarray"),
            # device_get is ALWAYS a flagged sync point
            ("def f(x: jax.Array):\n    return jax.device_get(x)\n", "device_get"),
            # float() coercion of a produced device value (taint chain)
            (
                "def f():\n    t = jnp.sum(jnp.ones(3))\n    u = t * 2\n"
                "    return float(u)\n",
                "coercion",
            ),
            # host control flow on a device value
            (
                "def f(x: jax.Array):\n    if x > 0:\n        return 1\n"
                "    return 0\n",
                "control flow",
            ),
            # .item() sync method
            ("def f(x: jax.Array):\n    return x.item()\n", ".item()"),
            # f-string formatting (P2)
            ("def f(x: jax.Array):\n    return f'{x}'\n", "f-string"),
        ],
    )
    def test_positive(self, tmp_path, body, needle):
        found = _active(_lint(tmp_path, _JAX_PRELUDE + body, "sync"), "sync")
        assert found, body
        assert any(needle in f.message for f in found)

    @pytest.mark.parametrize(
        "body",
        [
            # untainted argument: plain host conversion
            "def f(xs):\n    return np.asarray(xs)\n",
            # `is None` identity test never reads device data
            "def f(x: jax.Array):\n    if x is None:\n        return None\n"
            "    return x\n",
            # .ndim / .shape are host-side metadata
            "def f(x: jax.Array):\n    if x.ndim == 3:\n        return 1\n"
            "    return 0\n",
            # shape-derived ints are not tainted
            "def f(x: jax.Array):\n    n = x.shape[0]\n    if n > 2:\n"
            "        return n\n    return 0\n",
            # device math without any host crossing
            "def f(x: jax.Array):\n    return jnp.sum(x) * 2\n",
        ],
    )
    def test_negative(self, tmp_path, body):
        assert not _active(_lint(tmp_path, _JAX_PRELUDE + body, "sync"), "sync"), body

    def test_closure_inherits_taint(self, tmp_path):
        src = _JAX_PRELUDE + (
            "def outer(x: jax.Array):\n"
            "    def inner():\n"
            "        return float(x)\n"
            "    return inner\n"
        )
        assert _active(_lint(tmp_path, src, "sync"), "sync")

    def test_extra_producers_option(self, tmp_path):
        src = (
            "import numpy as np\nimport repro.kernels.ops as ops\n"
            "def f(a):\n    out = ops.lap_bid(a, a)\n    return np.asarray(out)\n"
        )
        # without the option the kernel result is not known to be device
        assert not _active(_lint(tmp_path, src, "sync"), "sync")
        found = _lint(
            tmp_path, src, "sync", options={"device_producers": ["repro.kernels."]}
        )
        assert _active(found, "sync")


# --------------------------------------------------------------------------- #
# Rule: det
# --------------------------------------------------------------------------- #
class TestDetRule:
    @pytest.mark.parametrize(
        "body,needle",
        [
            ("import time\ndef f():\n    return time.time()\n", "wall clock"),
            (
                "import numpy as np\ndef f():\n    return np.random.rand(3)\n",
                "legacy",
            ),
            (
                "import numpy as np\ndef f():\n"
                "    return np.random.default_rng()\n",
                "without a seed",
            ),
            ("import random\ndef f():\n    return random.random()\n", "stdlib RNG"),
            (
                "def f(xs):\n    return [x for x in set(xs)]\n",
                "iteration order",
            ),
            (
                "def f(xs, ys):\n    out = []\n"
                "    for v in set(xs).intersection(set(ys)):\n"
                "        out.append(v)\n    return out\n",
                "iteration order",
            ),
        ],
    )
    def test_positive(self, tmp_path, body, needle):
        found = _active(_lint(tmp_path, body, "det"), "det")
        assert found, body
        assert any(needle in f.message for f in found)

    @pytest.mark.parametrize(
        "body",
        [
            # durations may use perf_counter (the watchdog pattern)
            "import time\ndef f():\n    return time.perf_counter()\n",
            # seeded generator
            "import numpy as np\ndef f():\n    return np.random.default_rng(42)\n",
            # sorted() makes set order deterministic
            "def f(xs):\n    return [x for x in sorted(set(xs))]\n",
            # instance RNG with explicit seed
            "import random\ndef f():\n    return random.Random(7)\n",
            # list iteration is ordered
            "def f(xs):\n    return [x for x in list(xs)]\n",
        ],
    )
    def test_negative(self, tmp_path, body):
        assert not _active(_lint(tmp_path, body, "det"), "det"), body

    def test_dict_keys_opt_in(self, tmp_path):
        src = "def f(d):\n    return [k for k in d.keys()]\n"
        assert not _active(_lint(tmp_path, src, "det"), "det")
        found = _lint(tmp_path, src, "det", options={"flag_dict_keys": True})
        assert _active(found, "det")


# --------------------------------------------------------------------------- #
# Rule: jit
# --------------------------------------------------------------------------- #
class TestJitRule:
    @pytest.mark.parametrize(
        "body,needle",
        [
            (
                "import functools\nimport jax\n"
                "@functools.partial(jax.jit, static_argnames=('mode',))\n"
                "def f(x):\n    return x\n",
                "not a parameter",
            ),
            (
                "import jax\nCACHE = {}\n@jax.jit\ndef f(x):\n"
                "    return CACHE.get('k', 0) + x\n",
                "mutable",
            ),
            (
                "import jax\n@jax.jit\ndef f(x):\n    if x > 0:\n"
                "        return x\n    return -x\n",
                "control flow on traced parameter",
            ),
            (
                "import jax\n@jax.jit\ndef f(x):\n    global G\n    G = x\n"
                "    return x\n",
                "global",
            ),
            (
                "import jax\n@jax.jit\ndef f(x):\n    if x.shape[0] > 4:\n"
                "        return x * 2\n    return x\n",
                "recompiles",
            ),
            (
                "import jax\n"
                "@jax.jit(static_argnums=(3,))\n"
                "def f(x, y):\n    return x + y\n",
                "out of range",
            ),
        ],
    )
    def test_positive(self, tmp_path, body, needle):
        found = _active(_lint(tmp_path, body, "jit"), "jit")
        assert found, body
        assert any(needle in f.message for f in found)

    @pytest.mark.parametrize(
        "body",
        [
            # branching on a STATIC argument is the point of static args
            "import functools\nimport jax\n"
            "@functools.partial(jax.jit, static_argnames=('mode',))\n"
            "def f(x, mode):\n    if mode:\n        return x * 2\n    return x\n",
            # `is None` optional-arg dispatch is trace-time and idiomatic
            "import jax\n@jax.jit\ndef f(x, y=None):\n    if y is None:\n"
            "        return x\n    return x + y\n",
            # a shape branch that only raises is input validation
            "import jax\n@jax.jit\ndef f(x):\n    if x.ndim != 2:\n"
            "        raise ValueError('want 2-D')\n    return x\n",
            # module mutables are fine outside jit
            "CACHE = {}\ndef f(x):\n    return CACHE.get('k', 0) + x\n",
            # tuple module constant is not mutable capture
            "import jax\nDIMS = (1, 2)\n@jax.jit\ndef f(x):\n"
            "    return x + DIMS[0]\n",
        ],
    )
    def test_negative(self, tmp_path, body):
        assert not _active(_lint(tmp_path, body, "jit"), "jit"), body

    def test_jit_rebinding_form(self, tmp_path):
        src = (
            "import jax\ndef _f(x):\n    if x > 0:\n        return x\n"
            "    return -x\nf = jax.jit(_f)\n"
        )
        assert _active(_lint(tmp_path, src, "jit"), "jit")


# --------------------------------------------------------------------------- #
# Rule: mantissa
# --------------------------------------------------------------------------- #
class TestMantissaRule:
    WHOLE = {"functions": ["*"]}

    @pytest.mark.parametrize(
        "body,needle",
        [
            ("def plan():\n    pen = 0.3\n    return pen\n", "neither a half-unit"),
            (
                "def plan(total):\n    cost = total / 3.0\n    return cost\n",
                "unquantised division",
            ),
            (
                "def plan(base, n):\n    weights = base / n\n    return weights\n",
                "unquantised division",
            ),
        ],
    )
    def test_positive(self, tmp_path, body, needle):
        found = _active(_lint(tmp_path, body, "mantissa", options=self.WHOLE), "mantissa")
        assert found, body
        assert any(needle in f.message for f in found)

    @pytest.mark.parametrize(
        "body",
        [
            # half-units and powers of two are the allowed shapes
            "def plan():\n    pen = 1.5\n    scale = 0.25\n    return pen + scale\n",
            # power-of-two divisor keeps the lattice
            "def plan(total):\n    cost = total / 4.0\n    return cost\n",
            "def plan(total, k):\n    cost = total / 2**k\n    return cost\n",
            # non-cost-carrying names may divide freely
            "def plan(a):\n    tmp = a / 3\n    return tmp\n",
        ],
    )
    def test_negative(self, tmp_path, body):
        assert not _active(
            _lint(tmp_path, body, "mantissa", options=self.WHOLE), "mantissa"
        ), body

    def test_function_scoping(self, tmp_path):
        src = (
            "def scoped():\n    pen = 0.3\n    return pen\n"
            "def unscoped():\n    pen = 0.7\n    return pen\n"
        )
        found = _active(
            _lint(tmp_path, src, "mantissa", options={"functions": ["scoped"]}),
            "mantissa",
        )
        assert len(found) == 1 and found[0].line == 2


# --------------------------------------------------------------------------- #
# Rule: thread
# --------------------------------------------------------------------------- #
class TestThreadRule:
    @pytest.mark.parametrize(
        "body,needle",
        [
            # fire-and-forget: no join point anywhere in the function
            (
                "def run(self):\n    self.pool.submit(self.sched.prewarm)\n",
                "no join point",
            ),
            # owner touched between submit and join
            (
                "def run(self):\n"
                "    fut = self.pool.submit(self.sched.prewarm)\n"
                "    x = self.sched.stats\n"
                "    fut.result()\n"
                "    return x\n",
                "may still own",
            ),
            # threading.Thread(target=bound method), never joined
            (
                "import threading\n"
                "def go(self):\n"
                "    t = threading.Thread(target=self.ctx.poke)\n"
                "    t.start()\n",
                "no join point",
            ),
        ],
    )
    def test_positive(self, tmp_path, body, needle):
        found = _active(_lint(tmp_path, body, "thread"), "thread")
        assert found, body
        assert any(needle in f.message for f in found)

    @pytest.mark.parametrize(
        "body",
        [
            # the simulator pattern: join BEFORE touching the owner again
            "def run(self):\n"
            "    fut = self.pool.submit(self.sched.prewarm)\n"
            "    fut.result()\n"
            "    x = self.sched.stats\n"
            "    return x\n",
            # submitting a plain function shares no bound state
            "def run(self, work):\n"
            "    fut = self.pool.submit(work)\n"
            "    return fut\n",
            # no threading at all
            "def run(self):\n    return self.sched.stats\n",
        ],
    )
    def test_negative(self, tmp_path, body):
        assert not _active(_lint(tmp_path, body, "thread"), "thread"), body


# --------------------------------------------------------------------------- #
# Pragmas
# --------------------------------------------------------------------------- #
class TestPragmas:
    def test_suppression_with_reason(self, tmp_path):
        src = _JAX_PRELUDE + (
            "def f(x: jax.Array):\n"
            "    return np.asarray(x)  # tessalint: sync-ok(documented readout)\n"
        )
        found = _lint(tmp_path, src, "sync", rules=None)
        syncs = [f for f in found if f.rule == "sync"]
        assert syncs and all(f.suppressed for f in syncs)
        assert syncs[0].suppress_reason == "documented readout"
        assert not [f for f in found if f.rule == "pragma"]

    def test_bare_pragma_needs_reason(self, tmp_path):
        src = _JAX_PRELUDE + (
            "def f(x: jax.Array):\n"
            "    return np.asarray(x)  # tessalint: sync-ok()\n"
        )
        found = _lint(tmp_path, src, "sync", rules=None)
        assert any(
            f.rule == "pragma" and "no reason" in f.message for f in found
        )
        # and the empty pragma does NOT suppress
        assert _active(found, "sync")

    def test_unknown_rule_pragma(self, tmp_path):
        src = "x = 1  # tessalint: nosuchrule-ok(whatever)\n"
        found = _lint(tmp_path, src, "sync", rules=None)
        assert any(
            f.rule == "pragma" and "unknown rule" in f.message for f in found
        )

    def test_unused_pragma_flagged(self, tmp_path):
        src = _JAX_PRELUDE + (
            "def f(xs):\n"
            "    return np.asarray(xs)  # tessalint: sync-ok(stale excuse)\n"
        )
        found = _lint(tmp_path, src, "sync", rules=None)
        assert any(
            f.rule == "pragma" and "unused suppression" in f.message for f in found
        )

    def test_reason_may_contain_parens_and_commas(self, tmp_path):
        src = _JAX_PRELUDE + (
            "def f(x: jax.Array):\n"
            "    return np.asarray(x)"
            "  # tessalint: sync-ok(syncs only the (B,) verdict, see docstring)\n"
        )
        found = _lint(tmp_path, src, "sync", rules=None)
        syncs = [f for f in found if f.rule == "sync"]
        assert syncs and syncs[0].suppressed
        assert "(B,)" in syncs[0].suppress_reason
        assert not [f for f in found if f.rule == "pragma"]

    def test_multi_rule_pragma(self, tmp_path):
        src = _JAX_PRELUDE + (
            "import time\n"
            "def f(x: jax.Array):\n"
            "    return np.asarray(x), time.time()"
            "  # tessalint: sync-ok(readout), det-ok(telemetry only)\n"
        )
        p = tmp_path / "mod.py"
        p.write_text(src)
        man = Manifest(
            {
                "sync": RuleConfig(include=["*.py"]),
                "det": RuleConfig(include=["*.py"]),
            }
        )
        found = lint_file(p, man)
        assert found and all(f.suppressed for f in found if f.rule in ("sync", "det"))

    def test_pragma_on_any_line_of_multiline_expr(self, tmp_path):
        src = _JAX_PRELUDE + (
            "def f(x: jax.Array):\n"
            "    return np.asarray(  # tessalint: sync-ok(readout spans lines)\n"
            "        x\n"
            "    )\n"
        )
        found = _lint(tmp_path, src, "sync", rules=None)
        syncs = [f for f in found if f.rule == "sync"]
        assert syncs and all(f.suppressed for f in syncs)

    def test_syntax_error_reported_not_crashed(self, tmp_path):
        p = tmp_path / "broken.py"
        p.write_text("def f(:\n")
        man = Manifest({"sync": RuleConfig(include=["*.py"])})
        found = lint_file(p, man)
        assert len(found) == 1 and "does not parse" in found[0].message


# --------------------------------------------------------------------------- #
# Manifest scoping
# --------------------------------------------------------------------------- #
class TestManifest:
    SRC = _JAX_PRELUDE + "def f(x: jax.Array):\n    return np.asarray(x)\n"

    def test_rule_without_entry_runs_nowhere(self, tmp_path):
        p = tmp_path / "mod.py"
        p.write_text(self.SRC)
        assert lint_file(p, Manifest({}), rules=["sync"]) == []

    def test_include_exclude(self, tmp_path):
        (tmp_path / "core").mkdir()
        (tmp_path / "core" / "dev.py").write_text(self.SRC)
        (tmp_path / "core" / "host.py").write_text(self.SRC)
        man = Manifest(
            {
                "sync": RuleConfig(
                    include=["core/*.py"], exclude=["core/host.py"]
                )
            }
        )
        assert _active(lint_file(tmp_path / "core" / "dev.py", man), "sync")
        assert not _active(lint_file(tmp_path / "core" / "host.py", man), "sync")

    def test_suffix_matching_from_absolute_path(self, tmp_path):
        # the repo manifest says "src/repro/core/fused.py"; a fixture copy
        # living under an absolute tmp dir must still match
        d = tmp_path / "src" / "repro" / "core"
        d.mkdir(parents=True)
        p = d / "fused.py"
        p.write_text(self.SRC)
        man = Manifest({"sync": RuleConfig(include=["src/repro/core/fused.py"])})
        assert _active(lint_file(p, man), "sync")

    def test_version_mismatch_raises(self, tmp_path):
        bad = tmp_path / "m.json"
        bad.write_text(json.dumps({"version": "tessalint-manifest-v0", "rules": {}}))
        with pytest.raises(ValueError, match="version"):
            Manifest.load(bad)

    def test_repo_manifest_loads_and_names_known_rules(self):
        man = Manifest.load(DEFAULT_MANIFEST_PATH)
        assert man.rules, "repo manifest must scope at least one rule"
        for rule in man.rules:
            assert rule in ALL_RULES
        assert MANIFEST_VERSION == "tessalint-manifest-v1"


# --------------------------------------------------------------------------- #
# JSON schema / report round-trip
# --------------------------------------------------------------------------- #
class TestReportSchema:
    def test_finding_round_trip(self):
        f = Finding(
            "sync",
            "src/x.py",
            10,
            4,
            "message",
            snippet="np.asarray(x)",
            hint="do not",
            severity="P1",
            suppressed=True,
            suppress_reason="because",
            end_line=12,
        )
        assert Finding.from_dict(f.to_dict()) == f

    def test_report_shape(self, tmp_path):
        src = _JAX_PRELUDE + "def f(x: jax.Array):\n    return np.asarray(x)\n"
        p = tmp_path / "mod.py"
        p.write_text(src)
        man = Manifest({"sync": RuleConfig(include=["*.py"])})
        rep, findings = run_paths([p], manifest=man)
        assert rep["version"] == JSON_VERSION
        assert rep["files_scanned"] == 1
        assert rep["counts"]["sync"] == len(rep["findings"]) > 0
        assert rep["suppressed_count"] == 0
        round_tripped = [Finding.from_dict(d) for d in rep["findings"]]
        assert round_tripped == [f for f in findings if not f.suppressed]

    def test_cli_json_output(self, tmp_path, capsys):
        src = _JAX_PRELUDE + "def f(x: jax.Array):\n    return np.asarray(x)\n"
        p = tmp_path / "mod.py"
        p.write_text(src)
        man = tmp_path / "m.json"
        man.write_text(
            json.dumps(
                {
                    "version": MANIFEST_VERSION,
                    "rules": {"sync": {"include": ["*.py"]}},
                }
            )
        )
        rc = cli_main([str(p), "--format", "json", "--manifest", str(man)])
        rep = json.loads(capsys.readouterr().out)
        assert rc == 1
        assert rep["version"] == JSON_VERSION
        assert [f["rule"] for f in rep["findings"]] == ["sync"]

    def test_cli_exit_codes(self, tmp_path, capsys):
        clean = tmp_path / "clean.py"
        clean.write_text("x = 1\n")
        assert cli_main([str(clean)]) == 0
        capsys.readouterr()
        assert cli_main([str(clean), "--rules", "nosuchrule"]) == 2


# --------------------------------------------------------------------------- #
# The committed tree lints clean (the CI lane's gate)
# --------------------------------------------------------------------------- #
class TestRealTree:
    def test_src_lints_clean_with_sanctioned_suppressions(self):
        rep, findings = run_paths([REPO_ROOT / "src"])
        assert rep["findings"] == [], [f.format_text() for f in findings if not f.suppressed]
        # the sanctioned readouts exist and are pragma'd, not silent
        assert rep["suppressed_count"] >= 5
        # the suite genuinely exercises >= 5 distinct rules
        assert len(rep["rules"]) >= 5

    def test_deleting_the_fused_readout_pragma_fails_the_lint(self, tmp_path):
        real = (REPO_ROOT / "src" / "repro" / "core" / "fused.py").read_text()
        assert "# tessalint: sync-ok(THE one sanctioned readout" in real
        stripped = []
        for line in real.splitlines(keepends=True):
            if "# tessalint: sync-ok(THE one sanctioned readout" in line:
                line = line.split("  # tessalint:")[0] + "\n"
            stripped.append(line)
        d = tmp_path / "src" / "repro" / "core"
        d.mkdir(parents=True)
        p = d / "fused.py"
        p.write_text("".join(stripped))
        findings = lint_file(p, Manifest.load(DEFAULT_MANIFEST_PATH))
        live = [f for f in findings if not f.suppressed and f.rule == "sync"]
        assert live, "the un-pragma'd device_get readout must be flagged"
        assert any("device_get" in f.message for f in live)

    def test_tools_package_lints_itself_quietly(self):
        # the linter's own tree has no device code; running it must not crash
        rep, _ = run_paths([REPO_ROOT / "tools"])
        assert rep["findings"] == []
