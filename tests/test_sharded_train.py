"""End-to-end SHARDED train step on a real (1x1) mesh, incl. shard_map MoE.

Exercises the exact code path the dry-run lowers — sharding rules active,
in_shardings from the spec tree, shard_map expert parallelism — but on the
single CPU device, executing for real and checking numerics.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.launch.mesh import make_smoke_mesh
from repro.launch.pspec import ShardingRules, use_rules
from repro.launch.specs import (
    batch_logical_axes,
    logical_axes_for,
    sharding_tree,
)
from repro.train.data import batch_for
from repro.train.step import TrainConfig, make_train_step, train_state_init


def _batch(cfg, b=2, s=32):
    raw = batch_for(
        cfg.vocab_size, b, s, seed=0,
        frontend=cfg.frontend, frontend_len=cfg.frontend_len, d_model=cfg.d_model,
    )
    return {k: jnp.asarray(v) for k, v in raw.items()}


@pytest.mark.parametrize("arch", ["llama3-8b", "dbrx-132b"])
def test_sharded_train_step_executes(arch):
    cfg = get_reduced(arch)
    tc = TrainConfig(microbatches=2)
    mesh = make_smoke_mesh()
    rules = ShardingRules(mesh)
    os.environ["REPRO_MOE_SHARDMAP"] = "1"
    try:
        with mesh, use_rules(rules):
            state = train_state_init(jax.random.PRNGKey(0), cfg, tc)
            state_sh = sharding_tree(state, rules, logical_axes_for)
            batch = _batch(cfg)
            batch_sh = {
                k: rules.sharding_for(v.shape, batch_logical_axes(k, v.ndim))
                for k, v in batch.items()
            }
            step = jax.jit(
                make_train_step(cfg, tc),
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
            )
            new_state, metrics = step(state, batch)
        assert np.isfinite(float(metrics["loss"]))
        assert float(metrics["grad_norm"]) > 0
    finally:
        os.environ.pop("REPRO_MOE_SHARDMAP", None)


def test_shardmap_moe_loss_matches_reference_path():
    """Same seed, same batch: shard_map-MoE train loss == pjit-MoE loss on
    one device (dispatch semantics identical at G=1)."""
    cfg = get_reduced("dbrx-132b")
    tc = TrainConfig()
    mesh = make_smoke_mesh()
    rules = ShardingRules(mesh)
    batch = _batch(cfg)

    losses = {}
    for flag in ("0", "1"):
        os.environ["REPRO_MOE_SHARDMAP"] = flag
        try:
            with mesh, use_rules(rules):
                state = train_state_init(jax.random.PRNGKey(0), cfg, tc)
                step = jax.jit(make_train_step(cfg, tc))
                _, metrics = step(state, batch)
            losses[flag] = float(metrics["loss"])
        finally:
            os.environ.pop("REPRO_MOE_SHARDMAP", None)
    assert losses["0"] == pytest.approx(losses["1"], rel=2e-2)
