"""Multi-device fused-decide parity suite.

The fused migration planner (:mod:`repro.core.fused`) compiles the whole
Algorithm-2 stage — occupancy diff, in-program cost assembly, the sharded
pair-LAP fan-out, the node match and the physical scatter — into one
jitted XLA program with a single readout per round.  This suite is its
churn-replay differential gate:

* **fused vs host, bit-identical**: the 60+ round churn replay of
  ``test_churn_replay`` driven with ``fused_fanout=True`` and a cold
  scipy shadow deciding from the SAME per-round inputs must produce
  bit-identical physical plans every round under ``tie_break`` (the
  perturbed optimum is unique, so every exact solver agrees), and
  exactly equal integer-quantised matching costs without it.
* **shard invariance**: conftest forces 8 host devices
  (``--xla_force_host_platform_device_count=8``); replays sharded over
  1 / 2 / 8 of them must be bit-identical to each other — sharding the
  fan-out batch is pure partitioning, never semantics.
* **hypothesis property**: for random plan pairs, ANY shard split of the
  pair axis preserves the full physical relabelling.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax

from repro.core.cluster import ClusterSpec
from repro.core.fused import FusedMigrationPlanner
from repro.core.migration import plan_migration
from repro.core.placement import place_without_packing
from repro.core.profiler import ThroughputProfile
from repro.core.simulator import SimConfig, Simulator
from repro.core.policies import TiresiasPolicy
from repro.core.traces import shockwave_trace, synthetic_active_jobs

from tests.test_churn_replay import MIN_ROUNDS, N_JOBS, ARRIVAL_RATE, SEED, RecordingScheduler

pytest.importorskip("scipy.optimize")

SHARD_COUNTS = (1, 2, 8)


def _run_fused(shards, tie_break, shadow=True):
    profile = ThroughputProfile()
    cluster = ClusterSpec(4, 4)
    shadow_sched = None
    if shadow:
        from repro.core.scheduler import TesseraeScheduler

        shadow_sched = TesseraeScheduler(
            cluster,
            TiresiasPolicy(profile, queue_base=900.0),
            profile,
            lap_backend="scipy",
            enable_packing=False,
            tie_break=tie_break,
        )
    sched = RecordingScheduler(
        cluster,
        TiresiasPolicy(profile, queue_base=900.0),
        profile,
        lap_backend="scipy",
        cold=False,
        shadow=shadow_sched,
        enable_packing=False,
        tie_break=tie_break,
        fused_fanout=True,
        fanout_shards=shards,
    )
    trace = shockwave_trace(
        num_jobs=N_JOBS, arrival_rate_per_hour=ARRIVAL_RATE, seed=SEED, profile=profile
    )
    sim = Simulator(
        cluster,
        trace,
        sched,
        profile,
        SimConfig(round_duration_s=360.0, resume_fraction=0.25),
    )
    return sim.run(), sched


class TestFusedChurnParity:
    """Fused planner vs the cold scipy shadow over the full churn replay."""

    @pytest.fixture(scope="class")
    def replays(self):
        # one replay per shard count, shadow only on the first (the others
        # are compared against it round-by-round)
        out = {}
        for s in SHARD_COUNTS:
            out[s] = _run_fused(s, tie_break=True, shadow=(s == SHARD_COUNTS[0]))
        return out

    def test_devices_actually_forced(self):
        assert len(jax.devices()) >= max(SHARD_COUNTS), (
            "conftest did not force 8 host devices — shard parity is vacuous"
        )

    def test_plans_bit_identical_to_host_all_rounds(self, replays):
        _, sched = replays[SHARD_COUNTS[0]]
        assert len(sched.round_log) >= MIN_ROUNDS
        for t, entry in enumerate(sched.round_log):
            assert entry["plan"] == entry["shadow"]["plan"], (
                f"round {t}: fused physical plan != cold scipy shadow"
            )

    def test_matching_costs_exact(self, replays):
        _, sched = replays[SHARD_COUNTS[0]]
        compared = 0
        for t, entry in enumerate(sched.round_log):
            if entry["mig_cost"] is None:
                continue
            compared += 1
            assert entry["mig_cost"] == pytest.approx(
                entry["shadow"]["mig_cost"], abs=1e-9
            ), f"round {t}"
        assert compared >= MIN_ROUNDS

    def test_shard_counts_bit_identical(self, replays):
        ref_res, ref_sched = replays[SHARD_COUNTS[0]]
        for s in SHARD_COUNTS[1:]:
            res, sched = replays[s]
            assert len(sched.round_log) == len(ref_sched.round_log)
            for t, (a, b) in enumerate(zip(sched.round_log, ref_sched.round_log)):
                assert a["plan"] == b["plan"], f"shards={s} round {t}: plans differ"
                assert a["mig_cost"] == b["mig_cost"], f"shards={s} round {t}"
            np.testing.assert_array_equal(
                [res.jobs[j].finish_time for j in sorted(res.jobs)],
                [ref_res.jobs[j].finish_time for j in sorted(ref_res.jobs)],
            )

    def test_fused_lane_actually_ran(self, replays):
        """The replay must have been served by the fused program, not the
        host fallback, with exactly ONE device readout per migration
        round — the tentpole's O(1)-readout contract."""
        _, sched = replays[SHARD_COUNTS[0]]
        rounds = [e["match_stats"] for e in sched.round_log]
        fused_rounds = sum(r.get("fused_rounds", 0) for r in rounds)
        fallbacks = sum(r.get("fused_host_fallbacks", 0) for r in rounds)
        readouts = sum(r.get("fused_readouts", 0) for r in rounds)
        mig_rounds = sum(1 for e in sched.round_log if e["mig_cost"] is not None)
        assert fused_rounds == mig_rounds, (fused_rounds, mig_rounds)
        assert fallbacks == 0
        assert readouts == mig_rounds

    def test_invalidation_is_partial(self, replays):
        """Occupancy diffing must keep some pairs clean on most rounds —
        a full-batch invalidation every round would make the device cache
        pointless."""
        _, sched = replays[SHARD_COUNTS[0]]
        partial = 0
        total = 0
        for e in sched.round_log:
            st_ = e["match_stats"]
            if not st_.get("fused_pair_instances"):
                continue
            total += 1
            if st_.get("fused_dirty_pairs", 0) < st_["fused_pair_instances"]:
                partial += 1
        assert total >= MIN_ROUNDS
        assert partial >= total // 2, (partial, total)


class TestFusedCostParityNoTieBreak:
    """Without tie-breaking, assignments may legitimately differ between
    solvers, but the integer-quantised matching cost must still be exact
    every round."""

    def test_costs_exact(self):
        _, sched = _run_fused(1, tie_break=False, shadow=True)
        compared = 0
        for t, entry in enumerate(sched.round_log):
            if entry["mig_cost"] is None:
                continue
            compared += 1
            assert entry["mig_cost"] == pytest.approx(
                entry["shadow"]["mig_cost"], abs=1e-9
            ), f"round {t}"
        assert compared >= MIN_ROUNDS


class TestShardSplitProperty:
    """Hypothesis: sharding the fan-out batch along ANY split of the pair
    axis preserves the physical relabelling bit-for-bit."""

    @given(
        seed=st.integers(0, 2**32 - 1),
        drop=st.integers(0, 3),
        shards=st.sampled_from(SHARD_COUNTS + (3, 5)),
    )
    @settings(max_examples=12, deadline=None)
    def test_any_split_preserves_plan(self, seed, drop, shards):
        profile = ThroughputProfile()
        cluster = ClusterSpec(4, 4)
        jobs = synthetic_active_jobs(12, seed=seed, profile=profile)
        jobs = [j for j in jobs if j.num_gpus <= 4 or j.num_gpus % 4 == 0]
        prev, _, _ = place_without_packing(cluster, jobs)
        new, _, _ = place_without_packing(cluster, jobs[drop:] or jobs)
        g = {j.job_id: j.num_gpus for j in jobs}

        base = FusedMigrationPlanner(shards=1).plan(prev, new, g, tie_break=True)
        split = FusedMigrationPlanner(shards=shards).plan(prev, new, g, tie_break=True)
        host = plan_migration(
            prev, new, g, algorithm="node", backend="scipy", tie_break=True
        )
        np.testing.assert_array_equal(
            base.physical_plan.slots, split.physical_plan.slots
        )
        np.testing.assert_array_equal(
            base.physical_plan.slots, host.physical_plan.slots
        )
        assert base.matching_cost == pytest.approx(host.matching_cost, abs=1e-9)


class TestFusedHealthTermParity:
    """Straggler-drain penalties folded into the in-program cost assembly
    must stay bit-identical to the host planner: both sides share the
    same host-computed pen matrix and the mantissa budget accounts for
    its magnitude, so parity holds by construction — this pins it."""

    @given(seed=st.integers(0, 2**32 - 1), drop=st.integers(0, 3))
    @settings(max_examples=10, deadline=None)
    def test_speed_terms_preserve_host_parity(self, seed, drop):
        profile = ThroughputProfile()
        cluster = ClusterSpec(4, 4)
        jobs = synthetic_active_jobs(12, seed=seed, profile=profile)
        jobs = [j for j in jobs if j.num_gpus <= 4 or j.num_gpus % 4 == 0]
        prev, _, _ = place_without_packing(cluster, jobs)
        new, _, _ = place_without_packing(cluster, jobs[drop:] or jobs)
        g = {j.job_id: j.num_gpus for j in jobs}
        rng = np.random.default_rng(seed)
        speed = np.where(rng.random(4) < 0.5,
                         rng.uniform(0.2, 0.9, 4), 1.0)

        fused = FusedMigrationPlanner().plan(
            prev, new, g, tie_break=True, speed_factor=speed
        )
        host = plan_migration(
            prev, new, g, algorithm="node", backend="scipy",
            tie_break=True, speed_factor=speed,
        )
        np.testing.assert_array_equal(
            fused.physical_plan.slots, host.physical_plan.slots
        )
        assert fused.matching_cost == pytest.approx(
            host.matching_cost, abs=1e-9
        )
