"""Pallas kernels vs pure-jnp oracles (interpret mode, CPU).

Per the kernel contract: sweep shapes & dtypes, assert allclose vs ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.lap_bid import lap_bid_pallas, lap_bid_pallas_batched
from repro.kernels.migration_cost import migration_cost_pallas


class TestLapBidKernel:
    @pytest.mark.parametrize("n,m", [(4, 4), (7, 13), (64, 64), (130, 300), (5, 520), (257, 1100)])
    @pytest.mark.parametrize("dtype", [jnp.float32])
    def test_matches_ref(self, n, m, dtype):
        rng = np.random.default_rng(n * 1000 + m)
        a = jnp.asarray(rng.normal(size=(n, m)), dtype)
        p = jnp.asarray(rng.normal(size=(m,)), dtype)
        bv, bj, sv = lap_bid_pallas(a, p, interpret=True)
        rv, rj, rsv = ref.lap_bid_top2(a - p[None, :])
        np.testing.assert_allclose(bv, rv, rtol=1e-6)
        np.testing.assert_array_equal(bj, rj)
        np.testing.assert_allclose(sv, rsv, rtol=1e-6)

    def test_ties_and_duplicates(self):
        # duplicate best values -> second == best; argmax = first occurrence
        a = jnp.asarray([[1.0, 5.0, 5.0, 0.0], [2.0, 2.0, 2.0, 2.0]])
        p = jnp.zeros((4,))
        bv, bj, sv = lap_bid_pallas(a, p, interpret=True)
        rv, rj, rsv = ref.lap_bid_top2(a)
        np.testing.assert_allclose(bv, rv)
        np.testing.assert_array_equal(bj, rj)
        np.testing.assert_allclose(sv, rsv)

    def test_cross_tile_ties(self):
        # identical maxima in different column tiles: first tile must win
        m = 1100  # spans 3 column tiles at BLOCK_COLS=512
        a = np.zeros((3, m), np.float32)
        a[0, 10] = 7.0
        a[0, 700] = 7.0  # tie across tiles
        a[1, 600] = 3.0
        a[2, 1050] = 9.0
        bv, bj, sv = lap_bid_pallas(jnp.asarray(a), jnp.zeros((m,)), interpret=True)
        rv, rj, rsv = ref.lap_bid_top2(jnp.asarray(a))
        np.testing.assert_array_equal(bj, rj)
        np.testing.assert_allclose(sv, rsv)


class TestLapBidKernelBatched:
    """Batched kernel vs the auction's jnp top-2 oracle on shapes that
    exercise the padding edges: 1 short of a block (127 / 511), block+1
    (129 / 513), and non-multiples of the 128-row / 512-col tiles."""

    @pytest.mark.parametrize(
        "b,n,m",
        [
            (1, 4, 4),
            (3, 127, 512),   # rows one short of BLOCK_ROWS
            (2, 129, 64),    # rows = BLOCK_ROWS + 1
            (2, 128, 511),   # cols one short of BLOCK_COLS
            (2, 3, 513),     # cols = BLOCK_COLS + 1
            (4, 130, 300),   # both non-multiples
            (2, 127, 513),   # short rows x long cols
        ],
    )
    def test_matches_auction_top2(self, b, n, m):
        from repro.core.matching.auction import _top2

        rng = np.random.default_rng(b * 100000 + n * 100 + m)
        a = jnp.asarray(rng.normal(size=(b, n, m)), jnp.float32)
        p = jnp.asarray(rng.normal(size=(b, m)), jnp.float32)
        bv, bj, sv = lap_bid_pallas_batched(a, p, interpret=True)
        rv, rj, rsv = _top2(a - p[:, None, :])
        np.testing.assert_allclose(bv, rv, rtol=1e-6)
        np.testing.assert_array_equal(bj, rj)
        np.testing.assert_allclose(sv, rsv, rtol=1e-6)

    def test_matches_unbatched_kernel(self):
        rng = np.random.default_rng(7)
        a = jnp.asarray(rng.normal(size=(3, 130, 520)), jnp.float32)
        p = jnp.asarray(rng.normal(size=(3, 520)), jnp.float32)
        bv, bj, sv = lap_bid_pallas_batched(a, p, interpret=True)
        for i in range(3):
            bv1, bj1, sv1 = lap_bid_pallas(a[i], p[i], interpret=True)
            np.testing.assert_allclose(bv[i], bv1, rtol=1e-6)
            np.testing.assert_array_equal(bj[i], bj1)
            np.testing.assert_allclose(sv[i], sv1, rtol=1e-6)

    def test_cross_tile_ties_batched(self):
        # identical maxima in different column tiles: first tile must win,
        # independently per batch instance
        m = 1100  # spans 3 column tiles at BLOCK_COLS=512
        a = np.zeros((2, 2, m), np.float32)
        a[0, 0, 10] = 7.0
        a[0, 0, 700] = 7.0   # tie across tiles -> argmax must stay at 10
        a[1, 0, 700] = 7.0   # same value, later tile only, in instance 1
        a[1, 1, 1050] = 9.0
        bv, bj, sv = lap_bid_pallas_batched(
            jnp.asarray(a), jnp.zeros((2, m)), interpret=True
        )
        assert int(bj[0, 0]) == 10
        assert int(bj[1, 0]) == 700
        np.testing.assert_allclose(sv[0, 0], 7.0)

    def test_ops_dispatch_batched(self):
        """ops.lap_bid_top2 routes 3-D inputs to the batched kernel."""
        from repro.core.matching.auction import _top2
        from repro.kernels.ops import lap_bid_top2

        rng = np.random.default_rng(11)
        vals = jnp.asarray(rng.normal(size=(5, 9, 17)), jnp.float32)
        bv, bj, sv = lap_bid_top2(vals)
        rv, rj, rsv = _top2(vals)
        np.testing.assert_allclose(bv, rv, rtol=1e-6)
        np.testing.assert_array_equal(bj, rj)
        np.testing.assert_allclose(sv, rsv, rtol=1e-6)


class TestLapBidFusedKernel:
    """In-kernel benefit assembly (``-cost`` + positional tie-break ramp)
    vs the ``ref.lap_bid_fused_top2`` oracle, plus the exactness contract:
    integer costs + power-of-two scales give BIT-identical values to the
    host f64-assemble-then-cast path."""

    @staticmethod
    def _tb_scale(n, m):
        bound = 2.0 * min(n, m) * float(n) * float(n) * float(m)
        return 2.0 ** np.floor(np.log2(1.0 / bound))

    @pytest.mark.parametrize("n,m", [(4, 4), (8, 8), (7, 13), (64, 64), (130, 300)])
    def test_matches_ref(self, n, m):
        from repro.kernels.lap_bid import lap_bid_fused_pallas

        rng = np.random.default_rng(n * 991 + m)
        cost = jnp.asarray(rng.integers(0, 64, size=(n, m)), jnp.float32)
        p = jnp.asarray(rng.normal(size=(m,)), jnp.float32)
        tb = self._tb_scale(n, m)
        bv, bj, sv = lap_bid_fused_pallas(cost, p, tb, interpret=True)
        rv, rj, rsv = ref.lap_bid_fused_top2(cost, p, tb)
        np.testing.assert_array_equal(bv, rv)
        np.testing.assert_array_equal(bj, rj)
        np.testing.assert_array_equal(sv, rsv)

    def test_zero_scale_matches_plain_bid(self):
        from repro.kernels.lap_bid import lap_bid_fused_pallas

        rng = np.random.default_rng(3)
        cost = jnp.asarray(rng.normal(size=(9, 17)), jnp.float32)
        p = jnp.asarray(rng.normal(size=(17,)), jnp.float32)
        fv, fj, fs = lap_bid_fused_pallas(cost, p, 0.0, interpret=True)
        bv, bj, sv = lap_bid_pallas(-cost, p, interpret=True)
        np.testing.assert_array_equal(fv, bv)
        np.testing.assert_array_equal(fj, bj)
        np.testing.assert_array_equal(fs, sv)

    def test_bit_identical_to_host_assembly(self):
        """Integer cost + power-of-two ramp: the in-kernel f32 assembly is
        bit-equal to assembling the perturbed benefit in f64 on the host
        and casting — the property the fused planner's bit-parity with the
        host engine rests on (holds while n^2 * m < 2^24)."""
        from repro.kernels.lap_bid import lap_bid_fused_pallas

        n, m = 8, 8
        rng = np.random.default_rng(17)
        cost64 = rng.integers(0, 1 << 10, size=(n, m)).astype(np.float64)
        tb = self._tb_scale(n, m)
        gi = (np.arange(n, dtype=np.float64) + 1.0)[:, None]
        gj = (np.arange(m, dtype=np.float64) + 1.0)[None, :]
        host = (-cost64 + tb * gi * gi * gj).astype(np.float32)  # f64 then cast
        p = jnp.zeros((m,), jnp.float32)
        fv, fj, fs = lap_bid_fused_pallas(jnp.asarray(cost64, jnp.float32), p, tb, interpret=True)
        hv, hj, hs = ref.lap_bid_top2(jnp.asarray(host))
        np.testing.assert_array_equal(fv, hv)
        np.testing.assert_array_equal(fj, hj)
        np.testing.assert_array_equal(fs, hs)

    @pytest.mark.parametrize("b,n,m", [(1, 4, 4), (16, 8, 8), (3, 130, 300)])
    def test_batched_matches_unbatched(self, b, n, m):
        from repro.kernels.lap_bid import (
            lap_bid_fused_pallas,
            lap_bid_fused_pallas_batched,
        )

        rng = np.random.default_rng(b * 7919 + n * 31 + m)
        cost = jnp.asarray(rng.integers(0, 64, size=(b, n, m)), jnp.float32)
        p = jnp.asarray(rng.normal(size=(b, m)), jnp.float32)
        tb = np.full((b,), self._tb_scale(n, m), np.float32)
        tb[0] = 0.0  # per-instance scales: instance 0 un-perturbed
        bv, bj, sv = lap_bid_fused_pallas_batched(cost, p, jnp.asarray(tb), interpret=True)
        for i in range(b):
            v1, j1, s1 = lap_bid_fused_pallas(cost[i], p[i], float(tb[i]), interpret=True)
            np.testing.assert_array_equal(bv[i], v1)
            np.testing.assert_array_equal(bj[i], j1)
            np.testing.assert_array_equal(sv[i], s1)

    def test_ops_dispatch(self):
        from repro.kernels.ops import lap_bid_fused

        rng = np.random.default_rng(23)
        cost = jnp.asarray(rng.integers(0, 64, size=(2, 8, 8)), jnp.float32)
        p = jnp.zeros((2, 8), jnp.float32)
        tb = self._tb_scale(8, 8)
        bv, bj, sv = lap_bid_fused(cost, p, tb)
        rv, rj, rsv = ref.lap_bid_fused_top2(cost[0], p[0], tb)
        np.testing.assert_array_equal(bj[0], rj)


class TestMigrationCostKernel:
    @pytest.mark.parametrize("u,v", [(4, 4), (8, 8), (130, 70), (256, 256)])
    def test_matches_ref(self, u, v):
        rng = np.random.default_rng(u * 7 + v)
        # random job ids incl. empties
        slots_u = rng.integers(-1, 20, size=(u, 2)).astype(np.int32)
        slots_v = rng.integers(-1, 20, size=(v, 2)).astype(np.int32)
        lookup = rng.uniform(0.1, 0.5, size=21).astype(np.float32)
        w_u = np.where(slots_u >= 0, lookup[np.maximum(slots_u, 0)], 0.0).astype(np.float32)
        w_v = np.where(slots_v >= 0, lookup[np.maximum(slots_v, 0)], 0.0).astype(np.float32)
        got = migration_cost_pallas(
            jnp.asarray(slots_u), jnp.asarray(slots_v),
            jnp.asarray(w_u), jnp.asarray(w_v), interpret=True,
        )
        want = ref.migration_cost(
            jnp.asarray(slots_u), jnp.asarray(slots_v),
            jnp.asarray(w_u), jnp.asarray(w_v),
        )
        np.testing.assert_allclose(got, want, rtol=1e-6)

    def test_agrees_with_numpy_path(self):
        """Kernel vs the numpy implementation used by plan_migration."""
        from repro.core.migration import _weight_lookup, pairwise_migration_cost
        from repro.kernels.ops import migration_cost_matrix

        rng = np.random.default_rng(0)
        slots_u = rng.integers(-1, 10, size=(16, 2))
        slots_v = rng.integers(-1, 10, size=(16, 2))
        num_gpus_of = {j: int(g) for j, g in enumerate(rng.choice([1, 2, 4, 8], 10))}
        want = pairwise_migration_cost(slots_u, slots_v, _weight_lookup(num_gpus_of))
        got = migration_cost_matrix(slots_u, slots_v, num_gpus_of)
        np.testing.assert_allclose(got, want, rtol=1e-6)


class TestFlashAttentionKernel:
    @pytest.mark.parametrize("bh,s,d", [(2, 128, 64), (1, 256, 128), (3, 384, 64), (2, 1024, 128)])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_ref_f32(self, bh, s, d, causal):
        rng = np.random.default_rng(s + d)
        q = jnp.asarray(rng.normal(size=(bh, s, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(bh, s, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(bh, s, d)), jnp.float32)
        got = flash_attention_pallas(q, k, v, causal=causal, interpret=True)
        want = ref.flash_attention(q, k, v, causal=causal)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("s", [128, 512])
    def test_bf16(self, s):
        rng = np.random.default_rng(s)
        q = jnp.asarray(rng.normal(size=(2, s, 64)), jnp.bfloat16)
        k = jnp.asarray(rng.normal(size=(2, s, 64)), jnp.bfloat16)
        v = jnp.asarray(rng.normal(size=(2, s, 64)), jnp.bfloat16)
        got = flash_attention_pallas(q, k, v, causal=True, interpret=True)
        want = ref.flash_attention(q, k, v, causal=True)
        np.testing.assert_allclose(
            got.astype(jnp.float32), want.astype(jnp.float32), rtol=3e-2, atol=3e-2
        )

    def test_unaligned_seq(self):
        """Sequence not a multiple of the block size (padding path)."""
        rng = np.random.default_rng(9)
        q = jnp.asarray(rng.normal(size=(1, 200, 64)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, 200, 64)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, 200, 64)), jnp.float32)
        got = flash_attention_pallas(q, k, v, causal=True, interpret=True)
        want = ref.flash_attention(q, k, v, causal=True)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


class TestAuctionWithKernel:
    def test_auction_kernel_path(self):
        from repro.core.matching.auction import auction_lap
        from repro.core.matching.hungarian import assignment_cost
        from scipy.optimize import linear_sum_assignment as scipy_lsa

        rng = np.random.default_rng(0)
        benefit = rng.integers(0, 20, size=(8, 8)).astype(np.float32)
        res = auction_lap(jnp.asarray(benefit), use_kernel=True)
        col = np.asarray(res.col_of)
        got = benefit[np.arange(8), col].sum()
        r, c = scipy_lsa(benefit, maximize=True)
        assert np.isclose(got, benefit[r, c].sum())


class TestShapeContracts:
    """The ops-layer entry points validate shape/dtype at trace time and
    raise ValueError with the offending shapes in the message."""

    def test_lap_bid_prices_mismatch(self):
        from repro.kernels import ops

        a = jnp.zeros((4, 6), jnp.float32)
        with pytest.raises(ValueError, match="prices shape"):
            ops.lap_bid(a, jnp.zeros((5,), jnp.float32))

    def test_lap_bid_batched_prices_mismatch(self):
        from repro.kernels import ops

        a = jnp.zeros((2, 4, 6), jnp.float32)
        # batched prices must be (B, m), not (m,)
        with pytest.raises(ValueError, match="prices shape"):
            ops.lap_bid(a, jnp.zeros((6,), jnp.float32))

    def test_lap_bid_rejects_integer_matrix(self):
        from repro.kernels import ops

        a = jnp.zeros((4, 6), jnp.int32)
        with pytest.raises(ValueError, match="floating"):
            ops.lap_bid(a, jnp.zeros((6,), jnp.float32))

    def test_lap_bid_rejects_1d(self):
        from repro.kernels import ops

        with pytest.raises(ValueError, match=r"\(n, m\) or \(B, n, m\)"):
            ops.lap_bid(jnp.zeros((6,), jnp.float32), jnp.zeros((6,), jnp.float32))

    def test_lap_bid_fused_shares_contract(self):
        from repro.kernels import ops

        c = jnp.zeros((2, 4, 6), jnp.float32)
        with pytest.raises(ValueError, match="lap_bid_fused"):
            ops.lap_bid_fused(c, jnp.zeros((2, 5), jnp.float32))

    def test_lap_bid_top2_rejects_4d(self):
        from repro.kernels import ops

        with pytest.raises(ValueError, match="lap_bid_top2"):
            ops.lap_bid_top2(jnp.zeros((2, 2, 4, 6), jnp.float32))

    def test_valid_calls_pass(self):
        from repro.kernels import ops

        rng = np.random.default_rng(7)
        a = jnp.asarray(rng.normal(size=(5, 8)), jnp.float32)
        p = jnp.asarray(rng.normal(size=(8,)), jnp.float32)
        bv, bj, sv = ops.lap_bid(a, p)
        rv, rj, rsv = ref.lap_bid_top2(a - p[None, :])
        np.testing.assert_array_equal(np.asarray(bj), np.asarray(rj))

    def test_migration_cost_rejects_float_slots(self):
        from repro.kernels import ops

        with pytest.raises(ValueError, match="integer job ids"):
            ops.migration_cost_matrix(
                np.zeros((3, 4), np.float32), np.zeros((3, 4), np.int32), {0: 1}
            )

    def test_migration_cost_rejects_pack_mismatch(self):
        from repro.kernels import ops

        with pytest.raises(ValueError, match="MAX_PACK"):
            ops.migration_cost_matrix(
                np.zeros((3, 4), np.int32), np.zeros((3, 5), np.int32), {0: 1}
            )

    def test_flash_decode_head_group_contract(self):
        from repro.kernels import ops

        q = jnp.zeros((2, 3, 8), jnp.float32)  # H=3 not a multiple of KV=2
        kv = jnp.zeros((2, 16, 2, 8), jnp.float32)
        with pytest.raises(ValueError, match="multiple of KV"):
            ops.flash_decode(q, kv, kv, jnp.array([4, 4]))

    def test_flash_attention_shape_mismatch(self):
        from repro.kernels import ops

        q = jnp.zeros((2, 8, 4), jnp.float32)
        k = jnp.zeros((2, 9, 4), jnp.float32)
        with pytest.raises(ValueError, match="q/k/v shapes differ"):
            ops.flash_attention(q, k, q)

    def test_tile_mask_iota_floor(self):
        from repro.kernels.tile_mask import mask_ragged_cols, tile_col_ids

        with pytest.raises(ValueError, match="2-D"):
            tile_col_ids((8,), 0)
        with pytest.raises(ValueError, match="2-D"):
            mask_ragged_cols(jnp.zeros((8,)), 0, 4, 0.0)

    def test_tile_mask_valid(self):
        from repro.kernels.tile_mask import mask_ragged_cols

        x = jnp.ones((2, 4))
        out = np.asarray(mask_ragged_cols(x, 2, 4, -9.0))
        # global cols are [2, 3, 4, 5]; cols >= 4 get the fill value
        np.testing.assert_array_equal(out, [[1, 1, -9, -9], [1, 1, -9, -9]])
