"""Gavel / POP LP baseline sanity tests."""

import numpy as np
import pytest

from repro.core.cluster import ClusterSpec
from repro.core.policies.gavel import GavelPolicy, PopPolicy, solve_gavel_lp
from repro.core.profiler import ThroughputProfile
from repro.core.scheduler import TesseraeScheduler
from repro.core.simulator import SimConfig, Simulator
from repro.core.traces import shockwave_trace, synthetic_active_jobs


@pytest.fixture(scope="module")
def profile():
    return ThroughputProfile()


class TestGavelLp:
    def test_lp_respects_capacity(self, profile):
        cluster = ClusterSpec(4, 4)
        jobs = synthetic_active_jobs(20, seed=0, profile=profile)
        sol = solve_gavel_lp(jobs, profile, cluster)
        # per-job fractions within [0,1]
        used = 0.0
        for j in jobs:
            frac = sol.solo[j.job_id] + sum(
                f for (a, b), f in sol.pairs.items() if j.job_id in (a, b)
            )
            assert frac <= 1.0 + 1e-6
            used += sol.solo[j.job_id] * j.num_gpus
        for (a, b), f in sol.pairs.items():
            ga = next(j.num_gpus for j in jobs if j.job_id == a)
            used += f * ga
        assert used <= cluster.num_gpus + 1e-4

    def test_variable_count_grows_quadratically(self, profile):
        cluster = ClusterSpec(4, 4)
        j10 = synthetic_active_jobs(10, seed=1, profile=profile)
        j40 = synthetic_active_jobs(40, seed=1, profile=profile)
        s10 = solve_gavel_lp(j10, profile, cluster)
        s40 = solve_gavel_lp(j40, profile, cluster)
        assert s40.num_variables > 6 * s10.num_variables  # ~quadratic

    def test_pop_faster_than_gavel_large(self, profile):
        cluster = ClusterSpec(16, 4)
        jobs = synthetic_active_jobs(300, seed=2, profile=profile)
        g = GavelPolicy(profile)
        p = PopPolicy(profile, partition_size=64)
        tg = g.refresh(jobs, cluster)
        tp = p.refresh(jobs, cluster)
        assert tp < tg

    def test_gavel_end_to_end_sim(self, profile):
        cluster = ClusterSpec(2, 4)
        trace = shockwave_trace(num_jobs=12, seed=3, profile=profile)
        pol = GavelPolicy(profile)
        sched = TesseraeScheduler(
            cluster, pol, profile, migration_algorithm="none"
        )
        res = Simulator(cluster, trace, sched, profile, SimConfig()).run()
        assert all(s.finished for s in res.jobs.values())
        assert res.lp_refresh_s > 0
