"""Packing (Algorithm 4) tests: constraints, optimality, strategy lift."""

import itertools

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.jobs import JobSpec, JobState
from repro.core.packing import build_packing_graph, pack_jobs
from repro.core.profiler import ThroughputProfile

MODELS = ["resnet50", "vgg19", "dcgan", "pointnet", "gpt3-medium", "gpt3-xl"]


def _job(jid, model="resnet50", gpus=1, packable=True):
    spec = JobSpec(
        job_id=jid,
        model=model,
        num_gpus=gpus,
        total_iters=1000,
        arrival_time=0.0,
        packable=packable,
        is_llm=model.startswith("gpt3"),
    )
    return JobState(spec=spec)


@pytest.fixture
def profile():
    return ThroughputProfile()


class TestPackingConstraints:
    def test_gpu_count_must_match(self, profile):
        placed = [_job(0, gpus=2)]
        pending = [_job(1, gpus=1)]
        res = pack_jobs(placed, pending, profile)
        assert res.matches == {}

    def test_non_packable_bypassed(self, profile):
        placed = [_job(0, packable=False)]
        pending = [_job(1)]
        res = pack_jobs(placed, pending, profile)
        assert res.matches == {}

    def test_simple_match(self, profile):
        placed = [_job(0, "resnet50")]
        pending = [_job(1, "pointnet")]
        res = pack_jobs(placed, pending, profile)
        assert res.matches == {1: 0}
        assert res.total_weight > 1.0  # compute+memory-bound pair packs well

    def test_oom_pair_gets_no_edge(self):
        # v100 (16 GB): two 15 GB vgg19 cannot pack
        profile = ThroughputProfile(gpu_type="v100")
        placed = [_job(0, "vgg19")]
        pending = [_job(1, "vgg19")]
        res = pack_jobs(placed, pending, profile)
        assert res.matches == {}

    def test_at_most_one_partner(self, profile):
        placed = [_job(0, "resnet50")]
        pending = [_job(1, "pointnet"), _job(2, "dcgan")]
        res = pack_jobs(placed, pending, profile)
        assert len(res.matches) == 1


class TestPackingOptimality:
    @given(st.integers(0, 2**32 - 1), st.integers(1, 4), st.integers(1, 4))
    @settings(max_examples=40, deadline=None)
    def test_matches_bruteforce(self, seed, n_placed, n_pending):
        rng = np.random.default_rng(seed)
        profile = ThroughputProfile()
        placed = [
            _job(i, MODELS[rng.integers(len(MODELS))], gpus=int(rng.choice([1, 2])))
            for i in range(n_placed)
        ]
        pending = [
            _job(
                100 + i,
                MODELS[rng.integers(len(MODELS))],
                gpus=int(rng.choice([1, 2])),
            )
            for i in range(n_pending)
        ]
        w = build_packing_graph(placed, pending, profile)
        res = pack_jobs(placed, pending, profile)
        # brute force maximum-weight matching
        best = 0.0
        cols = list(range(n_pending))
        for k in range(min(n_placed, n_pending) + 1):
            for rows in itertools.permutations(range(n_placed), k):
                for cc in itertools.permutations(cols, k):
                    tot = sum(w[r, c] for r, c in zip(rows, cc))
                    best = max(best, tot)
        assert res.total_weight == pytest.approx(best, abs=1e-9)

    def test_strategy_optimisation_lifts_weight(self, profile):
        placed = [_job(0, "gpt3-3b", gpus=2)]
        pending = [_job(1, "resnet50", gpus=2)]
        res_plain = pack_jobs(placed, pending, profile, optimize_strategy=False)
        res_opt = pack_jobs(placed, pending, profile, optimize_strategy=True)
        assert res_opt.total_weight >= res_plain.total_weight


class TestPackingIdentityWarmStarts:
    """pack_jobs threads JOB identities into the matching context: a
    pending job arriving (the dominant churn event) must keep the
    surviving jobs' state warm instead of cold-starting the graph."""

    def test_unchanged_graph_memo_hits(self, profile):
        from repro.core.matching import MatchContext

        placed = [_job(i, MODELS[i % 3]) for i in range(6)]
        pending = [_job(10 + i, MODELS[i % 2]) for i in range(3)]
        ctx = MatchContext()
        r1 = pack_jobs(placed, pending, profile, backend="auction", context=ctx)
        r2 = pack_jobs(placed, pending, profile, backend="auction", context=ctx)
        assert ctx.stats["memo_hits"] == 1
        assert r1.matches == r2.matches

    def test_pending_arrival_stays_warm_and_matches_cold(self, profile):
        from repro.core.matching import MatchContext

        placed = [_job(i, MODELS[i % 4]) for i in range(8)]
        pending = [_job(20 + i, MODELS[i % 3]) for i in range(3)]
        ctx = MatchContext()
        pack_jobs(placed, pending, profile, backend="auction", context=ctx)
        pending2 = pending + [_job(30, MODELS[1])]
        warm = pack_jobs(placed, pending2, profile, backend="auction", context=ctx)
        cold = pack_jobs(placed, pending2, profile, backend="auction")
        # identity keying: the grown graph is not a cold start ...
        assert ctx.stats["warm_instances"] >= 1
        # ... and the warm result stays a valid Algorithm-4 matching with
        # the same total weight as a cold solve (assignment ids may differ
        # on equal-weight ties)
        assert warm.total_weight == pytest.approx(cold.total_weight, abs=1.0 + 1e-6)

    def test_job_departure_preserves_scipy_exactness(self, profile):
        from repro.core.matching import MatchContext

        placed = [_job(i, MODELS[i % 4]) for i in range(8)]
        pending = [_job(20 + i, MODELS[i % 3]) for i in range(4)]
        ctx = MatchContext()
        pack_jobs(placed, pending, profile, backend="scipy", context=ctx)
        # a placed job finishes, a pending job gets placed elsewhere
        placed2, pending2 = placed[1:], pending[:-1]
        warm = pack_jobs(placed2, pending2, profile, backend="scipy", context=ctx)
        cold = pack_jobs(placed2, pending2, profile, backend="scipy")
        assert warm.total_weight == pytest.approx(cold.total_weight, abs=1e-9)
