"""Observability-layer suite: inertness, deterministic tracing, metric
views, exports, crash-resume reseeding, and lint scoping.

The contract under test (``src/repro/obs``):

* **inert when disabled** — ``obs=None`` replays are bit-identical to
  each other and to the uninstrumented seed path (every call site routes
  through the ``NULL_TRACER`` no-op singleton);
* **inert when enabled** — tracing adds host-side bookkeeping only: an
  obs-enabled replay makes the SAME decisions as a plain one, for both
  the host and the fused migrate arms;
* **deterministic** — the timing-free span-tree fingerprint and the
  ``deterministic_snapshot()`` of the metrics registry are identical
  across two seeded runs (wall-clock histograms are excluded by design);
* **exact** — histogram percentiles are nearest-rank, not interpolated;
* **exportable** — the Chrome-trace/Perfetto document and the versioned
  ``tesserae-obs-v1`` document both pass their validators;
* **consolidated** — ``SimResult``'s telemetry views (``degrade_counts``,
  ``warm_hit_rounds``, ``total_bid_iters``, ``fused_host_fallbacks``)
  are registry reads that equal the legacy per-round aggregations they
  replaced, and crash-resume reseeds the registry to exactly the
  uninterrupted run's content;
* **lint-scoped** — the tessalint ``sync`` / ``det`` passes cover
  ``src/repro/obs`` (a stray device readout or wall clock there fails
  the lint; ``time.perf_counter`` stays sanctioned).
"""

import json
import textwrap
from collections import Counter
from pathlib import Path

import numpy as np
import pytest

from repro.core.cluster import ClusterSpec
from repro.core.policies import TiresiasPolicy
from repro.core.profiler import ThroughputProfile
from repro.core.scheduler import DegradeReason, TesseraeScheduler
from repro.core.simulator import SimConfig, Simulator
from repro.core.traces import shockwave_trace
from repro.obs import (
    NULL_TRACER,
    OBS_SCHEMA_VERSION,
    Histogram,
    MetricsRegistry,
    Observability,
    Tracer,
    to_chrome_trace,
    to_obs_doc,
    tracer_of,
    validate_chrome_trace,
    validate_obs_doc,
    write_chrome_trace,
)

#: replay shape: 12 jobs at ~220/h on 16 GPUs run 50+ contended rounds
#: with warm hits in nearly every one (the same regime perf_summary's
#: fresh gate replays).
N_JOBS = 12
SEED = 5
MIN_ROUNDS = 20


@pytest.fixture(scope="module")
def profile():
    return ThroughputProfile()


def _mk_sched(cluster, profile, fused=False):
    return TesseraeScheduler(
        cluster,
        TiresiasPolicy(profile),
        profile,
        lap_backend="auction",
        tie_break=fused,
        fused_fanout=fused,
    )


def _run(profile, obs=None, fused=False, cfg=None, sched=None):
    cluster = ClusterSpec(4, 4)
    trace = shockwave_trace(
        num_jobs=N_JOBS, arrival_rate_per_hour=220.0, seed=SEED, profile=profile
    )
    sched = sched or _mk_sched(cluster, profile, fused=fused)
    return Simulator(cluster, trace, sched, profile, cfg, obs=obs).run()


def _fingerprint(res):
    """The decision-relevant outcome of a run (no wall times)."""
    return {
        "jobs": {
            jid: (s.finish_time, s.iters_done, s.migrations)
            for jid, s in res.jobs.items()
        },
        "makespan": res.makespan_s,
        "migrations": res.total_migrations,
        "rounds": res.num_rounds,
        "degrade": tuple(res.degrade_rounds),
        "match_rounds": res.match_rounds,
    }


# --------------------------------------------------------------------------- #
# Inertness
# --------------------------------------------------------------------------- #
class TestInert:
    def test_disabled_obs_replay_is_bit_identical(self, profile):
        a = _run(profile)
        b = _run(profile)
        assert a.num_rounds >= MIN_ROUNDS
        assert _fingerprint(a) == _fingerprint(b)

    @pytest.mark.parametrize("fused", [False, True], ids=["host", "fused"])
    def test_enabled_obs_is_decision_invariant(self, profile, fused):
        plain = _run(profile, fused=fused)
        obs = Observability()
        traced = _run(profile, obs=obs, fused=fused)
        assert _fingerprint(plain) == _fingerprint(traced)
        # ...and the run was actually traced, not silently skipped
        assert obs.tracer.roots()

    def test_tracer_of_none_is_the_null_singleton(self):
        assert tracer_of(None) is NULL_TRACER
        # the no-op protocol: span() nests, annotates, and records nothing
        with NULL_TRACER.span("decide", jobs=3) as sp:
            sp.annotate(placed=1)
            with NULL_TRACER.span("inner"):
                pass
        assert NULL_TRACER.roots() == []


# --------------------------------------------------------------------------- #
# Tracer determinism + span catalog
# --------------------------------------------------------------------------- #
class TestTracer:
    def _span_names(self, tracer):
        names = set()

        def walk(node):
            names.add(node["name"])
            for c in node.get("children", ()):
                walk(c)

        for root in tracer.structure():
            walk(root)
        return names

    def test_fingerprint_identical_across_two_seeded_runs(self, profile):
        obs1, obs2 = Observability(), Observability()
        _run(profile, obs=obs1, fused=True)
        _run(profile, obs=obs2, fused=True)
        fp1, fp2 = obs1.tracer.fingerprint(), obs2.tracer.fingerprint()
        assert fp1 == fp2
        assert len(fp1) == 64 and int(fp1, 16) >= 0  # sha256 hex

    def test_host_arm_span_catalog(self, profile):
        obs = Observability()
        _run(profile, obs=obs)
        names = self._span_names(obs.tracer)
        assert {
            "round",
            "decide",
            "policy_sort",
            "place",
            "pack",
            "lap.solve",
            "migrate.host",
            "advance_round",
        } <= names
        assert "migrate.fused" not in names

    def test_fused_arm_span_catalog(self, profile):
        obs = Observability()
        res = _run(profile, obs=obs, fused=True)
        names = self._span_names(obs.tracer)
        assert {
            "migrate.fused",
            "migrate.fused.program",
            "migrate.fused.readout",
        } <= names
        # one sanctioned readout per fused round, zero host fallbacks

        def count(node, name):
            return (node["name"] == name) + sum(
                count(c, name) for c in node.get("children", ())
            )

        structure = obs.tracer.structure()
        readouts = sum(count(r, "migrate.fused.readout") for r in structure)
        fallbacks = sum(
            count(r, "migrate.fused.host_fallback") for r in structure
        )
        assert readouts == res.metrics.counter_value("match.fused_rounds")
        assert fallbacks == 0

    def test_spans_nest_under_decide(self, profile):
        obs = Observability()
        _run(profile, obs=obs)
        decides = [
            c
            for root in obs.tracer.structure()
            if root["name"] == "round"
            for c in root.get("children", ())
            if c["name"] == "decide"
        ]
        assert decides
        for d in decides:
            child_names = [c["name"] for c in d.get("children", ())]
            assert child_names[0] == "policy_sort"
            assert "place" in child_names and "pack" in child_names

    def test_explicit_spans_record_attrs_and_timings(self):
        t = Tracer()
        with t.span("outer", k=1) as sp:
            sp.annotate(result="ok")
            with t.span("inner"):
                pass
        (root,) = t.roots()
        assert root.name == "outer"
        assert root.attrs == {"k": 1, "result": "ok"}
        assert [c.name for c in root.children] == ["inner"]
        assert root.dur_s >= root.children[0].dur_s >= 0.0


# --------------------------------------------------------------------------- #
# Metrics: exactness + registry views
# --------------------------------------------------------------------------- #
class TestMetrics:
    def test_percentiles_are_nearest_rank_exact(self):
        h = Histogram("x")
        for v in range(1, 101):
            h.observe(float(v))
        assert h.percentile(50) == 50.0
        assert h.percentile(95) == 95.0
        assert h.percentile(99) == 99.0
        assert h.percentile(100) == 100.0
        single = Histogram("y")
        single.observe(7.0)
        assert single.percentile(50) == single.percentile(99) == 7.0
        with pytest.raises(ValueError):
            Histogram("empty").percentile(50)

    def test_simresult_views_equal_legacy_aggregations(self, profile):
        res = _run(profile, fused=True)
        rounds = res.match_rounds
        assert res.total_bid_iters == sum(
            int(rs.get("bid_iters", 0)) for rs in rounds
        )
        legacy_warm = sum(
            1 for rs in rounds[1:] if rs.get("warm_instances", 0) > 0
        )
        assert res.warm_hit_rounds(skip=1) == legacy_warm > 0
        assert res.fused_host_fallbacks == sum(
            int(rs.get("fused_host_fallbacks", 0)) for rs in rounds
        )
        assert res.degrade_counts == dict(Counter(res.degrade_rounds))

    def test_degrade_counts_view_under_forced_degradation(self, profile):
        # a 0-second decide deadline trips the ladder every round
        sched = _mk_sched(ClusterSpec(4, 4), profile)
        sched.decide_deadline_s = 0.0
        res = _run(profile, sched=sched)
        assert res.degrade_counts == dict(Counter(res.degrade_rounds))
        degraded = {
            k: v
            for k, v in res.degrade_counts.items()
            if k != DegradeReason.NONE
        }
        assert degraded, "0s deadline must force the degradation ladder"

    def test_deterministic_snapshot_excludes_timing(self, profile):
        obs1, obs2 = Observability(), Observability()
        _run(profile, obs=obs1)
        _run(profile, obs=obs2)
        snap1 = obs1.metrics.deterministic_snapshot()
        snap2 = obs2.metrics.deterministic_snapshot()
        assert snap1 == snap2
        flat = json.dumps(snap1)
        assert "decide.latency_s" not in flat
        assert "decide.stage." not in flat
        # ...while the full snapshot does carry the timing histograms
        assert "decide.latency_s" in json.dumps(obs1.metrics.snapshot())

    def test_summary_carries_decide_percentiles(self, profile):
        res = _run(profile)
        s = res.summary()
        assert s["decide_p50_s"] >= 0.0
        assert s["decide_p99_s"] >= s["decide_p50_s"]

    def test_registry_prefix_and_default_reads(self):
        m = MetricsRegistry()
        m.counter("sim.degrade.none").inc(3)
        m.counter("sim.degrade.deadline-host").inc()
        assert m.counters_with_prefix("sim.degrade.") == {
            "none": 3,
            "deadline-host": 1,
        }
        assert m.counter_value("absent") == 0
        assert m.histogram_values("absent") == []


# --------------------------------------------------------------------------- #
# Exports
# --------------------------------------------------------------------------- #
class TestExport:
    def test_chrome_trace_valid_and_json_roundtrips(self, profile, tmp_path):
        obs = Observability()
        _run(profile, obs=obs, fused=True)
        path = tmp_path / "trace.json"
        write_chrome_trace(obs.tracer, str(path))
        doc = json.loads(path.read_text())
        assert validate_chrome_trace(doc) == []
        events = doc["traceEvents"]
        assert events and all(e["ph"] == "X" for e in events)
        assert all(e["dur"] >= 0 and e["ts"] >= 0 for e in events)
        assert doc["otherData"]["schema"] == OBS_SCHEMA_VERSION
        names = {e["name"] for e in events}
        assert {"round", "decide", "migrate.fused"} <= names

    def test_obs_doc_valid(self, profile):
        obs = Observability()
        _run(profile, obs=obs)
        doc = to_obs_doc(obs.tracer, obs.metrics)
        assert doc["version"] == OBS_SCHEMA_VERSION
        assert validate_obs_doc(doc) == []
        assert doc["fingerprint"] == obs.tracer.fingerprint()

    def test_validators_reject_corruption(self, profile):
        obs = Observability()
        _run(profile, obs=obs)
        bad = to_obs_doc(obs.tracer, obs.metrics)
        bad["version"] = "tesserae-obs-v0"
        assert validate_obs_doc(bad)
        chrome = to_chrome_trace(obs.tracer)
        chrome["traceEvents"][0].pop("ts")
        assert validate_chrome_trace(chrome)


# --------------------------------------------------------------------------- #
# Crash-resume: the registry reseeds to the uninterrupted run's content
# --------------------------------------------------------------------------- #
class TestResume:
    def test_resume_reseeds_metrics_exactly(self, profile, tmp_path):
        baseline = _run(profile)
        cluster = ClusterSpec(4, 4)
        trace = shockwave_trace(
            num_jobs=N_JOBS,
            arrival_rate_per_hour=220.0,
            seed=SEED,
            profile=profile,
        )
        victim = Simulator(cluster, trace, _mk_sched(cluster, profile), profile)
        assert victim.run(stop_after_rounds=5) is None
        snap = str(tmp_path / "snap.npz")
        victim.save_state(snap)
        resumed = Simulator(
            cluster, trace, _mk_sched(cluster, profile), profile
        )
        resumed.load_state(snap)
        res = resumed.run()
        assert _fingerprint(res) == _fingerprint(baseline)
        assert (
            res.metrics.deterministic_snapshot()
            == baseline.metrics.deterministic_snapshot()
        )


# --------------------------------------------------------------------------- #
# Lint scoping (the tessalint manifest covers src/repro/obs)
# --------------------------------------------------------------------------- #
class TestLintScoping:
    @pytest.fixture()
    def lint(self):
        from tools.tessalint import Manifest, lint_file
        from tools.tessalint.manifest import DEFAULT_MANIFEST_PATH

        man = Manifest.load(DEFAULT_MANIFEST_PATH)

        def run(tmp_path, source, filename):
            p = tmp_path / "src" / "repro" / "obs" / filename
            p.parent.mkdir(parents=True, exist_ok=True)
            p.write_text(textwrap.dedent(source))
            return [f for f in lint_file(p, man) if not f.suppressed]

        return run

    def test_stray_device_readout_in_obs_fails_sync(self, lint, tmp_path):
        live = lint(
            tmp_path,
            """\
            import jax
            import jax.numpy as jnp
            import numpy as np

            def snapshot_device_val(device_val: jax.Array):
                return np.asarray(device_val)
            """,
            "probe.py",
        )
        assert any(f.rule == "sync" for f in live), [
            f.format_text() for f in live
        ]

    def test_wall_clock_in_obs_fails_det_perf_counter_clean(
        self, lint, tmp_path
    ):
        live = lint(
            tmp_path,
            """\
            import time

            def stamp():
                return time.time()
            """,
            "clocky.py",
        )
        assert any(f.rule == "det" for f in live)
        assert not lint(
            tmp_path,
            """\
            import time

            def stamp():
                return time.perf_counter()
            """,
            "clean.py",
        )

    def test_real_obs_modules_lint_clean(self):
        from tools.tessalint import Manifest, lint_file
        from tools.tessalint.manifest import DEFAULT_MANIFEST_PATH

        man = Manifest.load(DEFAULT_MANIFEST_PATH)
        repo = Path(__file__).resolve().parents[1]
        obs_dir = repo / "src" / "repro" / "obs"
        files = sorted(obs_dir.glob("*.py"))
        assert files
        for p in files:
            live = [f for f in lint_file(p, man) if not f.suppressed]
            assert live == [], [f.format_text() for f in live]


# --------------------------------------------------------------------------- #
# BENCH regression gate (file-only arm of perf_summary --check)
# --------------------------------------------------------------------------- #
class TestCheckGate:
    def test_committed_bench_files_pass_the_gate(self, capsys):
        from benchmarks.perf_summary import run_check

        assert run_check(fresh=False) == 0
        out = capsys.readouterr().out
        assert "0 failed" in out
