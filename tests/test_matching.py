"""Unit + property tests for the LAP solvers (hungarian, scipy, auction)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.matching.auction import auction_assignment, auction_lap
from repro.core.matching.hungarian import (
    assignment_cost,
    linear_sum_assignment,
    solve_lap,
)

scipy_lsa = pytest.importorskip("scipy.optimize").linear_sum_assignment


def _rand_cost(rng, n, m, integer=False):
    if integer:
        return rng.integers(0, 50, size=(n, m)).astype(float)
    return rng.uniform(0, 10, size=(n, m))


class TestHungarian:
    def test_identity(self):
        cost = np.array([[0.0, 1.0], [1.0, 0.0]])
        r, c = linear_sum_assignment(cost)
        assert list(r) == [0, 1] and list(c) == [0, 1]

    def test_matches_scipy_square(self):
        rng = np.random.default_rng(0)
        for n in [1, 2, 3, 5, 8, 17, 40]:
            cost = _rand_cost(rng, n, n)
            r1, c1 = linear_sum_assignment(cost)
            r2, c2 = scipy_lsa(cost)
            assert np.isclose(
                assignment_cost(cost, r1, c1), assignment_cost(cost, r2, c2)
            )

    def test_matches_scipy_rect(self):
        rng = np.random.default_rng(1)
        for n, m in [(2, 5), (5, 2), (7, 13), (13, 7), (1, 9)]:
            cost = _rand_cost(rng, n, m)
            r1, c1 = linear_sum_assignment(cost)
            r2, c2 = scipy_lsa(cost)
            assert len(r1) == min(n, m)
            assert np.isclose(
                assignment_cost(cost, r1, c1), assignment_cost(cost, r2, c2)
            )

    def test_maximize(self):
        rng = np.random.default_rng(2)
        cost = _rand_cost(rng, 6, 6)
        r1, c1 = linear_sum_assignment(cost, maximize=True)
        r2, c2 = scipy_lsa(cost, maximize=True)
        assert np.isclose(
            assignment_cost(cost, r1, c1), assignment_cost(cost, r2, c2)
        )

    def test_forbidden_edges(self):
        cost = np.array([[np.inf, 1.0], [1.0, np.inf]])
        r, c = linear_sum_assignment(cost)
        assert assignment_cost(cost, r, c) == 2.0

    @given(
        st.integers(1, 12),
        st.integers(1, 12),
        st.integers(0, 2**32 - 1),
    )
    @settings(max_examples=60, deadline=None)
    def test_property_optimal_vs_scipy(self, n, m, seed):
        rng = np.random.default_rng(seed)
        cost = _rand_cost(rng, n, m)
        r1, c1 = linear_sum_assignment(cost)
        r2, c2 = scipy_lsa(cost)
        # permutation validity
        assert len(set(r1)) == len(r1) and len(set(c1)) == len(c1)
        assert np.isclose(
            assignment_cost(cost, r1, c1), assignment_cost(cost, r2, c2)
        )

    def test_solve_lap_backends_agree(self):
        rng = np.random.default_rng(3)
        cost = _rand_cost(rng, 30, 30)
        r1, c1 = solve_lap(cost, backend="numpy")
        r2, c2 = solve_lap(cost, backend="scipy")
        assert np.isclose(
            assignment_cost(cost, r1, c1), assignment_cost(cost, r2, c2)
        )


class TestAuction:
    def test_small_exact(self):
        rng = np.random.default_rng(0)
        for n in [1, 2, 4, 8, 16]:
            cost = rng.integers(0, 20, size=(n, n)).astype(float)
            r, c = auction_assignment(cost)
            r2, c2 = scipy_lsa(cost)
            assert np.isclose(
                assignment_cost(cost, r, c), assignment_cost(cost, r2, c2)
            ), f"n={n}"

    def test_maximize(self):
        rng = np.random.default_rng(1)
        cost = rng.integers(0, 20, size=(8, 8)).astype(float)
        r, c = auction_assignment(cost, maximize=True)
        r2, c2 = scipy_lsa(cost, maximize=True)
        assert np.isclose(
            assignment_cost(cost, r, c), assignment_cost(cost, r2, c2)
        )

    def test_converged_flag_and_permutation(self):
        import jax.numpy as jnp

        rng = np.random.default_rng(2)
        b = jnp.asarray(rng.integers(0, 30, size=(12, 12)).astype(np.float32))
        res = auction_lap(b)
        assert bool(res.converged)
        col = np.asarray(res.col_of)
        assert sorted(col.tolist()) == list(range(12))

    @given(st.integers(1, 9), st.integers(0, 2**32 - 1))
    @settings(max_examples=25, deadline=None)
    def test_property_integer_optimal(self, n, seed):
        rng = np.random.default_rng(seed)
        cost = rng.integers(0, 15, size=(n, n)).astype(float)
        r, c = auction_assignment(cost)
        r2, c2 = scipy_lsa(cost)
        assert np.isclose(
            assignment_cost(cost, r, c), assignment_cost(cost, r2, c2)
        )

    def test_batched(self):
        import jax.numpy as jnp

        from repro.core.matching.auction import auction_lap_batched

        rng = np.random.default_rng(3)
        batch = rng.integers(0, 25, size=(6, 5, 5)).astype(np.float32)
        res = auction_lap_batched(jnp.asarray(batch))
        for i in range(6):
            col = np.asarray(res.col_of[i])
            got = batch[i][np.arange(5), col].sum()
            r2, c2 = scipy_lsa(batch[i], maximize=True)
            assert np.isclose(got, batch[i][r2, c2].sum()), f"instance {i}"
