"""Workload scenario lab: schema round-trips, loader semantics, generator
distribution sanity (KS-style bounds), scenario-registry determinism, and
heterogeneous-cluster backward compatibility (homogeneous configs must be
bit-identical to the seed paths)."""

import dataclasses
import json
import math

import numpy as np
import pytest

from repro import workloads as W
from repro.core.cluster import ClusterSpec
from repro.core.migration import CROSS_RACK_COST, _relabel_penalties, plan_migration
from repro.core.packing import build_packing_graph, pack_jobs
from repro.core.policies import TiresiasPolicy
from repro.core.profiler import GPU_TYPES, ThroughputProfile
from repro.core.scheduler import TesseraeScheduler
from repro.core.simulator import SimConfig, Simulator
from repro.core.traces import iters_for_duration, shockwave_trace
from repro.workloads.generators import Arrivals, Durations, GangSizes

pytest.importorskip("scipy.optimize")

PROFILE = ThroughputProfile()


# --------------------------------------------------------------------------- #
# Schema
# --------------------------------------------------------------------------- #
class TestSchema:
    def test_exactly_one_profile_field(self):
        with pytest.raises(ValueError):
            W.JobTrace(0, "resnet50", 1, 0.0)
        with pytest.raises(ValueError):
            W.JobTrace(0, "resnet50", 1, 0.0, duration_s=10.0, total_iters=5.0)

    def test_priority_validation(self):
        with pytest.raises(ValueError):
            W.JobTrace(0, "resnet50", 1, 0.0, duration_s=10.0, priority="vip")

    def test_duration_materialisation_matches_fixture_rule(self):
        t = W.JobTrace(7, "vgg19", 4, 30.0, duration_s=1800.0)
        spec = t.to_jobspec(PROFILE)
        assert spec.total_iters == iters_for_duration("vgg19", 4, 1800.0, PROFILE)
        assert spec.arrival_time == 30.0
        assert spec.packable  # best-effort packs

    def test_production_jobs_bypass_packing(self):
        t = W.JobTrace(1, "gpt3-xl", 8, 0.0, duration_s=600.0, priority="production")
        spec = t.to_jobspec(PROFILE)
        assert not spec.packable
        assert spec.is_llm

    def test_json_round_trip(self, tmp_path):
        trace = W.scenario("philly-like-burst").make_trace(seed=11, num_jobs=40)
        p = tmp_path / "trace.json"
        W.save_json(str(p), trace, meta={"note": "round-trip"})
        assert W.load_json(str(p)) == trace
        doc = json.loads(p.read_text())
        assert doc["schema"] == W.SCHEMA_VERSION

    def test_json_rejects_wrong_schema(self, tmp_path):
        p = tmp_path / "bad.json"
        p.write_text(json.dumps({"schema": "v0", "jobs": []}))
        with pytest.raises(ValueError):
            W.load_json(str(p))

    def test_fixture_round_trip_is_lossless(self):
        specs = shockwave_trace(num_jobs=25, seed=4, profile=PROFILE)
        back = W.to_jobspecs(W.from_jobspecs(specs), PROFILE)
        assert back == sorted(specs, key=lambda s: (s.arrival_time, s.job_id))


# --------------------------------------------------------------------------- #
# Loaders
# --------------------------------------------------------------------------- #
class TestPhillyLoader:
    def test_sample_loads(self):
        trace = W.philly_sample()
        assert len(trace) >= 40
        assert all(t.duration_s and t.duration_s > 0 for t in trace)
        # arrivals re-based and sorted
        arr = [t.arrival_s for t in trace]
        assert arr[0] == 0.0 and arr == sorted(arr)
        # ids dense
        assert [t.job_id for t in trace] == list(range(len(trace)))

    def test_failed_rows_dropped_and_vc_priority(self):
        trace = W.philly_sample()
        # the committed sample contains one Failed row out of 48
        assert len(trace) == 47
        assert any(t.priority == "production" for t in trace)

    def test_unknown_models_map_deterministically(self):
        from repro.core.profiler import MODEL_CATALOG
        from repro.workloads.loaders import _canonical_model

        assert _canonical_model("resnet50") == "resnet50"
        m1, m2 = _canonical_model("bert-large"), _canonical_model("bert-large")
        assert m1 == m2
        assert m1 in MODEL_CATALOG

    def test_csv_round_trip(self, tmp_path):
        trace = W.scenario("poisson-steady").make_trace(seed=2, num_jobs=20)
        p = tmp_path / "t.csv"
        W.save_philly_csv(str(p), trace)
        back = W.load_philly_csv(str(p))
        assert len(back) == len(trace)
        for a, b in zip(trace, back):
            assert a.model == b.model and a.num_gpus == b.num_gpus
            assert b.duration_s == pytest.approx(a.duration_s, abs=0.05)

    def test_missing_columns_rejected(self, tmp_path):
        p = tmp_path / "bad.csv"
        p.write_text("job_id,num_gpus\n0,1\n")
        with pytest.raises(ValueError):
            W.load_philly_csv(str(p))


# --------------------------------------------------------------------------- #
# Generators: seeded determinism + distribution sanity
# --------------------------------------------------------------------------- #
def _ks_exponential(samples: np.ndarray, mean: float) -> float:
    """KS statistic of ``samples`` against Exp(mean)."""
    x = np.sort(samples) / mean
    cdf = 1.0 - np.exp(-x)
    emp_hi = np.arange(1, len(x) + 1) / len(x)
    emp_lo = np.arange(0, len(x)) / len(x)
    return float(np.maximum(np.abs(cdf - emp_hi), np.abs(cdf - emp_lo)).max())


class TestGenerators:
    def test_seeded_determinism_every_kind(self):
        for kind in ("poisson", "diurnal", "bursty"):
            a = Arrivals(kind=kind).sample(np.random.default_rng(9), 200)
            b = Arrivals(kind=kind).sample(np.random.default_rng(9), 200)
            np.testing.assert_array_equal(a, b)
        for kind in ("lognormal", "pareto", "loguniform"):
            a = Durations(kind=kind).sample(np.random.default_rng(9), 200)
            b = Durations(kind=kind).sample(np.random.default_rng(9), 200)
            np.testing.assert_array_equal(a, b)

    def test_poisson_interarrivals_are_exponential(self):
        arr = Arrivals(kind="poisson", rate_per_hour=120.0).sample(
            np.random.default_rng(0), 4000
        )
        gaps = np.diff(arr)
        # KS bound: 1.63/sqrt(n) is the 1% critical value; allow slack
        assert _ks_exponential(gaps, 3600.0 / 120.0) < 2.0 / math.sqrt(len(gaps))
        assert gaps.mean() == pytest.approx(30.0, rel=0.1)

    def test_diurnal_peak_trough_ratio(self):
        spec = Arrivals(kind="diurnal", rate_per_hour=60.0, peak_ratio=4.0)
        arr = spec.sample(np.random.default_rng(1), 6000)
        period = spec.period_h * 3600.0
        phase = (arr % period) / period
        # peak half-period (phase around 0.5) vs trough half (around 0.0)
        peak = np.sum((phase > 0.25) & (phase < 0.75))
        trough = len(arr) - peak
        assert peak / max(trough, 1) > 2.0

    def test_bursty_is_burstier_than_poisson(self):
        rng = np.random.default_rng(2)
        bur = np.diff(Arrivals(kind="bursty", rate_per_hour=60.0).sample(rng, 3000))
        poi = np.diff(
            Arrivals(kind="poisson", rate_per_hour=60.0).sample(
                np.random.default_rng(2), 3000
            )
        )
        # coefficient of variation: bursts push it well above Poisson's ~1
        cv = lambda g: g.std() / g.mean()
        assert cv(bur) > 1.5 * cv(poi)

    def test_lognormal_median_and_shape(self):
        d = Durations(kind="lognormal", median_s=1800.0, sigma=1.2, min_s=1.0).sample(
            np.random.default_rng(3), 5000
        )
        assert np.median(d) == pytest.approx(1800.0, rel=0.12)
        logs = np.log(d)
        assert logs.std() == pytest.approx(1.2, rel=0.12)

    def test_pareto_tail_is_heavy(self):
        d = Durations(
            kind="pareto", median_s=600.0, alpha=1.1, cap_s=10**9, min_s=1.0
        ).sample(np.random.default_rng(4), 5000)
        med = np.median(d)
        # heavy tail: the top decile dominates total mass (untrue for
        # lognormal sigma<<1 / exponential at these sizes)
        top = np.sort(d)[-len(d) // 10 :]
        assert top.sum() > 0.5 * d.sum()
        assert d.max() > 50 * med

    def test_gang_size_frequencies(self):
        g = GangSizes(sizes=(1, 2, 4, 8), probs=(0.6, 0.25, 0.1, 0.05)).sample(
            np.random.default_rng(5), 8000
        )
        freq = {s: np.mean(g == s) for s in (1, 2, 4, 8)}
        for s, p in zip((1, 2, 4, 8), (0.6, 0.25, 0.1, 0.05)):
            assert freq[s] == pytest.approx(p, abs=0.03)

    def test_generate_trace_deterministic_and_valid(self):
        sc = W.scenario("tiresias-churn")
        t1 = sc.make_trace(seed=6, num_jobs=60)
        t2 = sc.make_trace(seed=6, num_jobs=60)
        assert t1 == t2
        assert t1 != sc.make_trace(seed=7, num_jobs=60)
        for t in t1:
            t.to_jobspec(PROFILE)  # validates model/gang/profile coupling


# --------------------------------------------------------------------------- #
# Scenario registry
# --------------------------------------------------------------------------- #
class TestScenarioRegistry:
    def test_registry_contract(self):
        names = W.list_scenarios()
        assert len(names) >= 6
        kinds = {n: W.scenario(n).kind for n in names}
        assert sum(k == "synthetic" for k in kinds.values()) >= 4
        assert sum(k in ("loader", "fixture") for k in kinds.values()) >= 1
        assert any(W.scenario(n).heterogeneous for n in names)

    def test_every_scenario_seeded_deterministic(self):
        for name in W.list_scenarios():
            sc = W.scenario(name)
            t1 = sc.make_trace(seed=13, num_jobs=20, profile=PROFILE)
            t2 = sc.make_trace(seed=13, num_jobs=20, profile=PROFILE)
            assert t1 == t2, name
            assert len(t1) > 0, name

    def test_unknown_scenario_raises(self):
        with pytest.raises(KeyError):
            W.scenario("no-such-scenario")

    def test_duplicate_registration_rejected(self):
        sc = W.scenario("poisson-steady")
        with pytest.raises(ValueError):
            W.register_scenario(sc)


# --------------------------------------------------------------------------- #
# Heterogeneous clusters: semantics + backward compatibility
# --------------------------------------------------------------------------- #
def _run_sim(cluster, num_jobs=18, seed=5, backend="scipy"):
    trace = shockwave_trace(num_jobs=num_jobs, seed=seed, profile=PROFILE)
    sched = TesseraeScheduler(
        cluster, TiresiasPolicy(PROFILE, queue_base=900.0), PROFILE, lap_backend=backend
    )
    res = Simulator(cluster, trace, sched, PROFILE, SimConfig()).run()
    return res, sched


class TestHeterogeneousClusters:
    def test_cluster_spec_accessors(self):
        cl = ClusterSpec(4, 4, node_gpu_types=("a100", "a100", "v100", "v100"),
                         nodes_per_rack=2)
        assert cl.is_heterogeneous and cl.has_topology
        assert cl.gpu_type_of(0) == "a100" and cl.gpu_type_of(3) == "v100"
        assert cl.rack_of(1) == 0 and cl.rack_of(2) == 1
        assert cl.num_racks == 2
        with pytest.raises(ValueError):
            ClusterSpec(4, 4, node_gpu_types=("a100",))

    def test_homogeneous_defaults_unchanged(self):
        plain = ClusterSpec(4, 4)
        assert not plain.is_heterogeneous and not plain.has_topology
        assert plain.node_types() == ("a100",) * 4
        assert _relabel_penalties(plain) is None

    def test_uniform_typed_cluster_bit_identical_to_untyped(self):
        """The heterogeneity plumbing must be inert when every node has
        the profile's own type: placements, JCTs, migrations identical."""
        plain, _ = _run_sim(ClusterSpec(4, 4))
        typed, _ = _run_sim(ClusterSpec(4, 4, node_gpu_types=("a100",) * 4))
        np.testing.assert_array_equal(
            [plain.jobs[j].finish_time for j in sorted(plain.jobs)],
            [typed.jobs[j].finish_time for j in sorted(typed.jobs)],
        )
        assert plain.total_migrations == typed.total_migrations
        assert plain.makespan_s == typed.makespan_s

    def test_v100_nodes_actually_slower(self):
        fast, _ = _run_sim(ClusterSpec(4, 4, node_gpu_types=("a100",) * 4))
        slow, _ = _run_sim(ClusterSpec(4, 4, node_gpu_types=("v100",) * 4))
        assert slow.avg_jct_s > fast.avg_jct_s
        assert slow.makespan_s > fast.makespan_s

    def test_relabel_penalties_structure(self):
        cl = ClusterSpec(4, 2, node_gpu_types=("a100", "a100", "v100", "v100"),
                         nodes_per_rack=2)
        pen = _relabel_penalties(cl)
        assert pen.shape == (4, 4)
        assert pen[0, 1] == 0.0  # same type, same rack
        assert pen[0, 2] > 2.0 * cl.gpus_per_node * cl.num_nodes  # type wall
        # same-type cross-rack pair does not exist here; racked-only case:
        cl2 = ClusterSpec(4, 2, nodes_per_rack=2)
        pen2 = _relabel_penalties(cl2)
        assert pen2[0, 1] == 0.0 and pen2[0, 2] == CROSS_RACK_COST

    def test_migration_relabel_is_type_preserving(self):
        """A plan shifted wholesale across node indices must relabel back
        within its type class — never rename an A100 plan row onto V100."""
        from repro.core.cluster import PlacementPlan

        cl = ClusterSpec(4, 2, node_gpu_types=("a100", "a100", "v100", "v100"))
        prev = PlacementPlan(cl)
        prev.place_job(1, [0, 1])  # node 0 (a100)
        prev.place_job(2, [4, 5])  # node 2 (v100)
        new = PlacementPlan(cl)
        new.place_job(1, [2, 3])   # logically node 1 (a100)
        new.place_job(2, [6, 7])   # logically node 3 (v100)
        res = plan_migration(prev, new, {1: 2, 2: 2}, algorithm="node")
        # relabelling keeps each job on its original node: zero migrations
        assert res.num_migrations == 0
        phys = res.physical_plan.job_gpu_map()
        assert phys[1] == frozenset({0, 1})
        assert phys[2] == frozenset({4, 5})

    def test_rack_penalty_prefers_local_relabel(self):
        from repro.core.cluster import PlacementPlan

        cl = ClusterSpec(4, 2, nodes_per_rack=2)
        prev = PlacementPlan(cl)
        prev.place_job(1, [0, 1])  # rack 0
        new = PlacementPlan(cl)
        new.place_job(1, [2, 3])   # logical node 1, still rack 0
        res = plan_migration(prev, new, {1: 2}, algorithm="node")
        assert res.num_migrations == 0
        assert res.physical_plan.job_gpu_map()[1] == frozenset({0, 1})

    def test_packing_weights_respect_node_hbm(self):
        """A pair that fits in 40 GB but OOMs in 16 GB must lose its edge
        exactly when the placed job sits on a V100 node."""
        from repro.core.jobs import JobSpec, JobState

        mk = lambda jid, model: JobState(
            spec=JobSpec(job_id=jid, model=model, num_gpus=1, total_iters=1e5,
                         arrival_time=0.0)
        )
        placed, pending = [mk(0, "gpt3-xl")], [mk(1, "gpt3-medium")]
        w_a100 = build_packing_graph(placed, pending, PROFILE,
                                     placed_gpu_types=["a100"])
        w_v100 = build_packing_graph(placed, pending, PROFILE,
                                     placed_gpu_types=["v100"])
        assert w_a100[0, 0] > 0.0
        assert w_v100[0, 0] == 0.0  # 25 + 17 GB >> 16 GB HBM
        # and the None path is bit-identical to the uniform-type path
        w_none = build_packing_graph(placed, pending, PROFILE)
        np.testing.assert_array_equal(w_none, w_a100)

    def test_hetero_scenario_end_to_end(self):
        sc = W.scenario("hetero-mixed")
        cl = sc.make_cluster(16)
        assert cl.is_heterogeneous and cl.has_topology
        trace = W.to_jobspecs(sc.make_trace(seed=1, num_jobs=16, profile=PROFILE),
                              PROFILE)
        sched = TesseraeScheduler(cl, TiresiasPolicy(PROFILE), PROFILE)
        res = Simulator(cl, trace, sched, PROFILE, SimConfig()).run()
        assert all(s.finished for s in res.jobs.values())
        # the same workload on an all-A100 cluster of equal size finishes
        # sooner: the V100 half really runs at V100 speed
        homo = ClusterSpec(cl.num_nodes, cl.gpus_per_node)
        sched2 = TesseraeScheduler(homo, TiresiasPolicy(PROFILE), PROFILE)
        res2 = Simulator(homo, trace, sched2, PROFILE, SimConfig()).run()
        assert res.avg_jct_s > res2.avg_jct_s


# --------------------------------------------------------------------------- #
# Evaluation harness plumbing (smoke-level, never timing)
# --------------------------------------------------------------------------- #
class TestEvaluateHarness:
    def test_run_arm_schema_and_determinism(self):
        import sys, os
        sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
        from benchmarks.evaluate import DETERMINISTIC_METRICS, run_arm, validate_schema

        a1 = run_arm("tesserae-t", "poisson-steady", 16, 12, seed=3)
        a2 = run_arm("tesserae-t", "poisson-steady", 16, 12, seed=3)
        for k in DETERMINISTIC_METRICS:
            assert a1["metrics"][k] == a2["metrics"][k], k
        assert a1["match_telemetry"] == a2["match_telemetry"]
        assert a1["match_telemetry"]["warm_instances"] > 0
        assert validate_schema({"arms": [a1]}) == []


# --------------------------------------------------------------------------- #
# Failure-event schema (trace-v2 envelope) + failure-generator bounds
# --------------------------------------------------------------------------- #
class TestFailureSchema:
    def _events(self):
        from repro.core.faults import (
            GPU_DEGRADE,
            JOB_FAIL,
            NODE_DOWN,
            NODE_UP,
            FailureEvent,
        )

        return [
            FailureEvent(100.0, NODE_DOWN, node=2),
            FailureEvent(700.0, NODE_UP, node=2),
            FailureEvent(300.0, GPU_DEGRADE, node=0, factor=0.5),
            FailureEvent(900.0, JOB_FAIL, job_id=4),
        ]

    def test_v2_round_trip_with_failures(self, tmp_path):
        trace = W.scenario("poisson-steady").make_trace(seed=1, num_jobs=12)
        p = tmp_path / "t.json"
        W.save_json(str(p), trace, failures=self._events())
        back_trace, back_failures = W.load_json_with_failures(str(p))
        assert back_trace == trace
        assert back_failures == sorted(
            self._events(), key=lambda e: e.sort_key()
        )
        doc = json.loads(p.read_text())
        assert doc["schema"] == W.SCHEMA_VERSION == "tesserae-trace-v2"
        # plain load_json still works on a failure-carrying document
        assert W.load_json(str(p)) == trace

    def test_no_failures_key_when_absent(self, tmp_path):
        trace = W.scenario("poisson-steady").make_trace(seed=1, num_jobs=5)
        p = tmp_path / "t.json"
        W.save_json(str(p), trace)
        assert "failures" not in json.loads(p.read_text())
        _, failures = W.load_json_with_failures(str(p))
        assert failures == []

    def test_v1_documents_still_load(self, tmp_path):
        trace = W.scenario("poisson-steady").make_trace(seed=2, num_jobs=8)
        p = tmp_path / "t.json"
        W.save_json(str(p), trace)
        doc = json.loads(p.read_text())
        doc["schema"] = "tesserae-trace-v1"
        p.write_text(json.dumps(doc))
        assert W.load_json(str(p)) == trace
        back, failures = W.load_json_with_failures(str(p))
        assert back == trace and failures == []


class TestFailureGeneratorBounds:
    def test_first_crash_times_are_exponential(self):
        from repro.workloads.failures import NodeOutages

        mtbf_s = 2.0 * 3600.0
        spec = NodeOutages(mtbf_h=2.0)
        events = spec.sample(
            np.random.default_rng(0), num_nodes=500, horizon_s=1e9
        )
        first = {}
        for e in events:
            if e.kind == "node-down" and e.node not in first:
                first[e.node] = e.time_s
        samples = np.array(sorted(first.values()))
        assert len(samples) == 500
        assert _ks_exponential(samples, mtbf_s) < 2.0 / math.sqrt(len(samples))
        assert samples.mean() == pytest.approx(mtbf_s, rel=0.15)

    def test_repair_durations_match_lognormal_median(self):
        from repro.workloads.failures import NodeOutages

        spec = NodeOutages(mtbf_h=0.5, repair_median_s=1800.0, repair_sigma=0.8)
        events = spec.sample(
            np.random.default_rng(1), num_nodes=300, horizon_s=1e8
        )
        downs, repairs = {}, []
        for e in sorted(events, key=lambda e: e.sort_key()):
            if e.kind == "node-down":
                downs[e.node] = e.time_s
            elif e.kind == "node-up":
                repairs.append(e.time_s - downs.pop(e.node))
        repairs = np.array(repairs)
        assert len(repairs) > 500
        assert np.all(repairs >= spec.min_repair_s)
        assert np.median(repairs) == pytest.approx(1800.0, rel=0.15)

    def test_degradation_factors_bounded_and_closed(self):
        from repro.workloads.failures import GpuDegradations

        spec = GpuDegradations(rate_per_node_per_day=48.0, factor_range=(0.3, 0.9))
        events = spec.sample(
            np.random.default_rng(2), num_nodes=100, horizon_s=86400.0
        )
        onsets = [e for e in events if e.factor != 1.0]
        assert onsets and all(0.3 <= e.factor <= 0.9 for e in onsets)
        # every episode that closes, closes with a full-speed restore
        restores = [e for e in events if e.factor == 1.0]
        assert len(onsets) - len(restores) <= 100

    def test_job_failure_rate_matches_fail_prob(self):
        from repro.workloads.failures import JobFailures

        trace = W.scenario("poisson-steady").make_trace(seed=3, num_jobs=2000)
        spec = JobFailures(fail_prob=0.15, max_failures=2)
        events = spec.sample(np.random.default_rng(3), trace)
        failed_jobs = {e.job_id for e in events}
        frac = len(failed_jobs) / len(trace)
        # binomial 3-sigma band around 0.15 at n=2000
        assert abs(frac - 0.15) < 3.0 * math.sqrt(0.15 * 0.85 / len(trace))
        arrivals = {t.job_id: t.arrival_s for t in trace}
        assert all(e.time_s >= arrivals[e.job_id] for e in events)
        per_job = {}
        for e in events:
            per_job[e.job_id] = per_job.get(e.job_id, 0) + 1
        assert max(per_job.values()) <= spec.max_failures

    def test_scenario_failure_streams_deterministic(self):
        sc = W.scenario("philly-failures")
        cluster = sc.make_cluster(16)
        rows = sc.make_trace(seed=5, num_jobs=30)
        a = sc.make_failures(5, cluster, 36_000.0, trace=rows)
        b = sc.make_failures(5, cluster, 36_000.0, trace=rows)
        assert a == b and len(a) > 0
        assert W.scenario("poisson-steady").make_failures(
            5, cluster, 36_000.0
        ) == []
