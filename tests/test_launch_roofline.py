"""Launch-layer tests: sharding rules, input specs, HLO collective parser."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_reduced, list_archs
from repro.launch.mesh import make_smoke_mesh
from repro.launch.pspec import ShardingRules, constrain, use_rules
from repro.launch.specs import (
    INPUT_SHAPES,
    batch_logical_axes,
    bytes_per_device,
    input_specs,
    logical_axes_for,
    sharding_tree,
)
from repro.roofline import bytes_of_type, parse_collectives


class TestShardingRules:
    def _rules(self):
        return ShardingRules(make_smoke_mesh())

    def test_divisibility_fallback(self):
        rules = self._rules()
        # fake a 16-way model axis by monkeypatching axis_size
        rules.axis_size = lambda phys: 16 if phys else 1
        spec = rules.spec_for((12, 128), ("heads", "ff"))
        assert spec[0] is None  # 12 heads don't divide 16
        assert spec[1] == "model"

    def test_duplicate_mesh_axis_suppressed(self):
        rules = ShardingRules(make_smoke_mesh(), {"seq": "model"})
        rules.axis_size = lambda phys: 16 if phys else 1
        spec = rules.spec_for((256, 4096, 32, 128), ("batch", "seq", "heads", None))
        # seq takes "model"; heads must NOT also get it
        assert spec[1] == "model"
        assert spec[2] is None

    def test_constrain_noop_outside_context(self):
        x = jnp.ones((4, 4))
        assert constrain(x, "batch", None) is x

    def test_constrain_rank_mismatch(self):
        rules = self._rules()
        with use_rules(rules):
            with pytest.raises(ValueError):
                constrain(jnp.ones((4, 4)), "batch")


class TestInputSpecs:
    @pytest.mark.parametrize("arch", list_archs())
    @pytest.mark.parametrize("shape_name", list(INPUT_SHAPES))
    def test_specs_exist_and_are_abstract(self, arch, shape_name):
        cfg = get_config(arch)
        specs = input_specs(cfg, INPUT_SHAPES[shape_name])
        assert "tokens" in specs
        for v in specs.values():
            assert isinstance(v, jax.ShapeDtypeStruct)
        shp = INPUT_SHAPES[shape_name]
        if shp.kind == "decode":
            assert specs["tokens"].shape == (shp.global_batch, 1)
        else:
            assert specs["tokens"].shape == (shp.global_batch, shp.seq_len)
        if cfg.frontend == "vision" and shp.kind != "decode":
            assert "image_embeds" in specs
        if cfg.frontend == "audio" and shp.kind != "decode":
            assert "audio_frames" in specs

    def test_param_logical_axes_patterns(self):
        assert logical_axes_for("embed", (1000, 64)) == ("vocab", "fsdp")
        assert logical_axes_for("layers.attn.wq", (4, 64, 8, 16)) == (
            None,
            "fsdp",
            "heads",
            None,
        )
        assert logical_axes_for("layers.moe.w_gate", (4, 8, 64, 128)) == (
            None,
            "expert",
            "fsdp",
            None,
        )
        # shared experts are dense ffn, not expert-parallel
        assert logical_axes_for("layers.moe.shared.w_gate", (4, 64, 128)) == (
            None,
            "fsdp",
            "ff",
        )
        assert logical_axes_for("layers.norm1", (4, 64)) == (None, None)
        assert logical_axes_for("layers.mamba.in_proj", (4, 64, 300)) == (
            None,
            "fsdp",
            "ssm_inner",
        )

    def test_bytes_per_device_unsharded(self):
        rules = ShardingRules(make_smoke_mesh())
        tree = {"a": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
        sh = sharding_tree(tree, rules, lambda p, s: (None, None))
        assert bytes_per_device(tree, sh) == 8 * 8 * 4


class TestCollectiveParser:
    HLO = """
HloModule jit_step

fused_computation {
  %p0 = f32[128,256]{1,0} parameter(0)
  ROOT %add.1 = f32[128,256]{1,0} add(%p0, %p0)
}

ENTRY main {
  %arg0 = f32[128,256]{1,0} parameter(0)
  %arg1 = bf16[64,64]{1,0} parameter(1)
  %all-gather.1 = f32[2048,256]{1,0} all-gather(%arg0), replica_groups={}, dimensions={0}
  %all-reduce.2 = f32[128,256]{1,0} all-reduce(%arg0), to_apply=%fused_computation
  %ar-start = f32[128,256]{1,0} all-reduce-start(%arg0), to_apply=%fused_computation
  %ar-done = f32[128,256]{1,0} all-reduce-done(%ar-start)
  %cp = bf16[64,64]{1,0} collective-permute(%arg1), source_target_pairs={{0,1}}
  ROOT %t = (f32[2048,256]{1,0}) tuple(%all-gather.1)
}
"""

    def test_bytes_of_type(self):
        assert bytes_of_type("f32[128,256]{1,0}") == 128 * 256 * 4
        assert bytes_of_type("bf16[64,64]") == 64 * 64 * 2
        assert bytes_of_type("(f32[8], bf16[4])") == 8 * 4 + 4 * 2
        assert bytes_of_type("pred[]") == 1

    def test_parse_collectives(self):
        stats = parse_collectives(self.HLO)
        assert stats.by_kind["all-gather"][0] == 1
        assert stats.by_kind["all-gather"][1] == 128 * 256 * 4  # operand size
        # all-reduce counted twice (plain + -start), -done skipped
        assert stats.by_kind["all-reduce"][0] == 2
        assert stats.by_kind["collective-permute"] == (1, 64 * 64 * 2)


class TestShardedSmoke:
    def test_sharded_forward_on_smoke_mesh(self):
        """The constrain() path executes under a real (1x1) mesh."""
        from repro.launch.mesh import dp_axes_of
        from repro.models import get_model

        cfg = get_reduced("llama3-8b")
        model = get_model(cfg)
        mesh = make_smoke_mesh()
        rules = ShardingRules(mesh, dp_axes=("data",))
        params = model.init(jax.random.PRNGKey(0), cfg)
        batch = {"tokens": jnp.zeros((2, 16), jnp.int32)}
        with mesh, use_rules(rules):
            logits, _ = jax.jit(lambda p, b: model.forward(p, cfg, b))(params, batch)
        assert logits.shape == (2, 16, cfg.vocab_size)
